"""Make the `compile` package importable whether pytest runs from the repo
root (`pytest python/tests/`) or from `python/` (`pytest tests/`)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
