"""L1 Pallas kernels for the Isomap block ops.

Authored for the TPU mental model (VMEM-tiled BlockSpecs, MXU-shaped inner
products where the semiring allows) but always lowered with
``interpret=True``: the CPU PJRT plugin cannot execute Mosaic custom-calls,
and interpret mode lowers each kernel to plain HLO that the Rust runtime's
CPU client runs bit-for-bit (see DESIGN.md §Hardware-Adaptation).
"""

from . import fw, minplus, ref, sqdist  # noqa: F401
