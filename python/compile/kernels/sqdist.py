"""Pairwise Euclidean-distance block Pallas kernel (kNN stage).

Uses the Gram expansion ‖x‖² + ‖y‖² − 2·x·yᵀ so the inner product is a
plain matmul: on a real TPU this is the MXU-eligible formulation (the
point blocks stream through the systolic array), unlike the naive
(bi, bj, D) difference tensor which is VPU-bound and D× larger in VMEM.
At (b=128, D=784) the VMEM working set is 2·128·784·8 ≈ 1.6 MiB.
Cancellation guard: clamp tiny negative d² to 0 before the sqrt.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.experimental import pallas as pl  # noqa: E402


def _sqdist_kernel(xi_ref, xj_ref, o_ref):
    xi = xi_ref[...]  # (bi, D)
    xj = xj_ref[...]  # (bj, D)
    gram = jax.lax.dot_general(
        xi, xj, (((1,), (1,)), ((), ())), preferred_element_type=xi.dtype
    )  # xi @ xj.T
    ni = jnp.sum(xi * xi, axis=1, keepdims=True)
    nj = jnp.sum(xj * xj, axis=1)
    d2 = ni + nj[None, :] - 2.0 * gram
    o_ref[...] = jnp.sqrt(jnp.maximum(d2, 0.0))


@jax.jit
def dist_block(xi, xj):
    """(bi, D) × (bj, D) → (bi, bj) Euclidean distance block."""
    bi, dim = xi.shape
    bj, dim2 = xj.shape
    assert dim == dim2, f"dimension mismatch {xi.shape} x {xj.shape}"
    # One block pair per call: the engine's unit of work is already a tile.
    return pl.pallas_call(
        _sqdist_kernel,
        out_shape=jax.ShapeDtypeStruct((bi, bj), xi.dtype),
        interpret=True,
    )(xi, xj)
