"""Tiled min-plus (tropical) matrix-product Pallas kernel — the APSP hot
spot (the paper's Numba-JIT'd Python routine).

TPU mapping (DESIGN.md §9): the semiring product has no MXU path (it is a
select-add, not a multiply-accumulate), so the kernel targets the VPU with
a 3-D broadcast over a short `k` tile. Tiles of (bm, bk)·(bk, bn) stay
resident in VMEM; the accumulator tile is initialized to +∞ on the first
`k` step and min-reduced across the `k` grid dimension. With the default
(128, 8, 128) tiling the working set is 128·8·128 f64 ≈ 1 MiB — well
inside VMEM with room to double-buffer.
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.experimental import pallas as pl  # noqa: E402

# Default k-tile. The kernel loops rank-1 updates inside the tile, so the
# working set is just the three 2-D tiles (no 3-D broadcast intermediate —
# §Perf: the (bm, bk, bn) tensor formulation was 1.6–2.4 ms/block at
# b=128 vs ~1 ms for the rank-1 loop).
BK = 128


def _minplus_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: bk rank-1 updates o = min(o, a[:,k]+b[k,:]).

    Mirrors the FW kernel's structure: each step is a fully vectorized
    (bm, bn) VPU op with the pivot column/row broadcast from VMEM.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)

    def body(k, o):
        col = jax.lax.dynamic_slice_in_dim(a, k, 1, axis=1)  # (bm, 1)
        row = jax.lax.dynamic_slice_in_dim(b, k, 1, axis=0)  # (1, bn)
        return jnp.minimum(o, col + row)

    o_ref[...] = jax.lax.fori_loop(0, a.shape[1], body, o_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def minplus(a, b, *, bm=None, bn=None, bk=None):
    """C = A ⊗ B over (min, +). Shapes (m, k)·(k, n); tiles must divide."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"shape mismatch {a.shape} x {b.shape}"
    bm = bm or min(m, 128)
    bn = bn or min(n, 128)
    bk = bk or min(k, BK)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, "tiles must divide"
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)
