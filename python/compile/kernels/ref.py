"""Pure-jnp oracles for every block op.

These are the correctness ground truth: pytest asserts each Pallas kernel
(and the composed L2 model ops) against these under hypothesis-driven
shape/value sweeps. They are intentionally written in the most obvious
formulation — no tiling, no tricks.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402


def minplus_ref(a, b):
    """Min-plus (tropical) matrix product: C[i,j] = min_k A[i,k] + B[k,j]."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def dist_ref(xi, xj):
    """Pairwise Euclidean distances between row sets."""
    diff = xi[:, None, :] - xj[None, :, :]
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1))


def fw_ref(g):
    """Floyd–Warshall via a lax scan over pivots."""

    def body(d, k):
        d = jnp.minimum(d, d[:, k][:, None] + d[k, :][None, :])
        return d, None

    out, _ = jax.lax.scan(body, g, jnp.arange(g.shape[0]))
    return out


def center_ref(block, mu_r, mu_c, grand):
    """Double-centering application with the classical-MDS -1/2 factor."""
    return -0.5 * (block - mu_r[:, None] - mu_c[None, :] + grand)


def gemm_ref(a, q):
    """Plain block product A·Q."""
    return a @ q


def gemmt_ref(a, q):
    """Transposed block product Aᵀ·Q."""
    return a.T @ q
