"""In-block Floyd–Warshall Pallas kernel (APSP Phase 1).

The whole b×b diagonal block lives in VMEM (128² f64 = 128 KiB) and the
pivot loop runs inside the kernel: each step loads pivot row k and pivot
column k and relaxes the full tile with a rank-1 min-plus update — the
sequential-k dependence is inherent to FW, but each step is a fully
vectorized (b, b) VPU op. Only one tile is resident, so on a real TPU the
pivot row/column broadcasts stay on-chip for the entire solve.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.experimental import pallas as pl  # noqa: E402


def _fw_kernel(g_ref, o_ref):
    o_ref[...] = g_ref[...]
    b = g_ref.shape[0]

    def body(k, _):
        d = o_ref[...]
        row = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=0)  # (1, b)
        col = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # (b, 1)
        o_ref[...] = jnp.minimum(d, col + row)
        return 0

    jax.lax.fori_loop(0, b, body, 0)


@jax.jit
def floyd_warshall(g):
    """All-pairs shortest paths within one square block, in-VMEM."""
    b, b2 = g.shape
    assert b == b2, "FW requires a square block"
    return pl.pallas_call(
        _fw_kernel,
        out_shape=jax.ShapeDtypeStruct((b, b), g.dtype),
        interpret=True,
    )(g)
