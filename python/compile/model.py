"""L2 — the JAX block-op compute graph.

Each function here is one unit of executor work in the Rust coordinator's
pipeline (paper Alg. 1), composed from the L1 Pallas kernels where the
paper offloads to BLAS/Numba, and plain jnp where XLA's native lowering is
already optimal (centering is a fused elementwise op; the power-iteration
block product is a native matmul the MXU/`dot` path handles directly).
`aot.py` lowers every function below to HLO text once at build time.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import fw as fw_kernel  # noqa: E402
from .kernels import minplus as minplus_kernel  # noqa: E402
from .kernels import sqdist as sqdist_kernel  # noqa: E402


def dist(xi, xj):
    """kNN stage: one distance block M^{(I,J)} (L1 sqdist kernel)."""
    return (sqdist_kernel.dist_block(xi, xj),)


def minplus(a, b):
    """APSP Phases 2/3: one min-plus block product (L1 kernel)."""
    return (minplus_kernel.minplus(a, b),)


def fw(g):
    """APSP Phase 1: in-block Floyd–Warshall (L1 kernel)."""
    return (fw_kernel.floyd_warshall(g),)


def center(block, mu_r, mu_c, grand):
    """Centering stage: a ← −½(a − μ_row − μ_col + μ̂), fused by XLA."""
    return (-0.5 * (block - mu_r[:, None] - mu_c[None, :] + grand),)


def gemm(a, q):
    """Power iteration: V_I contribution A^{(I,J)}·Q_J."""
    return (a @ q,)


def gemmt(a, q):
    """Power iteration, transposed contribution (A^{(I,J)})ᵀ·Q_I for the
    upper-triangular storage."""
    return (a.T @ q,)
