"""AOT lowering: JAX/Pallas block ops → HLO text + manifest.json.

Run once by ``make artifacts``. HLO *text* (not ``.serialize()``) is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which the Rust side's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Shapes are static in HLO, so every (op, shape) pair in the artifact matrix
below becomes one file. The Rust runtime is shape-polymorphic over these
static artifacts: a ragged call is padded up to the nearest artifact with
the op's neutral element and the result sliced back (see
``rust/src/runtime/mod.rs``). Each manifest entry therefore declares its
``pad`` policy — the fill value whose padding leaves the real corner of
the result exact — and the runtime refuses to load a manifest whose
declared policy disagrees with its own neutral-element table. Only shapes
beyond every artifact (block size above ``max(BLOCK_SIZES)``, point
dimensionality above ``max(DIST_DIMS)``) fall back to the native kernel,
and those fallbacks are counted, not silent.
"""

import argparse
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Block sizes the Rust coordinator uses by default (tests use 32/64; the
# examples/benches run 128). Keep this list short: each entry costs a
# lowering at build time and a compile at first use.
BLOCK_SIZES = (32, 64, 128)
# Ambient dimensionalities for the distance kernel: swiss roll / s-curve
# (3), the clusters benchmark (16), synthetic EMNIST (784).
DIST_DIMS = (3, 16, 784)
# gemm artifacts are lowered at this padded width; the runtime zero-pads
# Q's columns (exact for matmul) and slices the result.
DMAX = 8

F64 = jnp.float64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the Rust
    side unwraps a 1-tuple, matching the reference wiring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F64)


def artifact_matrix():
    """Yield (op, params, example-args) for every artifact to build."""
    for b in BLOCK_SIZES:
        yield "minplus", {"b": b}, (spec(b, b), spec(b, b))
        yield "fw", {"b": b}, (spec(b, b),)
        yield "center", {"b": b}, (spec(b, b), spec(b), spec(b), spec())
        # d=2 is the overwhelmingly common visualization case (§Perf:
        # avoids padding every power-iteration block product to DMAX).
        for d in (2, DMAX):
            yield "gemm", {"b": b, "d": d}, (spec(b, b), spec(b, d))
            yield "gemmt", {"b": b, "d": d}, (spec(b, b), spec(b, d))
        for dim in DIST_DIMS:
            yield "dist", {"b": b, "dim": dim}, (spec(b, dim), spec(b, dim))


FNS = {
    "minplus": model.minplus,
    "fw": model.fw,
    "center": model.center,
    "gemm": model.gemm,
    "gemmt": model.gemmt,
    "dist": model.dist,
}

# Neutral-element padding each op's artifacts tolerate (mirrored by the
# Rust runtime, which cross-checks at load time):
#   "+inf" — min-plus semiring annihilator: padded terms never win a min.
#   "zero" — additive identity: padded rows/cols/dims contribute nothing
#            to dots (gemm/gemmt/dist) or are sliced away (center).
PAD_POLICY = {
    "minplus": "+inf",
    "fw": "+inf",
    "center": "zero",
    "dist": "zero",
    "gemm": "zero",
    "gemmt": "zero",
}


def build(out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    ops = []
    for op, params, args in artifact_matrix():
        name = op + "".join(f"_{k}{v}" for k, v in sorted(params.items()))
        fname = f"{name}.hlo.txt"
        lowered = jax.jit(FNS[op]).lower(*args)
        text = to_hlo_text(lowered)
        (out_dir / fname).write_text(text)
        entry = {"op": op, "file": fname, "pad": PAD_POLICY[op]}
        entry.update(params)
        ops.append(entry)
        print(f"  {fname:<28} {len(text):>9} chars")
    manifest = {
        "version": 2,
        "dmax": DMAX,
        "max_b": max(BLOCK_SIZES),
        "pad_policy": PAD_POLICY,
        "ops": ops,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact directory")
    args = parser.parse_args()
    out = pathlib.Path(args.out)
    manifest = build(out)
    print(f"wrote {len(manifest['ops'])} artifacts + manifest.json to {out}")


if __name__ == "__main__":
    main()
