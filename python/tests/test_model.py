"""L2 model ops (the functions aot.py lowers) vs NumPy, plus an AOT
round-trip sanity check on the emitted HLO text."""

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

RNG = np.random.default_rng(1)


def jarr(*shape, lo=0.0, hi=5.0):
    return jnp.asarray(RNG.uniform(lo, hi, size=shape), dtype=jnp.float64)


class TestModelOps:
    def test_all_return_one_tuple(self):
        b = 8
        outs = [
            model.dist(jarr(b, 3), jarr(b, 3)),
            model.minplus(jarr(b, b), jarr(b, b)),
            model.fw(jarr(b, b)),
            model.center(jarr(b, b), jarr(b), jarr(b), jnp.float64(0.5)),
            model.gemm(jarr(b, b), jarr(b, 4)),
            model.gemmt(jarr(b, b), jarr(b, 4)),
        ]
        for out in outs:
            assert isinstance(out, tuple) and len(out) == 1
            assert out[0].dtype == jnp.float64

    def test_center_matches_numpy(self):
        blk = jarr(8, 8)
        mu_r, mu_c = jarr(8), jarr(8)
        grand = jnp.float64(1.25)
        (got,) = model.center(blk, mu_r, mu_c, grand)
        want = -0.5 * (np.asarray(blk) - np.asarray(mu_r)[:, None] - np.asarray(mu_c)[None, :] + 1.25)
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-12)

    def test_gemm_pair_consistent_with_transpose(self):
        a, q = jarr(8, 8), jarr(8, 3)
        (g1,) = model.gemm(a, q)
        (g2,) = model.gemmt(a, q)
        np.testing.assert_allclose(np.asarray(g2), np.asarray(a).T @ np.asarray(q), atol=1e-12)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(a) @ np.asarray(q), atol=1e-12)

    def test_dist_and_minplus_delegate_to_kernels(self):
        xi, xj = jarr(16, 3), jarr(16, 3)
        (d,) = model.dist(xi, xj)
        np.testing.assert_allclose(np.asarray(d), np.asarray(ref.dist_ref(xi, xj)), atol=1e-9)
        a, b = jarr(16, 16), jarr(16, 16)
        (mp,) = model.minplus(a, b)
        np.testing.assert_allclose(np.asarray(mp), np.asarray(ref.minplus_ref(a, b)), atol=0)


class TestAot:
    def test_artifact_matrix_covers_every_op(self):
        ops = {op for op, _, _ in aot.artifact_matrix()}
        assert ops == set(aot.FNS)

    def test_lowering_produces_parseable_hlo(self):
        # Lower the smallest minplus and verify HLO text structure.
        lowered = jax.jit(model.minplus).lower(aot.spec(32, 32), aot.spec(32, 32))
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f64" in text
        # return_tuple=True => the root computation returns a tuple.
        assert "(f64[32,32]" in text or "tuple" in text

    def test_build_writes_manifest(self, tmp_path, monkeypatch):
        # Restrict the matrix to one block size to keep the test fast.
        monkeypatch.setattr(aot, "BLOCK_SIZES", (32,))
        monkeypatch.setattr(aot, "DIST_DIMS", (3,))
        manifest = aot.build(tmp_path)
        assert (tmp_path / "manifest.json").exists()
        files = {e["file"] for e in manifest["ops"]}
        assert len(files) == len(manifest["ops"])  # unique names
        for e in manifest["ops"]:
            assert (tmp_path / e["file"]).exists()
            assert e["op"] in aot.FNS
        # minplus + fw + center + 2x(gemm, gemmt) + 1 dist dim.
        assert len(manifest["ops"]) == 8

    def test_executes_after_roundtrip(self):
        # Full fidelity check: lowered HLO text reloaded into an
        # XlaComputation and executed via the CPU client equals the ref.
        from jax._src.lib import xla_client as xc

        a = jarr(32, 32)
        b = jarr(32, 32)
        lowered = jax.jit(model.minplus).lower(a, b)
        text = aot.to_hlo_text(lowered)
        # Parse back and run through xla_client.
        comp = xc._xla.hlo_module_from_text(text) if hasattr(xc._xla, "hlo_module_from_text") else None
        if comp is None:
            pytest.skip("xla_client lacks hlo_module_from_text on this version")
        # Reaching here means the text parses; execution fidelity is
        # asserted end-to-end by the Rust runtime_equivalence tests.


class TestModelOpsSweeps:
    """Hypothesis sweeps over the L2 ops aot.py lowers (shapes + values)."""

    def test_center_shape_sweep(self):
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @settings(max_examples=15, deadline=None)
        @given(b=st.sampled_from([4, 16, 32, 128]), grand=st.floats(-5, 5))
        def prop(b, grand):
            blk = jarr(b, b)
            mu_r, mu_c = jarr(b), jarr(b)
            (got,) = model.center(blk, mu_r, mu_c, jnp.float64(grand))
            want = -0.5 * (
                np.asarray(blk)
                - np.asarray(mu_r)[:, None]
                - np.asarray(mu_c)[None, :]
                + grand
            )
            np.testing.assert_allclose(np.asarray(got), want, atol=1e-12)
            # Double-centering invariant: centering a centered block with
            # zero means and zero grand is -1/2 scaling.
            (again,) = model.center(got, jnp.zeros(b), jnp.zeros(b), jnp.float64(0.0))
            np.testing.assert_allclose(np.asarray(again), -0.5 * np.asarray(got), atol=1e-12)

        prop()

    def test_gemm_shape_sweep(self):
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @settings(max_examples=15, deadline=None)
        @given(b=st.sampled_from([4, 16, 64]), d=st.sampled_from([1, 2, 3, 8]))
        def prop(b, d):
            a, q = jarr(b, b, lo=-2, hi=2), jarr(b, d, lo=-1, hi=1)
            (g,) = model.gemm(a, q)
            (gt,) = model.gemmt(a, q)
            np.testing.assert_allclose(np.asarray(g), np.asarray(a) @ np.asarray(q), atol=1e-10)
            np.testing.assert_allclose(
                np.asarray(gt), np.asarray(a).T @ np.asarray(q), atol=1e-10
            )
            # Symmetric a => gemm == gemmt.
            s = (a + a.T) / 2
            (g1,) = model.gemm(s, q)
            (g2,) = model.gemmt(s, q)
            np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-10)

        prop()

    def test_fw_then_minplus_fixpoint(self):
        # After FW closes a block, min-plus squaring must not change it:
        # the L2 composition the APSP phases rely on.
        g = np.array(jarr(16, 16, lo=0.1, hi=4.0))
        np.fill_diagonal(g, 0.0)
        gj = jnp.asarray(g)
        (closed,) = model.fw(gj)
        (sq,) = model.minplus(closed, closed)
        np.testing.assert_allclose(np.asarray(sq), np.asarray(closed), atol=1e-9)
