"""L1 Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes and values (including +inf edge weights, the
empty-edge marker throughout the APSP stage); every property is also
pinned by at least one deterministic case.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import fw, minplus, ref, sqdist

RNG = np.random.default_rng(0)


def rand(*shape, lo=0.0, hi=10.0, inf_frac=0.0):
    x = RNG.uniform(lo, hi, size=shape)
    if inf_frac > 0.0:
        mask = RNG.uniform(size=shape) < inf_frac
        x = np.where(mask, np.inf, x)
    return jnp.asarray(x, dtype=jnp.float64)


# ---------------------------------------------------------------- minplus
class TestMinplus:
    def test_known_values(self):
        a = jnp.array([[1.0, 5.0], [2.0, 0.0]], dtype=jnp.float64)
        b = jnp.array([[0.0, 3.0], [1.0, 1.0]], dtype=jnp.float64)
        got = minplus.minplus(a, b, bm=2, bn=2, bk=2)
        want = ref.minplus_ref(a, b)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 32]),
        k=st.sampled_from([8, 16, 32]),
        n=st.sampled_from([8, 16, 32]),
        inf_frac=st.sampled_from([0.0, 0.2]),
    )
    def test_matches_ref(self, m, k, n, inf_frac):
        a = rand(m, k, inf_frac=inf_frac)
        b = rand(k, n, inf_frac=inf_frac)
        got = minplus.minplus(a, b)
        want = ref.minplus_ref(a, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)

    def test_tiled_equals_untiled(self):
        a = rand(32, 32)
        b = rand(32, 32)
        t1 = minplus.minplus(a, b, bm=8, bn=8, bk=8)
        t2 = minplus.minplus(a, b, bm=32, bn=32, bk=4)
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))

    def test_identity(self):
        a = rand(16, 16)
        eye = jnp.where(jnp.eye(16, dtype=bool), 0.0, jnp.inf).astype(jnp.float64)
        got = minplus.minplus(a, eye)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(a))

    def test_all_inf_rows(self):
        a = jnp.full((8, 8), jnp.inf, dtype=jnp.float64)
        b = rand(8, 8)
        got = np.asarray(minplus.minplus(a, b))
        assert np.isinf(got).all()

    def test_rejects_non_dividing_tiles(self):
        with pytest.raises(AssertionError):
            minplus.minplus(rand(10, 10), rand(10, 10), bm=3)


# ---------------------------------------------------------------- sqdist
class TestSqdist:
    def test_known_values(self):
        xi = jnp.array([[0.0, 0.0], [3.0, 4.0]], dtype=jnp.float64)
        got = np.asarray(sqdist.dist_block(xi, xi))
        assert got[0, 1] == pytest.approx(5.0, abs=1e-12)
        assert got[0, 0] == pytest.approx(0.0, abs=1e-9)

    @settings(max_examples=20, deadline=None)
    @given(
        bi=st.sampled_from([4, 16, 33]),
        bj=st.sampled_from([4, 16, 31]),
        dim=st.sampled_from([1, 3, 784]),
    )
    def test_matches_ref(self, bi, bj, dim):
        xi = rand(bi, dim, lo=-5.0, hi=5.0)
        xj = rand(bj, dim, lo=-5.0, hi=5.0)
        got = sqdist.dist_block(xi, xj)
        want = ref.dist_ref(xi, xj)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-9)

    def test_cancellation_guard(self):
        # Nearly identical far-from-origin points must not NaN via sqrt(-ε).
        xi = jnp.full((2, 3), 1e8, dtype=jnp.float64)
        xi = xi.at[1, 0].add(1e-4)
        got = np.asarray(sqdist.dist_block(xi, xi))
        assert np.isfinite(got).all()
        assert (got >= 0).all()

    def test_symmetry(self):
        x = rand(12, 5, lo=-1, hi=1)
        d = np.asarray(sqdist.dist_block(x, x))
        np.testing.assert_allclose(d, d.T, atol=1e-12)


# ---------------------------------------------------------------- fw
class TestFloydWarshall:
    def test_line_graph(self):
        inf = jnp.inf
        g = jnp.array(
            [[0.0, 1.0, inf], [1.0, 0.0, 1.0], [inf, 1.0, 0.0]], dtype=jnp.float64
        )
        got = np.asarray(fw.floyd_warshall(g))
        assert got[0, 2] == pytest.approx(2.0)
        assert got[2, 0] == pytest.approx(2.0)

    @settings(max_examples=15, deadline=None)
    @given(b=st.sampled_from([4, 8, 16, 32]), p=st.sampled_from([0.2, 0.5]))
    def test_matches_ref(self, b, p):
        g = np.asarray(rand(b, b, lo=0.1, hi=5.0))
        mask = RNG.uniform(size=(b, b)) > p
        g = np.where(mask, np.inf, g)
        np.fill_diagonal(g, 0.0)
        g = jnp.asarray(g)
        got = fw.floyd_warshall(g)
        want = ref.fw_ref(g)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)

    def test_idempotent(self):
        g = rand(16, 16, lo=0.1, hi=5.0)
        g = g.at[jnp.diag_indices(16)].set(0.0)
        once = fw.floyd_warshall(g)
        twice = fw.floyd_warshall(once)
        # Paths re-derived in a different association order may differ in
        # the last ulp; idempotency holds to fp precision.
        np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-12)

    def test_triangle_inequality(self):
        g = rand(12, 12, lo=0.1, hi=5.0, inf_frac=0.5)
        g = g.at[jnp.diag_indices(12)].set(0.0)
        d = np.asarray(fw.floyd_warshall(g))
        for i in range(12):
            for j in range(12):
                for k in range(12):
                    if np.isfinite(d[i, k]) and np.isfinite(d[k, j]):
                        assert d[i, j] <= d[i, k] + d[k, j] + 1e-9
