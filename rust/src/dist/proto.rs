//! Length-prefixed binary frame protocol for the block-shuffle transport.
//!
//! Same framing discipline as `serve/http.rs`: a pure-buffer
//! [`try_parse`] that never blocks — `Ok(None)` means "need more bytes",
//! `Err` means the peer spoke garbage (with enough context to say how) —
//! plus hard size caps so a malformed length prefix cannot balloon the
//! read buffer. On top of that, every frame carries an FNV-1a-64 checksum
//! over its variable-length content, because unlike the HTTP server this
//! protocol moves gigabytes of matrix payload whose silent corruption
//! would quietly break the bit-determinism contract.
//!
//! Wire layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//!      0     4  magic "ISPD"
//!      4     1  protocol version (1)
//!      5     1  frame kind (FrameKind)
//!      6     2  stage name length in bytes (≤ 256)
//!      8     4  task index
//!     12     4  attempt number
//!     16     8  payload length in bytes (≤ 512 MiB)
//!     24     8  FNV-1a-64 checksum over stage-name bytes ++ payload
//!     32     …  stage name (UTF-8), then payload
//! ```
//!
//! The header is fixed at 32 bytes so a reader can always pull it in one
//! shot and then knows the exact frame size; stage/task/attempt ride in
//! the header (not the payload) so the retry loop can route responses
//! without decoding payloads.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use crate::data::io::Fnv1a64;

/// First bytes of every frame.
pub const MAGIC: [u8; 4] = *b"ISPD";
/// Protocol version; a mismatch is rejected up front rather than
/// misparsed downstream.
pub const VERSION: u8 = 1;
/// Fixed header size — see the module-level wire layout.
pub const HEADER_BYTES: usize = 32;
/// Cap on the stage-name field.
pub const MAX_STAGE_BYTES: usize = 256;
/// Cap on a single frame's payload. Generous (a 512 MiB panel is a
/// ~90k-point block-row) but finite, so a corrupt length prefix fails
/// fast instead of OOMing the reader.
pub const MAX_PAYLOAD_BYTES: u64 = 512 * (1 << 20);

/// How long a blocked read waits before re-checking its stop flag and
/// deadline. Mirrors the poll discipline in `serve/mod.rs`.
const READ_SLICE: Duration = Duration::from_millis(100);

/// What a frame means. The discriminants are the on-wire byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Driver → worker: first frame on a connection.
    Hello = 0,
    /// Worker → driver: handshake reply (payload: u64 worker cores).
    HelloAck = 1,
    /// Driver → worker: named blob shared by every task of the coming
    /// stage(s) (payload: u16 name length ++ name ++ blob).
    Broadcast = 2,
    /// Driver → worker: execute one stage task (payload: `TaskSpec`).
    Task = 3,
    /// Worker → driver: task result (payload is task-specific).
    TaskOk = 4,
    /// Worker → driver: task or broadcast failed (payload: UTF-8 message).
    TaskErr = 5,
    /// Driver → worker: exit after acknowledging.
    Shutdown = 6,
    /// Worker → driver: broadcast/shutdown acknowledged.
    Ack = 7,
}

impl FrameKind {
    fn from_u8(v: u8) -> Option<FrameKind> {
        use FrameKind::*;
        Some(match v {
            0 => Hello,
            1 => HelloAck,
            2 => Broadcast,
            3 => Task,
            4 => TaskOk,
            5 => TaskErr,
            6 => Shutdown,
            7 => Ack,
            _ => return None,
        })
    }

    /// Human name for error messages.
    pub fn name(self) -> &'static str {
        match self {
            FrameKind::Hello => "hello",
            FrameKind::HelloAck => "hello-ack",
            FrameKind::Broadcast => "broadcast",
            FrameKind::Task => "task",
            FrameKind::TaskOk => "task-ok",
            FrameKind::TaskErr => "task-err",
            FrameKind::Shutdown => "shutdown",
            FrameKind::Ack => "ack",
        }
    }
}

/// One parsed frame. `stage`/`task`/`attempt` are routing metadata for
/// task traffic; control frames leave them at their defaults.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub stage: String,
    pub task: u32,
    pub attempt: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    /// A metadata-free control frame (hello, ack, shutdown).
    pub fn control(kind: FrameKind) -> Frame {
        Frame { kind, stage: String::new(), task: 0, attempt: 0, payload: Vec::new() }
    }

    /// A control frame carrying a payload (handshake info, broadcasts).
    pub fn with_payload(kind: FrameKind, payload: Vec<u8>) -> Frame {
        Frame { kind, stage: String::new(), task: 0, attempt: 0, payload }
    }

    /// Encoded size on the wire.
    pub fn wire_size(&self) -> usize {
        HEADER_BYTES + self.stage.len() + self.payload.len()
    }
}

/// Serialize a frame. Panics (debug assert) on frames that exceed the
/// protocol caps — callers own the caps because they own the chunking.
pub fn encode(f: &Frame) -> Vec<u8> {
    let stage = f.stage.as_bytes();
    debug_assert!(stage.len() <= MAX_STAGE_BYTES, "stage name over protocol cap");
    debug_assert!(f.payload.len() as u64 <= MAX_PAYLOAD_BYTES, "payload over protocol cap");
    let mut h = Fnv1a64::new();
    h.update(stage);
    h.update(&f.payload);
    let mut out = Vec::with_capacity(f.wire_size());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(f.kind as u8);
    out.extend_from_slice(&(stage.len() as u16).to_le_bytes());
    out.extend_from_slice(&f.task.to_le_bytes());
    out.extend_from_slice(&f.attempt.to_le_bytes());
    out.extend_from_slice(&(f.payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&h.finish().to_le_bytes());
    out.extend_from_slice(stage);
    out.extend_from_slice(&f.payload);
    out
}

fn le_u16(buf: &[u8], at: usize) -> u16 {
    u16::from_le_bytes(buf[at..at + 2].try_into().unwrap())
}

fn le_u32(buf: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

fn le_u64(buf: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
}

/// Try to parse one frame from the front of `buf`.
///
/// - `Ok(None)` — not enough bytes yet; read more and call again.
/// - `Ok(Some((frame, used)))` — one frame parsed; drain `used` bytes.
/// - `Err(msg)` — the bytes can never become a valid frame (bad magic,
///   over-cap lengths, checksum mismatch); the connection is unusable.
///
/// Pure function of the buffer — no IO, trivially unit-testable, the same
/// discipline as `serve::http::try_parse`.
pub fn try_parse(buf: &[u8]) -> Result<Option<(Frame, usize)>, String> {
    if buf.len() < HEADER_BYTES {
        return Ok(None);
    }
    if buf[..4] != MAGIC {
        return Err(format!("dist frame: bad magic {:02x?} (want \"ISPD\")", &buf[..4]));
    }
    if buf[4] != VERSION {
        return Err(format!(
            "dist frame: protocol version {} (this build speaks {VERSION})",
            buf[4]
        ));
    }
    let kind = FrameKind::from_u8(buf[5])
        .ok_or_else(|| format!("dist frame: unknown frame kind {}", buf[5]))?;
    let stage_len = le_u16(buf, 6) as usize;
    if stage_len > MAX_STAGE_BYTES {
        return Err(format!(
            "dist frame: stage name of {stage_len} bytes exceeds the {MAX_STAGE_BYTES}-byte cap"
        ));
    }
    let task = le_u32(buf, 8);
    let attempt = le_u32(buf, 12);
    let payload_len = le_u64(buf, 16);
    if payload_len > MAX_PAYLOAD_BYTES {
        return Err(format!(
            "dist frame: payload of {payload_len} bytes exceeds the {MAX_PAYLOAD_BYTES}-byte cap \
             ({} frame, stage task {task})",
            kind.name()
        ));
    }
    let total = HEADER_BYTES + stage_len + payload_len as usize;
    if buf.len() < total {
        return Ok(None);
    }
    let want = le_u64(buf, 24);
    let got = crate::data::io::fnv1a64(&buf[HEADER_BYTES..total]);
    if got != want {
        return Err(format!(
            "dist frame: checksum mismatch on {} frame (task {task}, attempt {attempt}): \
             computed {got:016x}, header says {want:016x}",
            kind.name()
        ));
    }
    let stage = std::str::from_utf8(&buf[HEADER_BYTES..HEADER_BYTES + stage_len])
        .map_err(|_| "dist frame: stage name is not UTF-8".to_string())?
        .to_string();
    let payload = buf[HEADER_BYTES + stage_len..total].to_vec();
    Ok(Some((Frame { kind, stage, task, attempt, payload }, total)))
}

/// Why a blocking read/write gave up. Transport failures are *data*, not
/// panics: the driver's retry loop matches on these to decide between
/// marking a worker dead (`ConnectionLost`/`TimedOut`) and failing the
/// run (`Malformed` — a protocol bug retrying cannot fix).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// Peer closed the connection or the socket errored.
    ConnectionLost(String),
    /// No complete frame arrived before the deadline.
    TimedOut(String),
    /// The peer's bytes can never parse as a frame.
    Malformed(String),
    /// The local stop flag was raised while waiting.
    Stopped,
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::ConnectionLost(m) => write!(f, "connection lost: {m}"),
            TransportError::TimedOut(m) => write!(f, "timed out: {m}"),
            TransportError::Malformed(m) => write!(f, "malformed frame: {m}"),
            TransportError::Stopped => write!(f, "stopped"),
        }
    }
}

/// Incremental frame reader over a blocking stream. Keeps its own buffer
/// so back-to-back frames pipelined by the peer are not lost between
/// calls — one `FrameReader` per connection, for its whole life.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Block until one full frame arrives, the `deadline` passes, `stop`
    /// is raised, or the connection dies. Reads in `READ_SLICE` slices
    /// so stop/deadline are observed promptly even when the peer is
    /// silent.
    pub fn read_frame(
        &mut self,
        stream: &mut TcpStream,
        deadline: Option<Instant>,
        stop: Option<&AtomicBool>,
    ) -> Result<Frame, TransportError> {
        if stream.set_read_timeout(Some(READ_SLICE)).is_err() {
            return Err(TransportError::ConnectionLost("set_read_timeout failed".into()));
        }
        let mut chunk = [0u8; 64 * 1024];
        loop {
            if let Some((frame, used)) = try_parse(&self.buf).map_err(TransportError::Malformed)? {
                self.buf.drain(..used);
                return Ok(frame);
            }
            if let Some(s) = stop {
                if s.load(Ordering::SeqCst) {
                    return Err(TransportError::Stopped);
                }
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return Err(TransportError::TimedOut(format!(
                        "no complete frame ({} bytes buffered)",
                        self.buf.len()
                    )));
                }
            }
            match stream.read(&mut chunk) {
                Ok(0) => {
                    return Err(TransportError::ConnectionLost(
                        "peer closed the connection".into(),
                    ))
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(TransportError::ConnectionLost(e.to_string())),
            }
        }
    }
}

/// Write one frame; returns its wire size for byte accounting.
pub fn write_frame(stream: &mut TcpStream, frame: &Frame) -> Result<usize, TransportError> {
    let bytes = encode(frame);
    stream.write_all(&bytes).map_err(|e| {
        TransportError::ConnectionLost(format!("writing {} frame: {e}", frame.kind.name()))
    })?;
    Ok(bytes.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Frame {
        Frame {
            kind: FrameKind::TaskOk,
            stage: "geo:dijkstra".into(),
            task: 3,
            attempt: 1,
            payload: vec![7, 8, 9, 250, 0, 1],
        }
    }

    #[test]
    fn roundtrip_preserves_every_field() {
        let f = sample();
        let bytes = encode(&f);
        assert_eq!(bytes.len(), f.wire_size());
        let (parsed, used) = try_parse(&bytes).unwrap().unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(parsed, f);
    }

    #[test]
    fn control_frame_roundtrips_with_empty_metadata() {
        let bytes = encode(&Frame::control(FrameKind::Shutdown));
        let (parsed, used) = try_parse(&bytes).unwrap().unwrap();
        assert_eq!(used, HEADER_BYTES);
        assert_eq!(parsed.kind, FrameKind::Shutdown);
        assert!(parsed.stage.is_empty() && parsed.payload.is_empty());
    }

    #[test]
    fn truncated_frames_ask_for_more_bytes() {
        let bytes = encode(&sample());
        for cut in [0, 1, HEADER_BYTES - 1, HEADER_BYTES, bytes.len() - 1] {
            assert_eq!(try_parse(&bytes[..cut]).unwrap(), None, "cut at {cut}");
        }
    }

    #[test]
    fn pipelined_frames_parse_one_at_a_time() {
        let a = encode(&Frame::control(FrameKind::Hello));
        let b = encode(&sample());
        let mut buf = a.clone();
        buf.extend_from_slice(&b);
        let (first, used) = try_parse(&buf).unwrap().unwrap();
        assert_eq!(first.kind, FrameKind::Hello);
        let (second, used2) = try_parse(&buf[used..]).unwrap().unwrap();
        assert_eq!(second, sample());
        assert_eq!(used + used2, buf.len());
    }

    #[test]
    fn bad_magic_and_version_are_rejected_with_context() {
        let mut bytes = encode(&sample());
        bytes[0] = b'X';
        let err = try_parse(&bytes).unwrap_err();
        assert!(err.contains("bad magic"), "{err}");
        let mut bytes = encode(&sample());
        bytes[4] = 9;
        let err = try_parse(&bytes).unwrap_err();
        assert!(err.contains("version 9"), "{err}");
    }

    #[test]
    fn oversized_lengths_fail_fast_not_oom() {
        let mut bytes = encode(&sample());
        bytes[6..8].copy_from_slice(&(MAX_STAGE_BYTES as u16 + 1).to_le_bytes());
        let err = try_parse(&bytes).unwrap_err();
        assert!(err.contains("stage name"), "{err}");
        let mut bytes = encode(&sample());
        bytes[16..24].copy_from_slice(&(MAX_PAYLOAD_BYTES + 1).to_le_bytes());
        let err = try_parse(&bytes).unwrap_err();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn corrupt_payload_trips_the_checksum() {
        let mut bytes = encode(&sample());
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40; // single bit-flip in the payload
        let err = try_parse(&bytes).unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("task 3"), "{err}");
    }

    #[test]
    fn unknown_kind_is_rejected() {
        let mut bytes = encode(&sample());
        bytes[5] = 200;
        let err = try_parse(&bytes).unwrap_err();
        assert!(err.contains("unknown frame kind 200"), "{err}");
    }
}
