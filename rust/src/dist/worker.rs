//! The `isospark worker` runtime: a TCP server that executes stage tasks
//! shipped by a driver over the [`super::proto`] frame protocol.
//!
//! A worker is state-light on purpose: it holds at most one broadcast
//! geodesic job (graph + block geometry) and recomputes everything else
//! per task, so a worker that dies loses only in-flight work — the
//! driver's retry loop re-runs those tasks elsewhere and, because every
//! task is a pure function of the broadcast state, gets bit-identical
//! panels back. Task kernels run through the same code path as the
//! single-process engine (`dijkstra::multi_source` → the
//! `engine/executor` task pool), which is the whole determinism argument:
//! same inputs, same code, same bits.
//!
//! Threading mirrors `serve/mod.rs`: an accept loop checks a stop flag
//! between connections, reads poll in short slices so shutdown is prompt,
//! and [`WorkerHandle`] unblocks a parked `accept` with a self-connect.

use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::dist::proto::{self, Frame, FrameKind, FrameReader};
use crate::dist::task::{decode_geo_job, encode_panel_result, GeoJob, TaskSpec, GEO_JOB};
use crate::graph::{dijkstra, CsrGraph};
use crate::util::Stopwatch;

/// How long a worker waits for a slow driver to accept reply bytes.
const WRITE_LIMIT: Duration = Duration::from_secs(30);

/// Tuning for a worker process.
#[derive(Clone, Debug, Default)]
pub struct WorkerOptions {
    /// OS threads for task kernels (0 = all cores), resolved by the same
    /// `engine/executor` rule as every other pool in the crate. Thread
    /// count never changes task *values* — only wall-clock.
    pub threads: usize,
    /// Test hook (`--die-after-tasks`): execute this many task frames,
    /// then drop every connection and stop accepting without replying — a
    /// deterministic stand-in for `kill -9` mid-stage, used by the
    /// worker-loss recovery tests and nothing else.
    pub die_after_tasks: Option<u64>,
}

struct WorkerState {
    threads: usize,
    stop: AtomicBool,
    /// Countdown for `die_after_tasks`; `None` = immortal.
    die_countdown: Option<AtomicU64>,
    /// The broadcast geodesic job, shared across connections so a driver
    /// reconnect (or a second run) can rebroadcast or reuse.
    job: Mutex<Option<Arc<GeoJobState>>>,
}

/// A decoded broadcast job plus the CSR graph rebuilt from it — built
/// once per broadcast, shared by every task against it.
struct GeoJobState {
    n: usize,
    block: usize,
    csr: CsrGraph,
}

/// An in-process worker (tests, benches): the same server loop as the
/// standalone `isospark worker` process, on a background thread.
/// Dropping the handle stops and joins the worker.
pub struct WorkerHandle {
    addr: SocketAddr,
    state: Arc<WorkerState>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl WorkerHandle {
    /// The bound address, e.g. to pass as `--workers`.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// Stop accepting, wake a parked accept, and join the server thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // Unblock a parked accept() the same way serve/mod.rs does.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(500));
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn new_state(opts: &WorkerOptions) -> Arc<WorkerState> {
    Arc::new(WorkerState {
        threads: opts.threads,
        stop: AtomicBool::new(false),
        die_countdown: opts.die_after_tasks.map(AtomicU64::new),
        job: Mutex::new(None),
    })
}

/// Spawn an in-process worker on `listen` (use port 0 for an ephemeral
/// port; the bound address is on the returned handle).
pub fn spawn(listen: &str, opts: WorkerOptions) -> Result<WorkerHandle> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("worker: bind {listen}"))?;
    let addr = listener.local_addr()?;
    let state = new_state(&opts);
    let thread_state = Arc::clone(&state);
    let thread = std::thread::Builder::new()
        .name("isospark-worker".into())
        .spawn(move || accept_loop(listener, &thread_state))
        .context("worker: spawn accept thread")?;
    Ok(WorkerHandle { addr, state, thread: Some(thread) })
}

/// Run a worker on the current thread until killed (the `isospark
/// worker` subcommand). Prints the bound address and optionally writes
/// the port to `port_file` so scripts can use ephemeral ports — the same
/// contract as `isospark serve`.
pub fn run_blocking(listen: &str, opts: WorkerOptions, port_file: Option<&str>) -> Result<()> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("worker: bind {listen}"))?;
    let addr = listener.local_addr()?;
    let threads = crate::engine::executor::resolve_workers(opts.threads);
    println!("isospark worker listening on {addr} ({threads} threads)");
    if let Some(path) = port_file {
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path}"))?;
        writeln!(f, "{}", addr.port())?;
    }
    let state = new_state(&opts);
    accept_loop(listener, &state);
    Ok(())
}

/// Serve connections one at a time until the stop flag is raised. A
/// driver holds one connection for a whole run, so serial service is the
/// natural discipline; a second driver simply queues.
fn accept_loop(listener: TcpListener, state: &Arc<WorkerState>) {
    for conn in listener.incoming() {
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        serve_conn(state, stream);
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
    }
}

/// Frame loop for one driver connection. Returning drops the stream —
/// the driver sees a closed connection and treats this worker as lost.
fn serve_conn(state: &Arc<WorkerState>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(WRITE_LIMIT));
    let mut reader = FrameReader::new();
    loop {
        // No deadline: a healthy driver may think for a long time between
        // stages. The stop flag still bounds shutdown latency.
        let frame = match reader.read_frame(&mut stream, None, Some(&state.stop)) {
            Ok(f) => f,
            Err(_) => return, // driver gone, garbage, or stopping
        };
        let reply = match frame.kind {
            FrameKind::Hello => Frame::with_payload(
                FrameKind::HelloAck,
                (crate::engine::executor::resolve_workers(state.threads) as u64)
                    .to_le_bytes()
                    .to_vec(),
            ),
            FrameKind::Broadcast => match install_broadcast(state, &frame.payload) {
                Ok(()) => Frame::control(FrameKind::Ack),
                Err(msg) => Frame::with_payload(FrameKind::TaskErr, msg.into_bytes()),
            },
            FrameKind::Task => {
                if dies_now(state) {
                    // Simulated crash: no reply, connection dropped,
                    // no further accepts.
                    state.stop.store(true, Ordering::SeqCst);
                    return;
                }
                match run_task(state, &frame) {
                    Ok(payload) => Frame {
                        kind: FrameKind::TaskOk,
                        stage: frame.stage.clone(),
                        task: frame.task,
                        attempt: frame.attempt,
                        payload,
                    },
                    Err(msg) => Frame {
                        kind: FrameKind::TaskErr,
                        stage: frame.stage.clone(),
                        task: frame.task,
                        attempt: frame.attempt,
                        payload: msg.into_bytes(),
                    },
                }
            }
            FrameKind::Shutdown => {
                let _ = proto::write_frame(&mut stream, &Frame::control(FrameKind::Ack));
                state.stop.store(true, Ordering::SeqCst);
                return;
            }
            // Driver-bound kinds arriving at a worker: protocol confusion.
            other => Frame::with_payload(
                FrameKind::TaskErr,
                format!("worker: unexpected {} frame", other.name()).into_bytes(),
            ),
        };
        if proto::write_frame(&mut stream, &reply).is_err() {
            return;
        }
    }
}

/// `die_after_tasks` bookkeeping: `false` while the countdown lasts,
/// `true` on the task that should kill the worker. Atomic because the
/// countdown must survive driver reconnects.
fn dies_now(state: &WorkerState) -> bool {
    let Some(rem) = &state.die_countdown else { return false };
    rem.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1)).is_err()
}

/// Decode a `Broadcast` payload (u16 name length ++ name ++ blob) and
/// install the named state.
fn install_broadcast(state: &WorkerState, payload: &[u8]) -> Result<(), String> {
    if payload.len() < 2 {
        return Err("broadcast: payload too short for name length".into());
    }
    let name_len = u16::from_le_bytes(payload[..2].try_into().unwrap()) as usize;
    if payload.len() < 2 + name_len {
        return Err(format!("broadcast: truncated name (want {name_len} bytes)"));
    }
    let name = std::str::from_utf8(&payload[2..2 + name_len])
        .map_err(|_| "broadcast: name is not UTF-8".to_string())?;
    let blob = &payload[2 + name_len..];
    match name {
        GEO_JOB => {
            let GeoJob { n, block, lists } = decode_geo_job(blob)?;
            let csr = CsrGraph::from_knn_lists(&lists)
                .map_err(|e| format!("broadcast {GEO_JOB}: CSR construction: {e:#}"))?;
            *state.job.lock().unwrap() = Some(Arc::new(GeoJobState { n, block, csr }));
            Ok(())
        }
        other => Err(format!("broadcast: unknown name {other:?}")),
    }
}

/// Execute one task frame; the returned bytes become the `TaskOk`
/// payload.
fn run_task(state: &WorkerState, frame: &Frame) -> Result<Vec<u8>, String> {
    let spec = TaskSpec::decode(&frame.payload)?;
    match spec {
        TaskSpec::GeodesicPanel { block } => {
            let job = state
                .job
                .lock()
                .unwrap()
                .clone()
                .ok_or_else(|| format!("no {GEO_JOB} broadcast received before task"))?;
            let q = crate::coordinator::num_blocks(job.n, job.block);
            let i = block as usize;
            if i >= q {
                return Err(format!("panel block {block} out of range (q = {q})"));
            }
            let (rs, re) = crate::coordinator::block_range(job.n, job.block, i);
            let sources: Vec<usize> = (rs..re).collect();
            let sw = Stopwatch::start();
            // The exact kernel the single-process path runs — this line
            // is the determinism argument, not just an implementation.
            let mut panel = dijkstra::multi_source(&job.csr, &sources, state.threads);
            crate::coordinator::panels::square_panel(&mut panel);
            Ok(encode_panel_result(sw.secs(), &panel))
        }
    }
}
