//! Driver-side view of a real worker fleet: connect, broadcast, scatter
//! tasks, gather results, and survive worker death.
//!
//! [`RemoteCluster`] is the process-boundary sibling of
//! `engine/executor.rs`: the same stage/task/attempt model, the same
//! deterministic fault schedule, but tasks execute in other OS processes
//! reached over the [`super::proto`] transport. The retry loop composes
//! with the PR 7 machinery in layers:
//!
//! - **Injected faults** (the `FaultPlan`) are decided *on the driver*
//!   before dispatch, at the same `(stage, task, attempt)` coordinates
//!   the in-process executor uses — an injected failure consumes an
//!   attempt without ever touching the network, so chaos runs exercise
//!   the retry path identically in both worlds.
//! - **Transport failures** (connection lost, response timeout) are typed
//!   [`TransportError`] values, never panics: the worker is marked dead,
//!   its in-flight tasks are requeued at `attempt + 1`, and the shared
//!   `ResilienceStats` can never be poisoned because no lock is ever held
//!   across a failure edge — each round's worker threads own their
//!   connection exclusively and report outcomes by value.
//! - **Exhaustion** (a task out of attempts, or every worker dead)
//!   propagates as an `anyhow` error carrying stage/task/attempt context,
//!   exactly like the in-process executor's exhaustion path.
//!
//! Determinism across process counts: task *values* are pure functions of
//! the broadcast state, placement only decides *where* a task runs, and
//! results are gathered by task index — so worker count, placement, and
//! retries change wall-clock and byte counts, never a single output bit.
//! Placement itself reuses the engine's [`Partitioner`] machinery (a
//! [`HashPartitioner`] over task ids folded onto the live workers), which
//! keeps it deterministic for a fixed live set without ever mattering for
//! correctness.

use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::dist::proto::{self, Frame, FrameKind, FrameReader, TransportError};
use crate::dist::task::TaskSpec;
use crate::engine::fault::{backoff_ms, Inject, TaskPolicy};
use crate::engine::{BlockId, HashPartitioner, Partitioner};
use crate::linalg::Matrix;
use crate::util::Stopwatch;

/// How long the driver waits for a slow worker to accept request bytes.
const WRITE_LIMIT: Duration = Duration::from_secs(30);

/// Connection parameters for a worker fleet, plumbed from the `[dist]`
/// config section / `--workers` flag.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// Worker addresses (`host:port`).
    pub workers: Vec<String>,
    /// Per-response deadline, seconds. A worker that holds a task longer
    /// is treated as dead and its tasks retried elsewhere.
    pub task_timeout_secs: f64,
    /// Connect + handshake deadline per worker, seconds.
    pub connect_timeout_secs: f64,
    /// Attempt ceiling per task when no fault policy is installed (with
    /// one, the policy's `max_attempts` governs both fault kinds).
    pub max_attempts: usize,
}

/// One worker connection. The `FrameReader` travels with the stream —
/// its buffer may hold the front of a pipelined next frame.
struct Conn {
    stream: TcpStream,
    reader: FrameReader,
}

/// A worker slot. `conn: None` means the worker was declared dead; it is
/// never revived within a run (a rejoining worker would recompute the
/// same bits anyway, but the bookkeeping is simpler and the tests
/// stricter this way).
struct WorkerLink {
    addr: String,
    conn: Mutex<Option<Conn>>,
}

/// Measured ground truth of the distributed stage(s), printed by the run
/// report next to the virtual-clock projection.
#[derive(Default)]
struct DistStats {
    tasks: AtomicU64,
    retries: AtomicU64,
    worker_losses: AtomicU64,
    bytes_tx: AtomicU64,
    bytes_rx: AtomicU64,
    wall_us: AtomicU64,
    virtual_us: AtomicU64,
}

/// Snapshot of the driver's distribution counters for reports.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DistReport {
    /// Workers the driver connected to at startup.
    pub workers: usize,
    /// Workers declared dead during the run.
    pub workers_lost: u64,
    /// Stage tasks dispatched (unique tasks, not attempts).
    pub tasks: u64,
    /// Tasks requeued after a worker loss or timeout.
    pub retries: u64,
    /// Bytes written to workers (broadcasts + task frames).
    pub bytes_sent: u64,
    /// Bytes read back (acks + results).
    pub bytes_received: u64,
    /// Measured driver wall-clock across distributed stages, seconds.
    pub wall_secs: f64,
    /// Virtual-clock projection of the same stages, seconds — the model
    /// this measurement grounds.
    pub virtual_secs: f64,
}

/// What one task attempt came back as. `Lost` marks the worker dead;
/// `Failed` is a worker-reported error that a retry elsewhere cannot fix.
enum TaskOutcome {
    Done(f64, Matrix),
    Failed(String),
    Lost(String),
}

/// A connected fleet of `isospark worker` processes.
pub struct RemoteCluster {
    links: Vec<WorkerLink>,
    task_timeout: Duration,
    max_attempts: usize,
    stats: DistStats,
}

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .with_context(|| format!("dist: resolve worker address {addr}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("dist: {addr} resolved to no address"))
}

impl RemoteCluster {
    /// Connect and handshake with every configured worker. Startup is
    /// strict — a worker that cannot be reached *now* is a config error,
    /// not a fault to tolerate.
    pub fn connect(cfg: &DistConfig) -> Result<RemoteCluster> {
        ensure!(!cfg.workers.is_empty(), "dist: no worker addresses configured");
        let connect_timeout = Duration::from_secs_f64(cfg.connect_timeout_secs.max(0.1));
        let mut links = Vec::with_capacity(cfg.workers.len());
        for addr in &cfg.workers {
            let sa = resolve(addr)?;
            let mut stream = TcpStream::connect_timeout(&sa, connect_timeout)
                .with_context(|| format!("dist: connect to worker {addr}"))?;
            let _ = stream.set_nodelay(true);
            let _ = stream.set_write_timeout(Some(WRITE_LIMIT));
            proto::write_frame(&mut stream, &Frame::control(FrameKind::Hello))
                .map_err(|e| anyhow::anyhow!("dist: hello to worker {addr}: {e}"))?;
            let mut reader = FrameReader::new();
            let ack = reader
                .read_frame(&mut stream, Some(Instant::now() + connect_timeout), None)
                .map_err(|e| anyhow::anyhow!("dist: handshake with worker {addr}: {e}"))?;
            ensure!(
                ack.kind == FrameKind::HelloAck,
                "dist: worker {addr} answered hello with a {} frame",
                ack.kind.name()
            );
            links.push(WorkerLink {
                addr: addr.clone(),
                conn: Mutex::new(Some(Conn { stream, reader })),
            });
        }
        Ok(RemoteCluster {
            links,
            task_timeout: Duration::from_secs_f64(cfg.task_timeout_secs.max(0.1)),
            max_attempts: cfg.max_attempts.max(1),
            stats: DistStats::default(),
        })
    }

    /// Ship a named blob to every live worker and wait for acks. A worker
    /// that *rejects* the blob fails the run (the data would be equally
    /// bad everywhere); a worker that *dies* is just marked lost.
    pub fn broadcast(&self, name: &str, blob: &[u8]) -> Result<()> {
        let mut payload = Vec::with_capacity(2 + name.len() + blob.len());
        payload.extend_from_slice(&(name.len() as u16).to_le_bytes());
        payload.extend_from_slice(name.as_bytes());
        payload.extend_from_slice(blob);
        let frame = Frame::with_payload(FrameKind::Broadcast, payload);
        let mut alive = 0usize;
        for link in &self.links {
            let Some(mut conn) = link.conn.lock().unwrap().take() else { continue };
            let outcome = self.exchange(&mut conn, &frame);
            match outcome {
                Ok(reply) if reply.kind == FrameKind::Ack => {
                    *link.conn.lock().unwrap() = Some(conn);
                    alive += 1;
                }
                Ok(reply) if reply.kind == FrameKind::TaskErr => bail!(
                    "dist: broadcast {name:?} rejected by worker {}: {}",
                    link.addr,
                    String::from_utf8_lossy(&reply.payload)
                ),
                Ok(reply) => bail!(
                    "dist: broadcast {name:?}: worker {} answered with a {} frame",
                    link.addr,
                    reply.kind.name()
                ),
                Err(TransportError::Malformed(m)) => {
                    bail!("dist: broadcast {name:?} to worker {}: {m}", link.addr)
                }
                Err(_) => {
                    self.stats.worker_losses.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        ensure!(alive > 0, "dist: broadcast {name:?}: all {} workers lost", self.links.len());
        Ok(())
    }

    /// One request/response round-trip on an owned connection, with byte
    /// accounting. The connection is NOT put back — the caller decides
    /// based on the outcome.
    fn exchange(&self, conn: &mut Conn, frame: &Frame) -> Result<Frame, TransportError> {
        let nb = proto::write_frame(&mut conn.stream, frame)?;
        self.stats.bytes_tx.fetch_add(nb as u64, Ordering::Relaxed);
        let reply = conn.reader.read_frame(
            &mut conn.stream,
            Some(Instant::now() + self.task_timeout),
            None,
        )?;
        self.stats.bytes_rx.fetch_add(reply.wire_size() as u64, Ordering::Relaxed);
        Ok(reply)
    }

    /// Execute `specs` across the fleet and gather results *by task
    /// index* — the gather order, and therefore every output bit, is
    /// independent of placement, worker count, and retries.
    ///
    /// `policy` is the same deterministic fault policy the in-process
    /// executor takes: injected failures consume attempts on the driver
    /// before dispatch, stragglers charge virtual delay, and the combined
    /// injected delay is charged to the virtual clock once per stage.
    pub fn run_stage(
        &self,
        stage: &str,
        specs: &[TaskSpec],
        policy: Option<&TaskPolicy>,
    ) -> Result<Vec<(f64, Matrix)>> {
        let m = specs.len();
        let max_attempts = policy.map(|p| p.plan.max_attempts()).unwrap_or(self.max_attempts);
        let sw = Stopwatch::start();
        self.stats.tasks.fetch_add(m as u64, Ordering::Relaxed);

        let mut results: Vec<Option<(f64, Matrix)>> = Vec::with_capacity(m);
        results.resize_with(m, || None);
        // (task, next attempt, saw a failure on an earlier attempt)
        let mut pending: Vec<(usize, usize, bool)> = (0..m).map(|i| (i, 0, false)).collect();
        let mut injected_ms: u64 = 0;

        while !pending.is_empty() {
            // Driver-side fault injection at the executor's coordinates.
            let mut dispatch: Vec<(usize, usize, bool)> = Vec::with_capacity(pending.len());
            for (task, mut attempt, mut bumped) in pending.drain(..) {
                if let Some(p) = policy {
                    loop {
                        match p.plan.decide(stage, task, attempt) {
                            Some(inject @ (Inject::Panic | Inject::TransientErr)) => {
                                if inject == Inject::Panic {
                                    p.stats.record_injected_panic();
                                } else {
                                    p.stats.record_injected_error();
                                }
                                if attempt + 1 >= max_attempts {
                                    p.stats.record_exhausted();
                                    bail!(
                                        "stage {stage}: task {task} of {m} failed after \
                                         {max_attempts} attempts (injected fault)"
                                    );
                                }
                                let backoff = backoff_ms(attempt);
                                p.stats.record_retry(backoff);
                                injected_ms += backoff;
                                attempt += 1;
                                bumped = true;
                            }
                            Some(Inject::StragglerDelay(ms)) => {
                                p.stats.record_straggler(ms);
                                injected_ms += ms;
                                break;
                            }
                            None => break,
                        }
                    }
                }
                dispatch.push((task, attempt, bumped));
            }

            let live: Vec<usize> = (0..self.links.len())
                .filter(|&wi| self.links[wi].conn.lock().unwrap().is_some())
                .collect();
            if live.is_empty() {
                bail!(
                    "stage {stage}: all {} workers lost with {} of {m} tasks outstanding",
                    self.links.len(),
                    dispatch.len()
                );
            }

            // Deterministic placement over the live set via the engine's
            // partitioner machinery. Placement never affects values.
            let part = HashPartitioner::new(live.len());
            let mut queues: Vec<Vec<(usize, usize)>> = vec![Vec::new(); live.len()];
            for &(task, attempt, _) in &dispatch {
                queues[part.partition(BlockId::new(task, task))].push((task, attempt));
            }

            // One driver thread per busy worker; each owns its connection
            // for the round and reports outcomes by value (no shared
            // mutation, no panics on the failure path).
            let round: Vec<(usize, usize, TaskOutcome)> = std::thread::scope(|scope| {
                let handles: Vec<_> = queues
                    .iter()
                    .enumerate()
                    .filter(|(_, q)| !q.is_empty())
                    .map(|(qi, queue)| {
                        let wi = live[qi];
                        scope.spawn(move || self.drive_worker(wi, queue, specs, stage))
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap_or_default()).collect()
            });

            // Workers whose connection did not come back this round died.
            let lost_now = live
                .iter()
                .filter(|&&wi| self.links[wi].conn.lock().unwrap().is_none())
                .count() as u64;
            if lost_now > 0 {
                self.stats.worker_losses.fetch_add(lost_now, Ordering::Relaxed);
                if let Some(p) = policy {
                    for _ in 0..lost_now {
                        p.stats.record_worker_loss();
                    }
                }
            }

            let mut outcomes: Vec<Option<TaskOutcome>> = Vec::with_capacity(m);
            outcomes.resize_with(m, || None);
            for (task, _, oc) in round {
                outcomes[task] = Some(oc);
            }
            for (task, attempt, bumped) in dispatch {
                match outcomes[task].take() {
                    Some(TaskOutcome::Done(secs, mat)) => {
                        results[task] = Some((secs, mat));
                        if bumped || attempt > 0 {
                            if let Some(p) = policy {
                                p.stats.record_recovered();
                            }
                        }
                    }
                    Some(TaskOutcome::Failed(msg)) => {
                        // Worker-reported errors are deterministic bugs
                        // (bad spec, missing broadcast) — retrying on
                        // another worker would fail identically.
                        bail!("stage {stage}: task {task} of {m}: {msg}");
                    }
                    lost => {
                        let reason = match lost {
                            Some(TaskOutcome::Lost(r)) => r,
                            _ => "driver thread produced no outcome".to_string(),
                        };
                        if attempt + 1 >= max_attempts {
                            if let Some(p) = policy {
                                p.stats.record_exhausted();
                            }
                            bail!(
                                "stage {stage}: task {task} of {m} exhausted {max_attempts} \
                                 attempts; last loss: {reason}"
                            );
                        }
                        self.stats.retries.fetch_add(1, Ordering::Relaxed);
                        if let Some(p) = policy {
                            // A real-world retry: counted, but no virtual
                            // backoff — the virtual model prices injected
                            // faults, not this machine's TCP behavior.
                            p.stats.record_retry(0);
                        }
                        pending.push((task, attempt + 1, true));
                    }
                }
            }
        }

        if let Some(p) = policy {
            p.charge_virtual_ms(injected_ms);
        }
        self.stats.wall_us.fetch_add((sw.secs() * 1e6) as u64, Ordering::Relaxed);
        Ok(results.into_iter().map(|r| r.expect("every task resolved or bailed")).collect())
    }

    /// Pipeline one round's queue to one worker and stream replies back.
    /// Every exit path is a returned value — transport failures mark the
    /// worker dead (its connection stays `None`) and surface as
    /// [`TaskOutcome::Lost`] entries for the retry loop.
    fn drive_worker(
        &self,
        wi: usize,
        queue: &[(usize, usize)],
        specs: &[TaskSpec],
        stage: &str,
    ) -> Vec<(usize, usize, TaskOutcome)> {
        let link = &self.links[wi];
        let all_lost = |msg: &str| -> Vec<(usize, usize, TaskOutcome)> {
            queue.iter().map(|&(t, a)| (t, a, TaskOutcome::Lost(msg.to_string()))).collect()
        };
        let Some(mut conn) = link.conn.lock().unwrap().take() else {
            return all_lost(&format!("worker {} already lost", link.addr));
        };

        // Send the whole queue up front; the worker executes serially and
        // replies in order, so responses pipeline behind the requests.
        for &(task, attempt) in queue {
            let frame = Frame {
                kind: FrameKind::Task,
                stage: stage.to_string(),
                task: task as u32,
                attempt: attempt as u32,
                payload: specs[task].encode(),
            };
            match proto::write_frame(&mut conn.stream, &frame) {
                Ok(nb) => {
                    self.stats.bytes_tx.fetch_add(nb as u64, Ordering::Relaxed);
                }
                Err(e) => return all_lost(&format!("worker {}: {e}", link.addr)),
            }
        }

        let mut out: Vec<(usize, usize, TaskOutcome)> = Vec::with_capacity(queue.len());
        for (k, &(task, attempt)) in queue.iter().enumerate() {
            let deadline = Instant::now() + self.task_timeout;
            let reply = match conn.reader.read_frame(&mut conn.stream, Some(deadline), None) {
                Ok(f) => f,
                Err(e) => {
                    let msg = format!("worker {}: {e}", link.addr);
                    out.extend(
                        queue[k..].iter().map(|&(t, a)| (t, a, TaskOutcome::Lost(msg.clone()))),
                    );
                    return out;
                }
            };
            self.stats.bytes_rx.fetch_add(reply.wire_size() as u64, Ordering::Relaxed);
            let routed = reply.task == task as u32
                && matches!(reply.kind, FrameKind::TaskOk | FrameKind::TaskErr);
            if !routed {
                let msg = format!(
                    "worker {}: unexpected {} frame for task {} (awaiting task {task})",
                    link.addr,
                    reply.kind.name(),
                    reply.task
                );
                out.extend(queue[k..].iter().map(|&(t, a)| (t, a, TaskOutcome::Lost(msg.clone()))));
                return out;
            }
            let outcome = if reply.kind == FrameKind::TaskErr {
                TaskOutcome::Failed(format!(
                    "worker {} reports: {}",
                    link.addr,
                    String::from_utf8_lossy(&reply.payload)
                ))
            } else {
                match crate::dist::task::decode_panel_result(&reply.payload) {
                    Ok((secs, mat)) => TaskOutcome::Done(secs, mat),
                    Err(e) => TaskOutcome::Failed(format!("worker {}: {e}", link.addr)),
                }
            };
            out.push((task, attempt, outcome));
        }
        *link.conn.lock().unwrap() = Some(conn);
        out
    }

    /// Fold `secs` of virtual-clock stage span into the report, so the
    /// printed measurement sits next to the projection it grounds.
    pub(crate) fn add_virtual_span(&self, secs: f64) {
        self.stats.virtual_us.fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
    }

    /// Best-effort `Shutdown` to every still-connected worker. The
    /// pipeline never calls this — workers outlive driver runs by design
    /// (the CI smoke runs several drivers against one fleet); benches and
    /// tests use it to tear down workers they spawned.
    pub fn stop_workers(&self) {
        for link in &self.links {
            let Some(mut conn) = link.conn.lock().unwrap().take() else { continue };
            if proto::write_frame(&mut conn.stream, &Frame::control(FrameKind::Shutdown)).is_ok() {
                let _ = conn.reader.read_frame(
                    &mut conn.stream,
                    Some(Instant::now() + Duration::from_secs(2)),
                    None,
                );
            }
        }
    }

    /// Measured ground truth so far.
    pub fn report(&self) -> DistReport {
        DistReport {
            workers: self.links.len(),
            workers_lost: self.stats.worker_losses.load(Ordering::Relaxed),
            tasks: self.stats.tasks.load(Ordering::Relaxed),
            retries: self.stats.retries.load(Ordering::Relaxed),
            bytes_sent: self.stats.bytes_tx.load(Ordering::Relaxed),
            bytes_received: self.stats.bytes_rx.load(Ordering::Relaxed),
            wall_secs: self.stats.wall_us.load(Ordering::Relaxed) as f64 / 1e6,
            virtual_secs: self.stats.virtual_us.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }
}
