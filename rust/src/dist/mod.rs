//! Real multi-process distribution: the `isospark worker` runtime and
//! the driver-side [`RemoteCluster`] that ships stage tasks to it over a
//! checksummed, length-prefixed TCP block-shuffle protocol.
//!
//! Everything else in the engine simulates a cluster (virtual clock,
//! network model); this module is where bytes actually cross a process
//! boundary. The layering:
//!
//! - [`proto`] — the wire format: 32-byte framed messages with stage/
//!   task/attempt routing headers, FNV-1a-64 content checksums, and the
//!   same pure-buffer `try_parse` discipline as `serve/http.rs`.
//! - [`task`] — the serializable task vocabulary ([`task::TaskSpec`])
//!   and payload codecs; every `f64` crosses the wire as `to_le_bytes`,
//!   a bit-exact round-trip.
//! - [`worker`] — the `isospark worker` server loop: receives broadcast
//!   state, executes tasks through the same kernels as the in-process
//!   engine, streams results back.
//! - [`cluster`] — the driver: placement over live workers via the
//!   engine's `Partitioner`, pipelined scatter/gather, and a retry loop
//!   that composes with the `engine/fault` machinery (injected faults
//!   consume attempts on the driver; a dead worker's tasks are retried
//!   elsewhere; exhaustion propagates with stage context).
//!
//! The bit-determinism contract extends across process counts: a task's
//! value is a pure function of broadcast state computed by the same code
//! the single-process path runs, and results are gathered by task index
//! — so 1 process and N workers produce bit-identical embeddings, which
//! `tests/dist_cluster.rs` enforces (including under fault injection and
//! mid-stage worker death).

pub mod cluster;
pub mod proto;
pub mod task;
pub mod worker;

pub use cluster::{DistConfig, DistReport, RemoteCluster};
