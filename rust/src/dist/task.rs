//! Serializable task vocabulary and payload codecs for the dist layer.
//!
//! Closures cannot cross a process boundary, so remote stages speak a
//! typed task enum instead: the driver encodes a [`TaskSpec`] into a
//! `Task` frame, the worker decodes it and runs the matching kernel
//! against state it received via `Broadcast` frames. Every codec here is
//! hand-rolled little-endian (no serde in the dependency tree) with
//! bounds-checked reads, and every `f64` moves as `to_le_bytes` /
//! `from_le_bytes` — a bit-exact round-trip, which is what lets the
//! embedding stay bit-identical no matter how many processes computed it.

use crate::data::io::{matrix_from_bytes, matrix_to_bytes};
use crate::kernels::kselect::Neighbor;
use crate::linalg::Matrix;

/// Broadcast name for the geodesic job (kNN graph + block geometry).
pub const GEO_JOB: &str = "geo-job";

/// Opcode bytes for [`TaskSpec`].
const OP_GEODESIC_PANEL: u8 = 1;

/// One remotely-executable stage task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskSpec {
    /// Compute the squared-geodesic row panel for block-row `block` of
    /// the broadcast [`GeoJob`]: multi-source Dijkstra from the block's
    /// rows over the shared CSR graph, then square in place.
    GeodesicPanel { block: u64 },
}

impl TaskSpec {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            TaskSpec::GeodesicPanel { block } => {
                let mut out = Vec::with_capacity(9);
                out.push(OP_GEODESIC_PANEL);
                out.extend_from_slice(&block.to_le_bytes());
                out
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<TaskSpec, String> {
        let mut cur = Cur::new(buf);
        match cur.u8()? {
            OP_GEODESIC_PANEL => {
                let block = cur.u64()?;
                cur.done()?;
                Ok(TaskSpec::GeodesicPanel { block })
            }
            op => Err(format!("task spec: unknown opcode {op}")),
        }
    }
}

/// The broadcast state every geodesic panel task executes against.
/// Workers rebuild the CSR graph from these lists with
/// `CsrGraph::from_knn_lists` — a deterministic construction, so every
/// process sees the identical graph the driver validated.
pub struct GeoJob {
    /// Point count.
    pub n: usize,
    /// Block size `b` (panel = `b × n`, last block possibly ragged).
    pub block: usize,
    /// Per-point kNN lists, exactly as the kNN stage produced them.
    pub lists: Vec<Vec<Neighbor>>,
}

/// Encode a [`GeoJob`]: `n` u64, `block` u64, list count u64, then per
/// list a u32 length followed by (f64 distance, u32 neighbor) pairs.
/// Neighbor indices fit u32 by the same cap `CsrGraph` enforces.
pub fn encode_geo_job(n: usize, block: usize, lists: &[Vec<Neighbor>]) -> Vec<u8> {
    let pairs: usize = lists.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(24 + lists.len() * 4 + pairs * 12);
    out.extend_from_slice(&(n as u64).to_le_bytes());
    out.extend_from_slice(&(block as u64).to_le_bytes());
    out.extend_from_slice(&(lists.len() as u64).to_le_bytes());
    for list in lists {
        out.extend_from_slice(&(list.len() as u32).to_le_bytes());
        for &(dist, j) in list {
            out.extend_from_slice(&dist.to_le_bytes());
            out.extend_from_slice(&(j as u32).to_le_bytes());
        }
    }
    out
}

/// Decode a [`GeoJob`]; rejects truncated or trailing bytes with context.
pub fn decode_geo_job(buf: &[u8]) -> Result<GeoJob, String> {
    let mut cur = Cur::new(buf);
    let n = cur.u64()? as usize;
    let block = cur.u64()? as usize;
    let count = cur.u64()? as usize;
    if block == 0 {
        return Err("geo job: zero block size".into());
    }
    if count != n {
        return Err(format!("geo job: {count} kNN lists for {n} points"));
    }
    // Cheap sanity cap before allocating: every list needs ≥ 4 bytes.
    if count > buf.len() / 4 {
        return Err(format!("geo job: {count} lists cannot fit in {} bytes", buf.len()));
    }
    let mut lists = Vec::with_capacity(count);
    for i in 0..count {
        let len = cur.u32()? as usize;
        let mut list = Vec::with_capacity(len.min(buf.len() / 12));
        for _ in 0..len {
            let dist = cur.f64()?;
            let j = cur.u32()? as usize;
            if j >= n {
                return Err(format!("geo job: list {i} names neighbor {j} ≥ n = {n}"));
            }
            list.push((dist, j));
        }
        lists.push(list);
    }
    cur.done()?;
    Ok(GeoJob { n, block, lists })
}

/// Encode a `TaskOk` payload for a geodesic panel: worker-measured
/// compute seconds (f64), then the squared panel in the `data::io`
/// matrix layout.
pub fn encode_panel_result(compute_secs: f64, panel: &Matrix) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + 24 + panel.as_slice().len() * 8);
    out.extend_from_slice(&compute_secs.to_le_bytes());
    matrix_to_bytes(panel, &mut out);
    out
}

/// Decode a geodesic panel result.
pub fn decode_panel_result(buf: &[u8]) -> Result<(f64, Matrix), String> {
    if buf.len() < 8 {
        return Err(format!("panel result: {} bytes is too short", buf.len()));
    }
    let secs = f64::from_le_bytes(buf[..8].try_into().unwrap());
    let (panel, used) = matrix_from_bytes(&buf[8..]).map_err(|e| format!("panel result: {e:#}"))?;
    if 8 + used != buf.len() {
        return Err(format!("panel result: {} trailing bytes", buf.len() - 8 - used));
    }
    Ok((secs, panel))
}

/// Bounds-checked little-endian reader — decode helpers share it so every
/// truncation produces an error instead of a panic.
struct Cur<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .at
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| format!("payload truncated at byte {} (want {n} more)", self.at))?;
        let s = &self.buf[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> Result<(), String> {
        if self.at == self.buf.len() {
            Ok(())
        } else {
            Err(format!("payload has {} trailing bytes", self.buf.len() - self.at))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_spec_roundtrips() {
        let spec = TaskSpec::GeodesicPanel { block: 42 };
        assert_eq!(TaskSpec::decode(&spec.encode()).unwrap(), spec);
        assert!(TaskSpec::decode(&[]).is_err());
        assert!(TaskSpec::decode(&[99]).is_err());
        let mut trailing = spec.encode();
        trailing.push(0);
        assert!(TaskSpec::decode(&trailing).is_err());
    }

    #[test]
    fn geo_job_roundtrips_bit_exact() {
        let lists: Vec<Vec<Neighbor>> =
            vec![vec![(0.5, 1), (1.25, 2)], vec![(0.5, 0)], vec![(1.25, 0), (3e-17, 1)]];
        let bytes = encode_geo_job(3, 2, &lists);
        let job = decode_geo_job(&bytes).unwrap();
        assert_eq!(job.n, 3);
        assert_eq!(job.block, 2);
        assert_eq!(job.lists.len(), 3);
        for (a, b) in job.lists.iter().flatten().zip(lists.iter().flatten()) {
            assert_eq!(a.0.to_bits(), b.0.to_bits());
            assert_eq!(a.1, b.1);
        }
    }

    #[test]
    fn geo_job_rejects_corrupt_shapes() {
        let lists: Vec<Vec<Neighbor>> = vec![vec![(1.0, 1)], vec![(1.0, 0)]];
        let good = encode_geo_job(2, 1, &lists);
        assert!(decode_geo_job(&good[..good.len() - 1]).is_err(), "truncated");
        let err = decode_geo_job(&encode_geo_job(5, 1, &lists)).unwrap_err();
        assert!(err.contains("2 kNN lists for 5 points"), "{err}");
        let oob: Vec<Vec<Neighbor>> = vec![vec![(1.0, 7)], vec![(1.0, 0)]];
        let err = decode_geo_job(&encode_geo_job(2, 1, &oob)).unwrap_err();
        assert!(err.contains("neighbor 7"), "{err}");
    }

    #[test]
    fn panel_result_roundtrips_bit_exact() {
        let m = Matrix::from_rows(&[vec![1.5, -0.0, f64::INFINITY], vec![2.5e-300, 4.0, 9.0]]);
        let (secs, r) = decode_panel_result(&encode_panel_result(0.125, &m)).unwrap();
        assert_eq!(secs, 0.125);
        let (rb, mb): (Vec<u64>, Vec<u64>) = (
            r.as_slice().iter().map(|v| v.to_bits()).collect(),
            m.as_slice().iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(rb, mb);
        assert!(decode_panel_result(&[1, 2, 3]).is_err());
    }
}
