//! Landmark Isomap (L-Isomap) — the approximate variant the paper
//! contrasts with (§V, de Silva & Tenenbaum): `m` landmarks are embedded
//! by exact MDS on their geodesic distances; the remaining points are
//! placed by distance-based triangulation. Shares the distributed kNN
//! stage with the exact pipeline; the `m × n` geodesics come from the
//! pooled multi-source Dijkstra over the CSR neighborhood graph
//! ([`crate::graph`]) — past the kNN stage (whose blocked distance
//! computation is still all-pairs), the only dense state is the
//! `m × n` landmark table.

use crate::backend::Backend;
use crate::config::{ClusterConfig, IsomapConfig};
use crate::engine::SparkContext;
use crate::graph::{self, CsrGraph};
use crate::linalg::{jacobi, Matrix};
use crate::util::Rng;
use anyhow::{bail, Context, Result};

/// L-Isomap output.
#[derive(Debug)]
pub struct LandmarkOutput {
    /// The `n × d` embedding.
    pub embedding: Matrix,
    /// Indices of the selected landmarks.
    pub landmarks: Vec<usize>,
    /// Top-`d` eigenvalues of the landmark MDS.
    pub eigenvalues: Vec<f64>,
}

/// Run L-Isomap with `m` randomly selected landmarks.
pub fn run(
    x: &Matrix,
    cfg: &IsomapConfig,
    m: usize,
    cluster: &ClusterConfig,
    backend: &Backend,
) -> Result<LandmarkOutput> {
    let n = x.nrows();
    cfg.validate(n)?;
    if m < cfg.d + 1 || m > n {
        bail!("landmark count m={m} must be in {}..={n}", cfg.d + 1);
    }
    let ctx = SparkContext::new(cluster.clone());

    // Distributed kNN stage, lists only — L-Isomap never needs the dense
    // blocked neighborhood graph, so it is never built.
    let kl = super::knn::build_lists(&ctx, x, cfg, backend).context("kNN stage")?;
    if crate::eval::components(&kl.lists) != 1 {
        bail!("kNN graph disconnected; increase k");
    }

    // Landmark selection (uniform, as in de Silva & Tenenbaum).
    let mut rng = Rng::seed(cfg.seed);
    let landmarks = rng.sample_indices(n, m);

    // Geodesics landmark -> all points: m pooled Dijkstra sources over the
    // CSR graph (the O(n³) APSP is exactly what L-Isomap avoids; past the
    // kNN stage the only dense state is the m × n landmark table).
    let csr = CsrGraph::from_knn_lists(&kl.lists).context("CSR construction")?;
    let delta = graph::geodesics_squared(&csr, &landmarks, ctx.parallelism())
        .context("landmark geodesics")?;

    // MDS on the m×m landmark sub-matrix.
    let mut dl = Matrix::zeros(m, m);
    for a in 0..m {
        for bb in 0..m {
            dl[(a, bb)] = delta[(a, landmarks[bb])];
        }
    }
    crate::kernels::centering::center_full_direct(&mut dl);
    let (vals, vecs) = jacobi::top_d(&dl, cfg.d);
    if vals[cfg.d - 1] <= 0.0 {
        bail!("landmark MDS produced non-positive eigenvalue {}", vals[cfg.d - 1]);
    }

    // Triangulation: y_i = ½·Λ^{-½}·Qᵀ·(δ̄ − δ_i), δ̄ = mean landmark row.
    let mut mean_delta = vec![0.0; m];
    for a in 0..m {
        for bb in 0..m {
            mean_delta[a] += dl_raw(&delta, &landmarks, a, bb);
        }
        mean_delta[a] /= m as f64;
    }
    let mut embedding = Matrix::zeros(n, cfg.d);
    for i in 0..n {
        for j in 0..cfg.d {
            let mut acc = 0.0;
            for a in 0..m {
                acc += vecs[(a, j)] * (mean_delta[a] - delta[(a, i)]);
            }
            embedding[(i, j)] = 0.5 * acc / vals[j].sqrt();
        }
    }

    Ok(LandmarkOutput { embedding, landmarks, eigenvalues: vals })
}

/// Raw squared landmark-landmark distance (helper for the mean row).
fn dl_raw(delta: &Matrix, landmarks: &[usize], a: usize, b: usize) -> f64 {
    delta[(a, landmarks[b])]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::swiss_roll;
    use crate::eval::procrustes;

    #[test]
    fn landmarks_approximate_exact_isomap() {
        let ds = swiss_roll::euler_isometric(600, 23);
        let cfg = IsomapConfig { k: 10, d: 2, block: 128, ..Default::default() };
        let exact = super::super::isomap::run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
        let lm = run(&ds.points, &cfg, 100, &ClusterConfig::local(), &Backend::Native).unwrap();
        assert_eq!(lm.landmarks.len(), 100);
        let err = procrustes(&exact.embedding, &lm.embedding);
        // Approximation, not exact — but must be structurally the same.
        assert!(err < 0.05, "L-Isomap vs exact procrustes = {err}");
    }

    #[test]
    fn landmark_embedding_matches_truth_roughly() {
        let ds = swiss_roll::euler_isometric(600, 29);
        let cfg = IsomapConfig { k: 10, d: 2, block: 128, ..Default::default() };
        let lm = run(&ds.points, &cfg, 80, &ClusterConfig::local(), &Backend::Native).unwrap();
        let err = procrustes(ds.ground_truth.as_ref().unwrap(), &lm.embedding);
        assert!(err < 0.05, "procrustes = {err}");
    }

    #[test]
    fn rejects_bad_m() {
        let ds = swiss_roll::euler_isometric(30, 3);
        let cfg = IsomapConfig { k: 5, d: 2, block: 16, ..Default::default() };
        assert!(run(&ds.points, &cfg, 2, &ClusterConfig::local(), &Backend::Native).is_err());
        assert!(run(&ds.points, &cfg, 31, &ClusterConfig::local(), &Backend::Native).is_err());
    }
}
