//! Communication-avoiding blocked Floyd–Warshall APSP (paper §III-B).
//!
//! Per diagonal iteration `I` (the critical path of length `q`):
//!
//! * **Phase 1** — sequential Floyd–Warshall on diagonal block `(I,I)`;
//!   the solved block is replicated to every block of row `I` / column `I`.
//! * **Phase 2** — those blocks are min-plus-updated with the pivot:
//!   `A_{IJ} ← A_{IJ} ⊕ (D ⊗ A_{IJ})`, `A_{ÎI} ← A_{ÎI} ⊕ (A_{ÎI} ⊗ D)`;
//!   each updated segment is replicated (transposing as needed for the
//!   upper-triangular storage) to the Phase-3 blocks that need it.
//! * **Phase 3** — every remaining block folds in the rank-`b` update
//!   `A_{RC} ← A_{RC} ⊕ (A_{RI} ⊗ A_{IC})`.
//!
//! Every data movement is a keyed shuffle (`flat_map` + `join_update`),
//! never a collect/broadcast through the driver — the paper found that
//! decisive on Spark. Replication payloads are `Arc<Matrix>`: fanning the
//! pivot out to `O(q)` destinations bumps a refcount per destination
//! instead of deep-copying a `b×b` block each time (the simulated network
//! still charges full payload bytes per message), and the `join_update`
//! phases mutate blocks copy-on-write — Phase 2/3 update blocks in place
//! with the scratch-reusing in-place min-plus kernels, and blocks a phase
//! leaves untouched are never cloned at all. Lineage is checkpointed every
//! `checkpoint_every` iterations (paper: 10) to keep the driver model's
//! scheduling overhead bounded.

use crate::backend::Backend;
use crate::config::IsomapConfig;
use crate::engine::{BlockId, BlockRdd};
use crate::linalg::Matrix;
use anyhow::Result;
use std::sync::Arc;

/// Left operand marker (`A_RI`) in Phase-3 messages.
const LEFT: usize = 0;
/// Right operand marker (`A_IC`).
const RIGHT: usize = 1;

/// Solve APSP in place over the graph's upper-triangular blocks; returns
/// the *feature matrix* `A = G°²` (squared geodesics), ready for
/// double centering.
pub fn solve(
    graph: BlockRdd<Matrix>,
    q: usize,
    cfg: &IsomapConfig,
    backend: &Backend,
) -> Result<BlockRdd<Matrix>> {
    let mut g = graph;

    for piv in 0..q {
        // ---- Phase 1: FW on the diagonal block, then replicate. ----
        let diag = g
            .filter_blocks(&format!("apsp:p1_filter[{piv}]"), |id| id.i == piv && id.j == piv)
            .map_values(&format!("apsp:p1_fw[{piv}]"), |_, blk| {
                let mut d = blk.clone();
                backend.fw_inplace(&mut d);
                d
            });
        let diag_msgs = diag.flat_map_arc(&format!("apsp:p1_emit[{piv}]"), |_, d| {
            let mut out = vec![(BlockId::new(piv, piv), Arc::clone(d))];
            for j in (piv + 1)..q {
                out.push((BlockId::new(piv, j), Arc::clone(d)));
            }
            for i in 0..piv {
                out.push((BlockId::new(i, piv), Arc::clone(d)));
            }
            out
        });

        // ---- Phase 2: pivot-row/column update (and diagonal swap). ----
        g = g.join_update(&format!("apsp:p2[{piv}]"), diag_msgs, |id, blk, ds| {
            let Some(d) = ds.into_iter().next() else { return }; // not in row/col piv
            if id.i == piv && id.j == piv {
                blk.set_shared(d); // zero-copy: adopt the solved pivot
            } else if id.i == piv {
                // Row segment A_{piv,J}: left-multiply by the pivot.
                backend.minplus_left_inplace(&d, blk.make_mut());
            } else {
                // Column segment A_{Î,piv}: right-multiply by the pivot.
                backend.minplus_right_inplace(&d, blk.make_mut());
            }
        });

        // ---- Phase-2 replication toward Phase 3. ----
        // Row segment (piv, J) carries A_{piv,J}; its transpose carries
        // A_{J,piv}. Column segment (Î, piv) carries A_{Î,piv}; transpose
        // carries A_{piv,Î}. Each Phase-3 block (R,C) needs LEFT = A_{R,piv}
        // and RIGHT = A_{piv,C}.
        let p2 = g.filter_blocks(&format!("apsp:p2_filter[{piv}]"), |id| {
            (id.i == piv) ^ (id.j == piv)
        });
        let p3_msgs = p2.flat_map_arc(&format!("apsp:p2_emit[{piv}]"), |id, m| {
            let mut out = Vec::new();
            if id.i == piv {
                let jj = id.j; // row segment A_{piv,jj}
                for r in 0..=jj {
                    if r != piv {
                        out.push((BlockId::new(r, jj), (RIGHT, Arc::clone(m))));
                    }
                }
                let t = Arc::new(m.transpose()); // A_{jj,piv}
                for c in jj..q {
                    if c != piv {
                        out.push((BlockId::new(jj, c), (LEFT, Arc::clone(&t))));
                    }
                }
            } else {
                let ii = id.i; // column segment A_{ii,piv}
                for c in ii..q {
                    if c != piv {
                        out.push((BlockId::new(ii, c), (LEFT, Arc::clone(m))));
                    }
                }
                let t = Arc::new(m.transpose()); // A_{piv,ii}
                for r in 0..=ii {
                    if r != piv {
                        out.push((BlockId::new(r, ii), (RIGHT, Arc::clone(&t))));
                    }
                }
            }
            out
        });

        // ---- Phase 3: rank-b min-plus update of the remaining blocks. ----
        g = g.join_update(&format!("apsp:p3[{piv}]"), p3_msgs, |id, blk, msgs| {
            if msgs.is_empty() {
                return; // pivot row/column blocks: already final this iter
            }
            debug_assert!(id.i != piv && id.j != piv, "phase-3 message hit pivot block {id}");
            let left = msgs.iter().find(|(role, _)| *role == LEFT);
            let right = msgs.iter().find(|(role, _)| *role == RIGHT);
            if let (Some((_, l)), Some((_, r))) = (left, right) {
                backend.minplus_into(l, r, blk.make_mut());
            }
        });

        // ---- Lineage maintenance (paper: checkpoint every 10 iters). ----
        if cfg.checkpoint_every > 0 && (piv + 1) % cfg.checkpoint_every == 0 {
            g.checkpoint();
            g.persist("G")?;
        }
    }

    // Feature matrix: element-wise square of the geodesics.
    let a = g.map_values("apsp:square", |_, blk| blk.map(|v| v * v));
    a.persist("G")?;
    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::config::ClusterConfig;
    use crate::coordinator::{block_range, knn};
    use crate::data::swiss_roll;
    use crate::engine::SparkContext;

    /// Run kNN+APSP through the engine and densify the result
    /// (square-rooted back to geodesic distances).
    fn engine_geodesics(n: usize, b: usize, k: usize, checkpoint_every: usize) -> (Matrix, Matrix) {
        let ds = swiss_roll::euler_isometric(n, 21);
        let ctx = SparkContext::new(ClusterConfig::local());
        let cfg = IsomapConfig { k, block: b, checkpoint_every, ..Default::default() };
        let backend = Backend::Native;
        let kg = knn::build(&ctx, &ds.points, &cfg, &backend).unwrap();
        let a = solve(kg.graph, kg.q, &cfg, &backend).unwrap();
        let mut dense = Matrix::full(n, n, f64::INFINITY);
        for (id, blk) in a.iter() {
            let (rs, _) = block_range(n, b, id.i);
            let (cs, _) = block_range(n, b, id.j);
            for r in 0..blk.nrows() {
                for c in 0..blk.ncols() {
                    let v = blk[(r, c)].sqrt();
                    dense[(rs + r, cs + c)] = v;
                    dense[(cs + c, rs + r)] = v;
                }
            }
        }
        (ds.points, dense)
    }

    fn reference_geodesics(x: &Matrix, k: usize) -> Matrix {
        let g = baselines::knn_graph_dense(&baselines::brute_knn(x, k));
        baselines::dijkstra_apsp(&g)
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f64) {
        for i in 0..a.nrows() {
            for j in 0..a.ncols() {
                let (x, y) = (a[(i, j)], b[(i, j)]);
                if x.is_infinite() || y.is_infinite() {
                    assert!(x.is_infinite() && y.is_infinite(), "({i},{j}): {x} vs {y}");
                } else {
                    assert!((x - y).abs() <= tol, "({i},{j}): {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn matches_dijkstra_divisible() {
        let (x, got) = engine_geodesics(48, 16, 6, 10);
        let want = reference_geodesics(&x, 6);
        assert_close(&got, &want, 1e-9);
    }

    #[test]
    fn matches_dijkstra_ragged_blocks() {
        let (x, got) = engine_geodesics(50, 16, 6, 10);
        let want = reference_geodesics(&x, 6);
        assert_close(&got, &want, 1e-9);
    }

    #[test]
    fn matches_dijkstra_single_block() {
        // q = 1: only Phase 1 runs.
        let (x, got) = engine_geodesics(20, 32, 5, 10);
        let want = reference_geodesics(&x, 5);
        assert_close(&got, &want, 1e-9);
    }

    #[test]
    fn checkpoint_cadence_does_not_change_results() {
        let (_, a) = engine_geodesics(40, 8, 5, 0); // never checkpoint
        let (_, b) = engine_geodesics(40, 8, 5, 2); // every 2 iters
        assert_close(&a, &b, 0.0);
    }

    #[test]
    fn many_small_blocks() {
        // Large q stresses the 3-phase replication logic.
        let (x, got) = engine_geodesics(42, 5, 6, 3);
        let want = reference_geodesics(&x, 6);
        assert_close(&got, &want, 1e-9);
    }
}
