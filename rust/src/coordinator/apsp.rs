//! Communication-avoiding blocked Floyd–Warshall APSP (paper §III-B).
//!
//! Per diagonal iteration `I` (the critical path of length `q`):
//!
//! * **Phase 1** — sequential Floyd–Warshall on diagonal block `(I,I)`;
//!   the solved block is replicated to every block of row `I` / column `I`.
//! * **Phase 2** — those blocks are min-plus-updated with the pivot:
//!   `A_{IJ} ← A_{IJ} ⊕ (D ⊗ A_{IJ})`, `A_{ÎI} ← A_{ÎI} ⊕ (A_{ÎI} ⊗ D)`;
//!   each updated segment is replicated (transposing as needed for the
//!   upper-triangular storage) to the Phase-3 blocks that need it.
//! * **Phase 3** — every remaining block folds in the rank-`b` update
//!   `A_{RC} ← A_{RC} ⊕ (A_{RI} ⊗ A_{IC})`.
//!
//! Every data movement is a keyed shuffle (`flat_map` + `join_update`),
//! never a collect/broadcast through the driver — the paper found that
//! decisive on Spark. Replication payloads are `Arc<Matrix>`: fanning the
//! pivot out to `O(q)` destinations bumps a refcount per destination
//! instead of deep-copying a `b×b` block each time (the simulated network
//! still charges full payload bytes per message), and the `join_update`
//! phases mutate blocks copy-on-write — Phase 2/3 update blocks in place
//! with the scratch-reusing in-place min-plus kernels, and blocks a phase
//! leaves untouched are never cloned at all. Lineage is checkpointed every
//! `checkpoint_every` iterations (paper: 10) to keep the driver model's
//! scheduling overhead bounded.
//!
//! [`solve_sparse`] is the k-sparse alternative (`--geodesics
//! sparse-dijkstra`): the same squared-geodesic feature blocks, produced
//! by pooled multi-source Dijkstra over a CSR view of the kNN lists
//! instead of `O(n³)` dense block algebra — see [`crate::graph`].

use crate::backend::Backend;
use crate::config::IsomapConfig;
use crate::engine::{BlockId, BlockRdd, SparkContext};
use crate::graph::{dijkstra, CsrGraph};
use crate::kernels::kselect::Neighbor;
use crate::linalg::Matrix;
use anyhow::{Context, Result};
use std::sync::Arc;

/// Left operand marker (`A_RI`) in Phase-3 messages.
const LEFT: usize = 0;
/// Right operand marker (`A_IC`).
const RIGHT: usize = 1;

/// Content fingerprint binding a durable APSP checkpoint to its input:
/// FNV over `q` and every input block's id, shape, and f64 bits. A
/// checkpoint directory reused across different datasets/configs can
/// never serve stale state — a different input graph hashes to a
/// different job key and simply finds no checkpoint.
fn graph_fingerprint(graph: &BlockRdd<Matrix>, q: usize) -> u64 {
    let mut h = crate::data::io::Fnv1a64::new();
    h.update(&(q as u64).to_le_bytes());
    for (id, blk) in graph.iter() {
        h.update(&(id.i as u64).to_le_bytes());
        h.update(&(id.j as u64).to_le_bytes());
        h.update(&(blk.nrows() as u64).to_le_bytes());
        h.update(&(blk.ncols() as u64).to_le_bytes());
        for v in blk.as_slice() {
            h.update(&v.to_le_bytes());
        }
    }
    h.finish()
}

/// Solve APSP in place over the graph's upper-triangular blocks; returns
/// the *feature matrix* `A = G°²` (squared geodesics), ready for
/// double centering.
///
/// With `--checkpoint-dir` set, the `checkpoint_every` cadence also spills
/// the blocks durably (keyed by a content fingerprint of the input), and a
/// fresh call restores from the newest valid spill, skipping the already
/// completed pivot iterations — the resumed run's output is bit-identical
/// to an uninterrupted one, because the blocks round-trip bit-exactly and
/// the remaining pivots see exactly the state they would have seen.
pub fn solve(
    graph: BlockRdd<Matrix>,
    q: usize,
    cfg: &IsomapConfig,
    backend: &Backend,
) -> Result<BlockRdd<Matrix>> {
    let ctx = graph.context();
    let job = ctx
        .checkpoint_store()
        .map(|_| format!("apsp-{:016x}", graph_fingerprint(&graph, q)));

    let mut g = graph;
    let mut start = 0usize;
    if let (Some(store), Some(job)) = (ctx.checkpoint_store(), job.as_deref()) {
        if let Some((step, blocks)) = store.latest_valid(job) {
            // `step` = completed pivot iterations at spill time.
            let sw = crate::util::Stopwatch::start();
            let part = g.partitioner();
            g = ctx.parallelize("apsp:restore", blocks, part);
            g.persist("G")?;
            ctx.resilience().record_restore();
            ctx.push_metrics(crate::engine::metrics::StageMetrics {
                name: "checkpoint:restore".to_string(),
                tasks: g.len(),
                compute_real: 0.0,
                virtual_span: 0.0,
                shuffle_bytes: 0,
                network_time: 0.0,
                driver_time: sw.secs(),
            });
            start = step.min(q);
        }
    }

    for piv in start..q {
        // ---- Phase 1: FW on the diagonal block, then replicate. ----
        let diag = g
            .filter_blocks(&format!("apsp:p1_filter[{piv}]"), |id| id.i == piv && id.j == piv)
            .map_values(&format!("apsp:p1_fw[{piv}]"), |_, blk| {
                let mut d = blk.clone();
                backend.fw_inplace(&mut d);
                d
            });
        let diag_msgs = diag.flat_map_arc(&format!("apsp:p1_emit[{piv}]"), |_, d| {
            let mut out = vec![(BlockId::new(piv, piv), Arc::clone(d))];
            for j in (piv + 1)..q {
                out.push((BlockId::new(piv, j), Arc::clone(d)));
            }
            for i in 0..piv {
                out.push((BlockId::new(i, piv), Arc::clone(d)));
            }
            out
        });

        // ---- Phase 2: pivot-row/column update (and diagonal swap). ----
        g = g.join_update(&format!("apsp:p2[{piv}]"), diag_msgs, |id, blk, ds| {
            let Some(d) = ds.into_iter().next() else { return }; // not in row/col piv
            if id.i == piv && id.j == piv {
                blk.set_shared(d); // zero-copy: adopt the solved pivot
            } else if id.i == piv {
                // Row segment A_{piv,J}: left-multiply by the pivot.
                backend.minplus_left_inplace(&d, blk.make_mut());
            } else {
                // Column segment A_{Î,piv}: right-multiply by the pivot.
                backend.minplus_right_inplace(&d, blk.make_mut());
            }
        });

        // ---- Phase-2 replication toward Phase 3. ----
        // Row segment (piv, J) carries A_{piv,J}; its transpose carries
        // A_{J,piv}. Column segment (Î, piv) carries A_{Î,piv}; transpose
        // carries A_{piv,Î}. Each Phase-3 block (R,C) needs LEFT = A_{R,piv}
        // and RIGHT = A_{piv,C}.
        let p2 = g.filter_blocks(&format!("apsp:p2_filter[{piv}]"), |id| {
            (id.i == piv) ^ (id.j == piv)
        });
        let p3_msgs = p2.flat_map_arc(&format!("apsp:p2_emit[{piv}]"), |id, m| {
            let mut out = Vec::new();
            if id.i == piv {
                let jj = id.j; // row segment A_{piv,jj}
                for r in 0..=jj {
                    if r != piv {
                        out.push((BlockId::new(r, jj), (RIGHT, Arc::clone(m))));
                    }
                }
                let t = Arc::new(m.transpose()); // A_{jj,piv}
                for c in jj..q {
                    if c != piv {
                        out.push((BlockId::new(jj, c), (LEFT, Arc::clone(&t))));
                    }
                }
            } else {
                let ii = id.i; // column segment A_{ii,piv}
                for c in ii..q {
                    if c != piv {
                        out.push((BlockId::new(ii, c), (LEFT, Arc::clone(m))));
                    }
                }
                let t = Arc::new(m.transpose()); // A_{piv,ii}
                for r in 0..=ii {
                    if r != piv {
                        out.push((BlockId::new(r, ii), (RIGHT, Arc::clone(&t))));
                    }
                }
            }
            out
        });

        // ---- Phase 3: rank-b min-plus update of the remaining blocks. ----
        g = g.join_update(&format!("apsp:p3[{piv}]"), p3_msgs, |id, blk, msgs| {
            if msgs.is_empty() {
                return; // pivot row/column blocks: already final this iter
            }
            debug_assert!(id.i != piv && id.j != piv, "phase-3 message hit pivot block {id}");
            let left = msgs.iter().find(|(role, _)| *role == LEFT);
            let right = msgs.iter().find(|(role, _)| *role == RIGHT);
            if let (Some((_, l)), Some((_, r))) = (left, right) {
                backend.minplus_into(l, r, blk.make_mut());
            }
        });

        // ---- Lineage maintenance (paper: checkpoint every 10 iters),
        // made durable when a checkpoint store is configured. ----
        if cfg.checkpoint_every > 0 && (piv + 1) % cfg.checkpoint_every == 0 {
            match job.as_deref() {
                Some(job) => {
                    g.checkpoint_durable(job, piv + 1)
                        .with_context(|| format!("durable checkpoint at pivot {piv}"))?;
                }
                None => g.checkpoint(),
            }
            g.persist("G")?;
        }
    }

    // Feature matrix: element-wise square of the geodesics.
    let a = g.map_values("apsp:square", |_, blk| blk.map(|v| v * v));
    a.persist("G")?;
    Ok(a)
}

/// Sparse alternative to [`solve`]: squared geodesics straight from the
/// kNN lists via a CSR graph and pooled multi-source Dijkstra
/// (`isospark run --geodesics sparse-dijkstra`).
///
/// One panel per block-row: the `b` points of block-row `I` are the
/// sources of one batched Dijkstra ([`crate::graph::dijkstra::multi_source`],
/// fanned over the engine's worker pool), and the resulting `b × n`
/// distance panel is squared and sliced into the upper-triangular feature
/// blocks `(I, J), J ≥ I` — the exact shape the centering stage consumes.
/// The dense blocked APSP RDD (and its `O(q)` shuffle rounds) is never
/// built; peak transient state is one row panel. Work drops from the
/// dense path's `O(n³)` to `O(n·(n + E) log n)` with `E = n·k`.
///
/// Deterministic for any pool size (each source row is an independent
/// serial Dijkstra), and bails up front with context when the graph is
/// disconnected — the condition the dense path only surfaces as infinite
/// column sums at the centering stage.
pub fn solve_sparse(
    ctx: &SparkContext,
    lists: &[Vec<Neighbor>],
    n: usize,
    cfg: &IsomapConfig,
) -> Result<BlockRdd<Matrix>> {
    use super::{block_range, default_partitions, num_blocks};
    use crate::engine::partitioner::UpperTriangularPartitioner;

    if lists.len() != n {
        anyhow::bail!("sparse geodesics: {} kNN lists for n = {n} points", lists.len());
    }
    let csr = CsrGraph::from_knn_lists(lists).context("sparse geodesics: CSR construction")?;
    csr.require_connected().context("sparse geodesics")?;
    let b = cfg.block;
    let q = num_blocks(n, b);
    let workers = ctx.parallelism();

    let policy = ctx.task_policy();
    let mut blocks: Vec<(BlockId, Matrix)> =
        Vec::with_capacity(crate::engine::partitioner::ut_count(q));
    let mut panel_tasks = Vec::with_capacity(q);
    let mut compute_real = 0.0;
    let mut sources = Vec::with_capacity(b);
    for i in 0..q {
        let (rs, re) = block_range(n, b, i);
        let sw = crate::util::Stopwatch::start();
        sources.clear();
        sources.extend(rs..re);
        let mut panel =
            dijkstra::multi_source_with_policy(&csr, &sources, workers, policy.as_ref());
        // Square and slice the panel into its UT blocks. Geodesics are
        // finite here: connectivity was checked against the same graph.
        // The shared in-place squaring keeps this path bit-identical to
        // the implicit panel source, which squares the same panels.
        super::panels::square_panel(&mut panel);
        for j in i..q {
            let (cs, ce) = block_range(n, b, j);
            blocks.push((BlockId::new(i, j), panel.slice(0, re - rs, cs, ce)));
        }
        let secs = sw.secs();
        compute_real += secs;
        panel_tasks.push(crate::engine::clock::Task { node: ctx.node_of(i, q), duration: secs });
    }

    // Account the panel computation like any other stage: measured
    // durations replay onto the virtual cluster, plus the driver's
    // per-task scheduling charge.
    let virtual_span = ctx.run_stage(&panel_tasks);
    let driver_time = ctx.charge_driver("geo:dijkstra", q, 0);
    ctx.push_metrics(crate::engine::metrics::StageMetrics {
        name: "geo:dijkstra".to_string(),
        tasks: q,
        compute_real,
        virtual_span,
        shuffle_bytes: 0,
        network_time: 0.0,
        driver_time,
    });

    let parts = default_partitions(q, ctx.cluster().total_cores());
    let part: Arc<dyn crate::engine::Partitioner> =
        Arc::new(UpperTriangularPartitioner::new(q, parts));
    let a = ctx.parallelize("geo:blocks", blocks, part);
    a.persist("G")?;
    Ok(a)
}

/// [`solve_sparse`] with the panel fan-out executed on real worker
/// processes over the dist transport (`isospark run --workers ...`).
///
/// The driver broadcasts the kNN lists once; each worker rebuilds the
/// CSR graph (a deterministic construction) and runs the *same*
/// `multi_source` + `square_panel` kernels the local path runs, so a
/// panel is a pure function of the broadcast state and the output is
/// bit-identical to the single-process run for any worker count —
/// `f64::to_le_bytes` round-trips every value exactly, and panels are
/// gathered by block-row index regardless of which worker computed them.
///
/// Accounting runs both clocks: worker-measured compute durations replay
/// onto the virtual cluster exactly like the local path's measurements
/// (stage `geo:dijkstra`), while the measured TCP reality — wall-clock,
/// shuffle bytes, retries, worker losses — lands in a `geo:dist` stage
/// row and in [`crate::dist::RemoteCluster::report`] so the run report
/// can print the model next to its ground truth.
pub fn solve_sparse_dist(
    ctx: &SparkContext,
    remote: &crate::dist::RemoteCluster,
    lists: &[Vec<Neighbor>],
    n: usize,
    cfg: &IsomapConfig,
) -> Result<BlockRdd<Matrix>> {
    use super::{block_range, default_partitions, num_blocks};
    use crate::dist::task::{encode_geo_job, TaskSpec, GEO_JOB};
    use crate::engine::partitioner::UpperTriangularPartitioner;

    if lists.len() != n {
        anyhow::bail!("dist geodesics: {} kNN lists for n = {n} points", lists.len());
    }
    // Validate connectivity on the driver against the same CSR the
    // workers will rebuild from the broadcast lists.
    let csr = CsrGraph::from_knn_lists(lists).context("dist geodesics: CSR construction")?;
    csr.require_connected().context("dist geodesics")?;
    let b = cfg.block;
    let q = num_blocks(n, b);

    let sw_stage = crate::util::Stopwatch::start();
    remote
        .broadcast(GEO_JOB, &encode_geo_job(n, b, lists))
        .context("dist geodesics: broadcast kNN graph")?;

    let specs: Vec<TaskSpec> =
        (0..q).map(|i| TaskSpec::GeodesicPanel { block: i as u64 }).collect();
    let policy = ctx.task_policy();
    let panels = remote
        .run_stage("geo:dijkstra", &specs, policy.as_ref())
        .context("dist geodesics: panel stage")?;
    let stage_wall = sw_stage.secs();

    // Slice each squared panel into its UT blocks — the same layout the
    // local path produces, so everything downstream is path-agnostic.
    let mut blocks: Vec<(BlockId, Matrix)> =
        Vec::with_capacity(crate::engine::partitioner::ut_count(q));
    let mut panel_tasks = Vec::with_capacity(q);
    let mut compute_real = 0.0;
    for (i, (secs, panel)) in panels.into_iter().enumerate() {
        let (rs, re) = block_range(n, b, i);
        if panel.nrows() != re - rs || panel.ncols() != n {
            anyhow::bail!(
                "dist geodesics: worker returned a {}×{} panel for block {i} (want {}×{n})",
                panel.nrows(),
                panel.ncols(),
                re - rs
            );
        }
        for j in i..q {
            let (cs, ce) = block_range(n, b, j);
            blocks.push((BlockId::new(i, j), panel.slice(0, re - rs, cs, ce)));
        }
        compute_real += secs;
        panel_tasks.push(crate::engine::clock::Task { node: ctx.node_of(i, q), duration: secs });
    }

    // Virtual projection: replay the worker-measured durations onto the
    // simulated cluster, exactly as the local path replays its own.
    let virtual_span = ctx.run_stage(&panel_tasks);
    remote.add_virtual_span(virtual_span);
    let driver_time = ctx.charge_driver("geo:dijkstra", q, 0);
    ctx.push_metrics(crate::engine::metrics::StageMetrics {
        name: "geo:dijkstra".to_string(),
        tasks: q,
        compute_real,
        virtual_span,
        shuffle_bytes: 0,
        network_time: 0.0,
        driver_time,
    });
    // Measured ground truth beside the projection: the real TCP stage.
    let r = remote.report();
    ctx.push_metrics(crate::engine::metrics::StageMetrics {
        name: "geo:dist".to_string(),
        tasks: q,
        compute_real: 0.0,
        virtual_span: 0.0,
        shuffle_bytes: r.bytes_sent + r.bytes_received,
        network_time: stage_wall,
        driver_time: 0.0,
    });

    let parts = default_partitions(q, ctx.cluster().total_cores());
    let part: Arc<dyn crate::engine::Partitioner> =
        Arc::new(UpperTriangularPartitioner::new(q, parts));
    let a = ctx.parallelize("geo:blocks", blocks, part);
    a.persist("G")?;
    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::config::ClusterConfig;
    use crate::coordinator::{block_range, knn};
    use crate::data::swiss_roll;
    use crate::engine::SparkContext;

    /// Run kNN+APSP through the engine and densify the result
    /// (square-rooted back to geodesic distances).
    fn engine_geodesics(n: usize, b: usize, k: usize, checkpoint_every: usize) -> (Matrix, Matrix) {
        let ds = swiss_roll::euler_isometric(n, 21);
        let ctx = SparkContext::new(ClusterConfig::local());
        let cfg = IsomapConfig { k, block: b, checkpoint_every, ..Default::default() };
        let backend = Backend::Native;
        let kg = knn::build(&ctx, &ds.points, &cfg, &backend).unwrap();
        let a = solve(kg.graph, kg.q, &cfg, &backend).unwrap();
        let mut dense = Matrix::full(n, n, f64::INFINITY);
        for (id, blk) in a.iter() {
            let (rs, _) = block_range(n, b, id.i);
            let (cs, _) = block_range(n, b, id.j);
            for r in 0..blk.nrows() {
                for c in 0..blk.ncols() {
                    let v = blk[(r, c)].sqrt();
                    dense[(rs + r, cs + c)] = v;
                    dense[(cs + c, rs + r)] = v;
                }
            }
        }
        (ds.points, dense)
    }

    fn reference_geodesics(x: &Matrix, k: usize) -> Matrix {
        let g = baselines::knn_graph_dense(&baselines::brute_knn(x, k));
        baselines::dijkstra_apsp(&g)
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f64) {
        for i in 0..a.nrows() {
            for j in 0..a.ncols() {
                let (x, y) = (a[(i, j)], b[(i, j)]);
                if x.is_infinite() || y.is_infinite() {
                    assert!(x.is_infinite() && y.is_infinite(), "({i},{j}): {x} vs {y}");
                } else {
                    assert!((x - y).abs() <= tol, "({i},{j}): {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn matches_dijkstra_divisible() {
        let (x, got) = engine_geodesics(48, 16, 6, 10);
        let want = reference_geodesics(&x, 6);
        assert_close(&got, &want, 1e-9);
    }

    #[test]
    fn matches_dijkstra_ragged_blocks() {
        let (x, got) = engine_geodesics(50, 16, 6, 10);
        let want = reference_geodesics(&x, 6);
        assert_close(&got, &want, 1e-9);
    }

    #[test]
    fn matches_dijkstra_single_block() {
        // q = 1: only Phase 1 runs.
        let (x, got) = engine_geodesics(20, 32, 5, 10);
        let want = reference_geodesics(&x, 5);
        assert_close(&got, &want, 1e-9);
    }

    #[test]
    fn checkpoint_cadence_does_not_change_results() {
        let (_, a) = engine_geodesics(40, 8, 5, 0); // never checkpoint
        let (_, b) = engine_geodesics(40, 8, 5, 2); // every 2 iters
        assert_close(&a, &b, 0.0);
    }

    #[test]
    fn many_small_blocks() {
        // Large q stresses the 3-phase replication logic.
        let (x, got) = engine_geodesics(42, 5, 6, 3);
        let want = reference_geodesics(&x, 6);
        assert_close(&got, &want, 1e-9);
    }

    /// Sparse path: kNN lists -> CSR -> pooled Dijkstra panels, densified
    /// back to geodesic distances (square-rooted).
    fn sparse_geodesics(n: usize, b: usize, k: usize, workers: usize) -> (Matrix, Matrix) {
        let ds = swiss_roll::euler_isometric(n, 21);
        let ctx = SparkContext::new(ClusterConfig {
            parallelism: workers,
            ..ClusterConfig::local()
        });
        let cfg = IsomapConfig { k, block: b, ..Default::default() };
        let kl = knn::build_lists(&ctx, &ds.points, &cfg, &Backend::Native).unwrap();
        let a = solve_sparse(&ctx, &kl.lists, n, &cfg).unwrap();
        let dense = crate::coordinator::dense_from_blocks(&a, n, b).map(|v| v.sqrt());
        (ds.points, dense)
    }

    #[test]
    fn sparse_matches_dense_fw() {
        // Same seed/config as `engine_geodesics`, so the two engine paths
        // are compared on the identical kNN graph.
        let (_, dense_fw) = engine_geodesics(50, 16, 6, 10);
        let (x, sparse) = sparse_geodesics(50, 16, 6, 1);
        assert_close(&sparse, &dense_fw, 1e-9);
        let want = reference_geodesics(&x, 6);
        assert_close(&sparse, &want, 1e-9);
    }

    #[test]
    fn sparse_pool_size_is_invisible() {
        let (_, serial) = sparse_geodesics(53, 16, 6, 1);
        for workers in [2, 4, 7] {
            let (_, pooled) = sparse_geodesics(53, 16, 6, workers);
            assert_close(&pooled, &serial, 0.0); // bitwise
        }
    }

    #[test]
    fn sparse_rejects_disconnected_graph() {
        // Two far-apart blobs at tiny k: the dense path reports this at
        // centering; the sparse path must bail up front, with context.
        let x = crate::data::clusters::gaussian_clusters(30, 3, 2, 0.01, 3).points;
        let ctx = SparkContext::new(ClusterConfig::local());
        let cfg = IsomapConfig { k: 2, block: 8, ..Default::default() };
        let kl = knn::build_lists(&ctx, &x, &cfg, &Backend::Native).unwrap();
        let err = solve_sparse(&ctx, &kl.lists, 30, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("disconnected"), "{err:#}");
    }

    #[test]
    fn sparse_metrics_account_the_geo_stage() {
        let ds = swiss_roll::euler_isometric(40, 21);
        let ctx = SparkContext::new(ClusterConfig::local());
        let cfg = IsomapConfig { k: 6, block: 16, ..Default::default() };
        let kl = knn::build_lists(&ctx, &ds.points, &cfg, &Backend::Native).unwrap();
        let _ = solve_sparse(&ctx, &kl.lists, 40, &cfg).unwrap();
        let geo = ctx.stage_aggregate("geo");
        assert!(geo.tasks >= kl.q, "geo stage tasks = {}", geo.tasks);
        assert!(geo.compute_real >= 0.0);
    }
}
