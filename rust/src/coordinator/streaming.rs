//! Streaming Isomap — the companion method the paper discusses in §V
//! (Schoeneman et al., SDM 2017): learn a faithful manifold from an
//! initial batch, then map new points arriving on a stream in O(k·m) each,
//! without re-running the O(n³) pipeline. "Both methods could be combined
//! in case when the initial batch is large" — this module is that
//! combination: the batch model comes from the distributed exact pipeline.

use crate::backend::Backend;
use crate::config::{ClusterConfig, IsomapConfig};
use crate::kernels::kselect::row_topk;
use crate::linalg::{jacobi, Matrix};
use crate::util::Rng;
use anyhow::{bail, Context, Result};

/// A fitted streaming model: batch data + landmark geodesic tables.
pub struct StreamingModel {
    /// Batch points (n × D), kept for kNN of incoming points.
    batch: Matrix,
    /// Landmark indices into the batch.
    landmarks: Vec<usize>,
    /// Squared geodesic distances landmark → every batch point (m × n).
    delta: Matrix,
    /// Mean squared landmark-landmark distance per landmark (δ̄).
    mean_delta: Vec<f64>,
    /// Landmark MDS eigenpairs used for triangulation.
    eigvals: Vec<f64>,
    eigvecs: Matrix,
    /// Output dimensionality.
    d: usize,
    /// Neighborhood size used for incoming points.
    k: usize,
    /// Batch embedding (n × d) — triangulated, same frame as new points.
    pub batch_embedding: Matrix,
}

impl StreamingModel {
    /// Fit the model: run the distributed kNN stage on the batch, select
    /// `m` landmarks, Dijkstra their geodesics, landmark MDS.
    pub fn fit(
        x: &Matrix,
        cfg: &IsomapConfig,
        m: usize,
        cluster: &ClusterConfig,
        backend: &Backend,
    ) -> Result<StreamingModel> {
        let n = x.nrows();
        cfg.validate(n)?;
        if m < cfg.d + 1 || m > n {
            bail!("landmark count m={m} out of range");
        }
        let ctx = crate::engine::SparkContext::new(cluster.clone());
        let kg = super::knn::build(&ctx, x, cfg, backend).context("kNN stage")?;
        if crate::eval::components(&kg.lists) != 1 {
            bail!("batch kNN graph disconnected; increase k");
        }

        // Symmetric sparse adjacency.
        let mut adj: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        for (i, list) in kg.lists.iter().enumerate() {
            for &(dist, j) in list {
                adj[i].push((j, dist));
                adj[j].push((i, dist));
            }
        }

        let mut rng = Rng::seed(cfg.seed);
        let landmarks = rng.sample_indices(n, m);
        let mut delta = Matrix::zeros(m, n);
        for (li, &l) in landmarks.iter().enumerate() {
            let dist = dijkstra(&adj, l);
            for (j, dj) in dist.iter().enumerate() {
                if !dj.is_finite() {
                    bail!("landmark {l} cannot reach point {j}");
                }
                delta[(li, j)] = dj * dj;
            }
        }

        // Landmark MDS.
        let mut dl = Matrix::zeros(m, m);
        for a in 0..m {
            for b in 0..m {
                dl[(a, b)] = delta[(a, landmarks[b])];
            }
        }
        let mut mean_delta = vec![0.0; m];
        for a in 0..m {
            mean_delta[a] = (0..m).map(|b| dl[(a, b)]).sum::<f64>() / m as f64;
        }
        crate::kernels::centering::center_full_direct(&mut dl);
        let (vals, vecs) = jacobi::top_d(&dl, cfg.d);
        if vals[cfg.d - 1] <= 0.0 {
            bail!("landmark MDS spectrum not positive: {vals:?}");
        }

        let mut model = StreamingModel {
            batch: x.clone(),
            landmarks,
            delta,
            mean_delta,
            eigvals: vals,
            eigvecs: vecs,
            d: cfg.d,
            k: cfg.k,
            batch_embedding: Matrix::zeros(n, cfg.d),
        };
        // Triangulate the batch itself into the landmark frame.
        for i in 0..n {
            let di: Vec<f64> = (0..m).map(|a| model.delta[(a, i)]).collect();
            let y = model.triangulate(&di);
            model.batch_embedding.row_mut(i).copy_from_slice(&y);
        }
        Ok(model)
    }

    /// Number of landmarks.
    pub fn num_landmarks(&self) -> usize {
        self.landmarks.len()
    }

    /// Map one new point from the stream: kNN against the batch, geodesics
    /// to landmarks through those neighbors, distance-based triangulation.
    pub fn map_point(&self, p: &[f64]) -> Result<Vec<f64>> {
        if p.len() != self.batch.ncols() {
            bail!("point dimensionality {} != batch D {}", p.len(), self.batch.ncols());
        }
        let n = self.batch.nrows();
        // Distances to every batch point (O(n·D) — the stream fast path).
        let dists: Vec<f64> = (0..n)
            .map(|i| {
                self.batch
                    .row(i)
                    .iter()
                    .zip(p)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        let nbrs = row_topk(&dists, self.k, 0, None);
        // Geodesic to each landmark ≈ min over neighbors of (edge + geo).
        let m = self.landmarks.len();
        let mut dsq = vec![0.0; m];
        for (a, ds) in dsq.iter_mut().enumerate() {
            let mut best = f64::INFINITY;
            for &(edge, j) in &nbrs {
                let geo = self.delta[(a, j)].sqrt();
                best = best.min(edge + geo);
            }
            *ds = best * best;
        }
        Ok(self.triangulate(&dsq))
    }

    /// Map a batch of streaming points.
    pub fn map_points(&self, pts: &Matrix) -> Result<Matrix> {
        let mut out = Matrix::zeros(pts.nrows(), self.d);
        for i in 0..pts.nrows() {
            let y = self.map_point(pts.row(i))?;
            out.row_mut(i).copy_from_slice(&y);
        }
        Ok(out)
    }

    /// L-Isomap triangulation: y = ½·Λ^{-½}·Qᵀ·(δ̄ − δ).
    fn triangulate(&self, dsq: &[f64]) -> Vec<f64> {
        let m = self.landmarks.len();
        (0..self.d)
            .map(|j| {
                let mut acc = 0.0;
                for a in 0..m {
                    acc += self.eigvecs[(a, j)] * (self.mean_delta[a] - dsq[a]);
                }
                0.5 * acc / self.eigvals[j].sqrt()
            })
            .collect()
    }
}

fn dijkstra(adj: &[Vec<(usize, f64)>], src: usize) -> Vec<f64> {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;
    #[derive(PartialEq)]
    struct Item(f64, usize);
    impl Eq for Item {}
    impl Ord for Item {
        fn cmp(&self, o: &Self) -> Ordering {
            o.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
        }
    }
    impl PartialOrd for Item {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    let n = adj.len();
    let mut dist = vec![f64::INFINITY; n];
    let mut heap = BinaryHeap::new();
    dist[src] = 0.0;
    heap.push(Item(0.0, src));
    while let Some(Item(d, u)) = heap.pop() {
        if d > dist[u] {
            continue;
        }
        for &(v, w) in &adj[u] {
            let nd = d + w;
            if nd < dist[v] {
                dist[v] = nd;
                heap.push(Item(nd, v));
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::swiss_roll;
    use crate::eval::procrustes;

    fn fitted(n: usize, m: usize, seed: u64) -> (StreamingModel, crate::data::Dataset) {
        let ds = swiss_roll::euler_isometric(n, seed);
        let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
        let model =
            StreamingModel::fit(&ds.points, &cfg, m, &ClusterConfig::local(), &Backend::Native)
                .unwrap();
        (model, ds)
    }

    #[test]
    fn batch_embedding_recovers_latents() {
        let (model, ds) = fitted(600, 100, 23);
        let err = procrustes(ds.ground_truth.as_ref().unwrap(), &model.batch_embedding);
        assert!(err < 0.05, "batch procrustes = {err}");
    }

    #[test]
    fn streamed_points_land_near_truth() {
        let (model, _) = fitted(600, 100, 31);
        // New points from the same manifold, different seed.
        let fresh = swiss_roll::euler_isometric(200, 97);
        let mapped = model.map_points(&fresh.points).unwrap();
        // Compare in the latent frame: fit the similarity transform on the
        // *batch* only, then apply the same comparison to streamed points —
        // procrustes over the combined set bounds both.
        let err = procrustes(fresh.ground_truth.as_ref().unwrap(), &mapped);
        assert!(err < 0.05, "streamed procrustes = {err}");
    }

    #[test]
    fn stream_mapping_is_fast() {
        let (model, _) = fitted(600, 80, 5);
        let fresh = swiss_roll::euler_isometric(50, 98);
        let sw = crate::util::Stopwatch::start();
        let _ = model.map_points(&fresh.points).unwrap();
        let per_point = sw.secs() / 50.0;
        assert!(per_point < 0.01, "stream path too slow: {per_point}s/pt");
    }

    #[test]
    fn batch_point_maps_to_its_embedding() {
        // A point already in the batch must map (approximately) onto its
        // own batch-embedding position.
        let (model, ds) = fitted(500, 80, 7);
        let y = model.map_point(ds.points.row(123)).unwrap();
        let want = model.batch_embedding.row(123);
        let dist: f64 =
            y.iter().zip(want).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        // Scale-aware tolerance: small fraction of the embedding diameter.
        assert!(dist < 0.5, "self-mapping error {dist}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let (model, _) = fitted(200, 40, 9);
        assert!(model.map_point(&[1.0, 2.0]).is_err()); // wrong D
        let ds = swiss_roll::euler_isometric(50, 1);
        let cfg = IsomapConfig { k: 10, d: 2, block: 16, ..Default::default() };
        assert!(StreamingModel::fit(
            &ds.points,
            &cfg,
            2, // m < d+1
            &ClusterConfig::local(),
            &Backend::Native
        )
        .is_err());
    }
}
