//! Streaming Isomap — the companion method the paper discusses in §V
//! (Schoeneman et al., SDM 2017): learn a faithful manifold from an
//! initial batch, then map new points arriving on a stream in O(k·m) each,
//! without re-running the O(n³) pipeline. "Both methods could be combined
//! in case when the initial batch is large" — this module is that
//! combination: the batch model comes from the distributed exact pipeline.
//!
//! The fit-state itself lives in [`crate::model::FittedModel`] — a
//! serializable struct with `save`/`load` so a fit survives the process
//! (and can be served over HTTP by [`crate::serve`]). This module owns the
//! *fitting*: the distributed kNN stage, landmark selection, landmark
//! geodesics, and landmark MDS. [`StreamingModel`] derefs to the fitted
//! model, so `map_point` / `map_points` / `batch_embedding` read exactly
//! as before.

use crate::backend::Backend;
use crate::config::{ClusterConfig, IsomapConfig, KnnMode};
use crate::engine::metrics::StageMetrics;
use crate::engine::{BlockId, SparkContext};
use crate::graph::{self, CsrGraph};
use crate::linalg::{jacobi, Matrix};
use crate::model::FittedModel;
use crate::util::{Rng, Stopwatch};
use anyhow::{bail, Context, Result};

/// Content fingerprint binding a streaming-fit durable checkpoint to its
/// input: FNV over the batch bytes and every knob that shapes the
/// landmark table δ (m, k, seed, kNN front end, forest params). A
/// checkpoint directory reused across datasets or configs can never serve
/// a stale table — a different input hashes to a different job key and
/// simply finds no checkpoint.
fn delta_job_key(x: &Matrix, cfg: &IsomapConfig, m: usize) -> String {
    let mut h = crate::data::io::Fnv1a64::new();
    h.update(&(x.nrows() as u64).to_le_bytes());
    h.update(&(x.ncols() as u64).to_le_bytes());
    for v in x.as_slice() {
        h.update(&v.to_le_bytes());
    }
    h.update(&(m as u64).to_le_bytes());
    h.update(&(cfg.k as u64).to_le_bytes());
    h.update(&cfg.seed.to_le_bytes());
    h.update(&[(cfg.knn == KnnMode::RpForest) as u8]);
    h.update(&(cfg.rp_trees as u64).to_le_bytes());
    h.update(&(cfg.rp_leaf_resolved() as u64).to_le_bytes());
    format!("stream-{:016x}", h.finish())
}

/// Try to restore the landmark table δ from the latest valid durable
/// checkpoint under `job`. Shape-guarded: anything unexpected falls back
/// to recomputation (the fit is always able to proceed from scratch).
fn restore_delta(ctx: &SparkContext, job: &str, m: usize, n: usize) -> Option<Matrix> {
    let store = ctx.checkpoint_store()?;
    let sw = Stopwatch::start();
    let (_, mut blocks) = store.latest_valid(job)?;
    if blocks.len() != 1 {
        return None;
    }
    let (_, delta) = blocks.pop()?;
    if delta.nrows() != m || delta.ncols() != n {
        return None;
    }
    ctx.resilience().record_restore();
    ctx.push_metrics(StageMetrics {
        name: "checkpoint:restore".to_string(),
        tasks: 1,
        compute_real: 0.0,
        virtual_span: 0.0,
        shuffle_bytes: 0,
        network_time: 0.0,
        driver_time: sw.secs(),
    });
    Some(delta)
}

/// Spill the landmark table δ as a single-block durable checkpoint under
/// `job`. A no-op without a configured checkpoint directory.
fn save_delta(ctx: &SparkContext, job: &str, delta: &Matrix) -> Result<()> {
    let Some(store) = ctx.checkpoint_store() else {
        return Ok(());
    };
    let sw = Stopwatch::start();
    let bytes = store.save(job, 1, &[(BlockId::new(0, 0), delta)])?;
    ctx.resilience().record_spill(bytes);
    ctx.push_metrics(StageMetrics {
        name: "checkpoint:durable".to_string(),
        tasks: 1,
        compute_real: 0.0,
        virtual_span: 0.0,
        shuffle_bytes: 0,
        network_time: 0.0,
        driver_time: sw.secs(),
    });
    Ok(())
}

/// A fitted streaming model: batch data + landmark geodesic tables,
/// wrapped around the serializable [`FittedModel`].
pub struct StreamingModel {
    model: FittedModel,
    fit_report: String,
}

impl std::ops::Deref for StreamingModel {
    type Target = FittedModel;
    fn deref(&self) -> &FittedModel {
        &self.model
    }
}

impl StreamingModel {
    /// Fit the model: run the distributed kNN stage on the batch, select
    /// `m` landmarks, Dijkstra their geodesics, landmark MDS.
    pub fn fit(
        x: &Matrix,
        cfg: &IsomapConfig,
        m: usize,
        cluster: &ClusterConfig,
        backend: &Backend,
    ) -> Result<StreamingModel> {
        let n = x.nrows();
        cfg.validate(n)?;
        if m < cfg.d + 1 || m > n {
            bail!("landmark count m={m} out of range");
        }
        let ctx = crate::engine::SparkContext::new(cluster.clone());
        // Lists-only kNN: the fit needs the neighbor lists, never the
        // dense blocked neighborhood graph.
        let kl = super::knn::build_lists(&ctx, x, cfg, backend).context("kNN stage")?;
        if crate::eval::components(&kl.lists) != 1 {
            bail!("batch kNN graph disconnected; increase k");
        }

        let mut rng = Rng::seed(cfg.seed);
        let landmarks = rng.sample_indices(n, m);
        // Landmark geodesics: m pooled Dijkstra sources over the CSR
        // graph — past the kNN stage, the only dense state is the m × n
        // landmark table.
        let csr = CsrGraph::from_knn_lists(&kl.lists).context("CSR construction")?;
        // Landmark table δ: restored bitwise from the latest valid durable
        // checkpoint when one exists for this exact (batch, config) input,
        // else computed and spilled for the next attempt. Restore skips
        // the m pooled Dijkstra sources — the dominant post-kNN cost.
        let job = delta_job_key(x, cfg, m);
        let delta = match restore_delta(&ctx, &job, m, n) {
            Some(delta) => delta,
            None => {
                let policy = ctx.task_policy();
                let delta = graph::geodesics_squared_with_policy(
                    &csr,
                    &landmarks,
                    ctx.parallelism(),
                    policy.as_ref(),
                )
                .context("landmark geodesics")?;
                save_delta(&ctx, &job, &delta).context("durable checkpoint of landmark table")?;
                delta
            }
        };
        let fit_report = format!(
            "knn: {}\ngeodesics: sparse-dijkstra (CSR: {} arcs over {n} points; {m} pooled \
             sources)\n{}",
            kl.path.describe(),
            csr.num_edges(),
            ctx.metrics_report(&["knn"]),
        );

        // Landmark MDS.
        let mut dl = Matrix::zeros(m, m);
        for a in 0..m {
            for b in 0..m {
                dl[(a, b)] = delta[(a, landmarks[b])];
            }
        }
        let mut mean_delta = vec![0.0; m];
        for a in 0..m {
            mean_delta[a] = (0..m).map(|b| dl[(a, b)]).sum::<f64>() / m as f64;
        }
        crate::kernels::centering::center_full_direct(&mut dl);
        let (vals, vecs) = jacobi::top_d(&dl, cfg.d);
        if vals[cfg.d - 1] <= 0.0 {
            bail!("landmark MDS spectrum not positive: {vals:?}");
        }

        let mut model = FittedModel {
            batch: x.clone(),
            landmarks,
            delta,
            mean_delta,
            eigvals: vals,
            eigvecs: vecs,
            d: cfg.d,
            k: cfg.k,
            batch_embedding: Matrix::zeros(n, cfg.d),
        };
        // Triangulate the batch itself into the landmark frame.
        for i in 0..n {
            let di: Vec<f64> = (0..m).map(|a| model.delta[(a, i)]).collect();
            let y = model.triangulate(&di);
            model.batch_embedding.row_mut(i).copy_from_slice(&y);
        }
        Ok(StreamingModel { model, fit_report })
    }

    /// Human-readable summary of how the fit was computed: which
    /// geodesics path ran (always the CSR sparse path) and the kNN stage
    /// metrics. Surfaced by `isospark fit` / `isospark stream`.
    pub fn fit_report(&self) -> &str {
        &self.fit_report
    }

    /// Borrow the serializable fit-state.
    pub fn model(&self) -> &FittedModel {
        &self.model
    }

    /// Extract the serializable fit-state (e.g. to [`FittedModel::save`]).
    pub fn into_model(self) -> FittedModel {
        self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::swiss_roll;
    use crate::eval::procrustes;

    fn fitted(n: usize, m: usize, seed: u64) -> (StreamingModel, crate::data::Dataset) {
        let ds = swiss_roll::euler_isometric(n, seed);
        let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
        let model =
            StreamingModel::fit(&ds.points, &cfg, m, &ClusterConfig::local(), &Backend::Native)
                .unwrap();
        (model, ds)
    }

    #[test]
    fn batch_embedding_recovers_latents() {
        let (model, ds) = fitted(600, 100, 23);
        let err = procrustes(ds.ground_truth.as_ref().unwrap(), &model.batch_embedding);
        assert!(err < 0.05, "batch procrustes = {err}");
        // The fit reports its geodesics path and kNN stage metrics.
        assert!(model.fit_report().contains("sparse-dijkstra"), "{}", model.fit_report());
        assert!(model.fit_report().contains("knn"));
    }

    #[test]
    fn rp_forest_fit_recovers_latents_and_reports_path() {
        // The streaming fit inherits the rp-forest front end through
        // `build_lists` — no streaming-specific wiring required.
        use crate::config::KnnMode;
        let ds = swiss_roll::euler_isometric(600, 23);
        let cfg =
            IsomapConfig { k: 10, d: 2, block: 64, knn: KnnMode::RpForest, ..Default::default() };
        let model =
            StreamingModel::fit(&ds.points, &cfg, 100, &ClusterConfig::local(), &Backend::Native)
                .unwrap();
        let err = procrustes(ds.ground_truth.as_ref().unwrap(), &model.batch_embedding);
        assert!(err < 0.05, "batch procrustes = {err}");
        assert!(model.fit_report().contains("rp-forest"), "{}", model.fit_report());
        assert!(model.fit_report().contains("knn:rpforest"));
    }

    #[test]
    fn streamed_points_land_near_truth() {
        let (model, _) = fitted(600, 100, 31);
        // New points from the same manifold, different seed.
        let fresh = swiss_roll::euler_isometric(200, 97);
        let mapped = model.map_points(&fresh.points).unwrap();
        // Compare in the latent frame: fit the similarity transform on the
        // *batch* only, then apply the same comparison to streamed points —
        // procrustes over the combined set bounds both.
        let err = procrustes(fresh.ground_truth.as_ref().unwrap(), &mapped);
        assert!(err < 0.05, "streamed procrustes = {err}");
    }

    #[test]
    fn stream_mapping_is_fast() {
        let (model, _) = fitted(600, 80, 5);
        let fresh = swiss_roll::euler_isometric(50, 98);
        let sw = crate::util::Stopwatch::start();
        let _ = model.map_points(&fresh.points).unwrap();
        let per_point = sw.secs() / 50.0;
        assert!(per_point < 0.01, "stream path too slow: {per_point}s/pt");
    }

    #[test]
    fn batch_point_maps_to_its_embedding() {
        // A point already in the batch must map (approximately) onto its
        // own batch-embedding position.
        let (model, ds) = fitted(500, 80, 7);
        let y = model.map_point(ds.points.row(123)).unwrap();
        let want = model.batch_embedding.row(123);
        let dist: f64 =
            y.iter().zip(want).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        // Scale-aware tolerance: small fraction of the embedding diameter.
        assert!(dist < 0.5, "self-mapping error {dist}");
    }

    #[test]
    fn map_points_parallel_pool_is_bit_identical() {
        // The pooled path must agree with the serial path bit-for-bit for
        // any worker count (this is what makes batched serving safe).
        let (model, _) = fitted(600, 80, 13);
        let fresh = swiss_roll::euler_isometric(300, 99);
        let seq = model.map_points_with(&fresh.points, 1).unwrap();
        for workers in [2, 5, 8] {
            let par = model.map_points_with(&fresh.points, workers).unwrap();
            for (a, b) in seq.as_slice().iter().zip(par.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
            }
        }
    }

    #[test]
    fn fit_restores_landmark_table_bitwise_from_durable_checkpoint() {
        let dir = std::env::temp_dir()
            .join(format!("isospark_stream_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ds = swiss_roll::euler_isometric(300, 23);
        let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
        let cluster = ClusterConfig {
            checkpoint_dir: Some(dir.to_string_lossy().into_owned()),
            ..ClusterConfig::local()
        };
        let first =
            StreamingModel::fit(&ds.points, &cfg, 60, &cluster, &Backend::Native).unwrap();
        // The fit spilled its landmark table under a content-keyed job dir.
        let jobs: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(jobs.len(), 1, "one stream-<fingerprint> job dir expected");
        // A second fit restores δ from disk instead of recomputing — and
        // must be bit-identical to both the first fit and a fit that never
        // saw a checkpoint directory.
        let second =
            StreamingModel::fit(&ds.points, &cfg, 60, &cluster, &Backend::Native).unwrap();
        let plain = StreamingModel::fit(
            &ds.points,
            &cfg,
            60,
            &ClusterConfig::local(),
            &Backend::Native,
        )
        .unwrap();
        for (a, b) in first.delta.as_slice().iter().zip(second.delta.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in
            plain.batch_embedding.as_slice().iter().zip(second.batch_embedding.as_slice())
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_bad_inputs() {
        let (model, _) = fitted(200, 40, 9);
        assert!(model.map_point(&[1.0, 2.0]).is_err()); // wrong D
        let ds = swiss_roll::euler_isometric(50, 1);
        let cfg = IsomapConfig { k: 10, d: 2, block: 16, ..Default::default() };
        assert!(StreamingModel::fit(
            &ds.points,
            &cfg,
            2, // m < d+1
            &ClusterConfig::local(),
            &Backend::Native
        )
        .is_err());
    }
}
