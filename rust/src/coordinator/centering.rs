//! Distributed double centering (paper §III-C).
//!
//! The feature matrix is symmetric, so only column sums are reduced:
//! every block contributes its column sums keyed by block column `J` (and,
//! for off-diagonal blocks, its row sums keyed by `I` — the transposed
//! contribution of the never-materialized lower triangle). Partial sums
//! are `reduceByKey`-ed, collected to the driver, turned into means,
//! broadcast back, and applied block-wise with the MDS `-½` factor.

use super::block_range;
use crate::backend::Backend;
use crate::engine::{BlockId, BlockRdd};
use crate::kernels::centering::{col_sums, row_sums};
use crate::linalg::Matrix;
use anyhow::{bail, Result};

/// Double-center the feature matrix; returns the centered blocks and the
/// broadcast column means (useful for diagnostics).
pub fn center(
    feature: BlockRdd<Matrix>,
    n: usize,
    b: usize,
    backend: &Backend,
) -> Result<(BlockRdd<Matrix>, Vec<f64>)> {
    let ctx = feature.context();

    // Per-block partial sums: key (J,0) carries column sums, and for
    // off-diagonal (I,J) key (I,0) additionally carries row sums (the
    // columns of the transposed block under the diagonal).
    let partials = feature.flat_map("center:sums", |id, blk| {
        let mut out = vec![(BlockId::new(id.j, 0), col_sums(blk))];
        if id.i != id.j {
            out.push((BlockId::new(id.i, 0), row_sums(blk)));
        }
        out
    });
    let reduced = partials.reduce_by_key("center:reduce", feature.partitioner(), |mut a, c| {
        for (x, y) in a.iter_mut().zip(&c) {
            *x += y;
        }
        a
    });

    // Driver: assemble means (reduce + collectAsMap in the paper).
    let collected = reduced.collect();
    let (mu, grand) = means_from_sums(collected.into_iter().map(|(id, s)| (id.i, s)), n, b)?;

    // Broadcast the means vector to the executors.
    ctx.broadcast("center:means", (n as u64) * 8 + 8);

    // Apply: a ← −½ (a − μ_row − μ_col + μ̂), per block. In place through
    // copy-on-write: the feature RDD is consumed here and its blocks have
    // no other owner, so no block is ever cloned (the apply stage used to
    // copy every block before writing it).
    let mu_apply = mu.clone();
    let centered = feature.update_values("center:apply", move |id, blk| {
        let (rs, re) = block_range(n, b, id.i);
        let (cs, ce) = block_range(n, b, id.j);
        backend.center_block(blk, &mu_apply[rs..re], &mu_apply[cs..ce], grand);
    });
    centered.persist("G")?;
    Ok((centered, mu))
}

/// Turn per-block-row column sums into the centering means: `μ_j` (column
/// means) and the grand mean `μ̂`. Factored out of [`center`] so the
/// implicit panel source (`super::panels`) derives *bit-identical* means
/// from its streamed sums — the division and the `μ̂` summation order here
/// are part of the determinism contract between the two feature paths.
pub(crate) fn means_from_sums(
    sums: impl IntoIterator<Item = (usize, Vec<f64>)>,
    n: usize,
    b: usize,
) -> Result<(Vec<f64>, f64)> {
    let mut mu = vec![0.0f64; n];
    for (i, sums) in sums {
        let (s, e) = block_range(n, b, i);
        if sums.len() != e - s {
            bail!("centering: block {i} produced {} sums for {} columns", sums.len(), e - s);
        }
        for (dst, v) in mu[s..e].iter_mut().zip(&sums) {
            if !v.is_finite() {
                bail!(
                    "centering: infinite column sum — the kNN graph is disconnected; increase k"
                );
            }
            *dst = v / n as f64;
        }
    }
    let grand = mu.iter().sum::<f64>() / n as f64;
    Ok((mu, grand))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, IsomapConfig};
    use crate::coordinator::{apsp, knn};
    use crate::data::swiss_roll;
    use crate::engine::SparkContext;
    use crate::kernels::centering::center_full_direct;

    /// Dense symmetric matrix from UT blocks.
    fn densify(rdd: &BlockRdd<Matrix>, n: usize, b: usize) -> Matrix {
        let mut out = Matrix::zeros(n, n);
        for (id, blk) in rdd.iter() {
            let (rs, _) = block_range(n, b, id.i);
            let (cs, _) = block_range(n, b, id.j);
            for r in 0..blk.nrows() {
                for c in 0..blk.ncols() {
                    out[(rs + r, cs + c)] = blk[(r, c)];
                    out[(cs + c, rs + r)] = blk[(r, c)];
                }
            }
        }
        out
    }

    #[test]
    fn blocked_centering_matches_dense() {
        let n = 45;
        let b = 16;
        let ds = swiss_roll::euler_isometric(n, 5);
        let ctx = SparkContext::new(ClusterConfig::local());
        let cfg = IsomapConfig { k: 8, block: b, ..Default::default() };
        let be = Backend::Native;
        let kg = knn::build(&ctx, &ds.points, &cfg, &be).unwrap();
        let a = apsp::solve(kg.graph, kg.q, &cfg, &be).unwrap();
        let dense_a = densify(&a, n, b);

        let (centered, mu) = center(a, n, b, &be).unwrap();
        let got = densify(&centered, n, b);

        let mut want = dense_a.clone();
        center_full_direct(&mut want);
        assert!(got.max_abs_diff(&want) < 1e-9);

        // Means diagnostics are the actual column means.
        let expect_mu = dense_a.col_means();
        for (a, b) in mu.iter().zip(&expect_mu) {
            assert!((a - b).abs() < 1e-9);
        }

        // Row/col means of the centered matrix are ~0.
        for i in 0..n {
            let rm: f64 = got.row(i).iter().sum::<f64>() / n as f64;
            assert!(rm.abs() < 1e-9);
        }
    }

    #[test]
    fn disconnected_graph_reports_error() {
        // Two far-apart Gaussian blobs with tiny k disconnect the graph.
        let x = crate::data::clusters::gaussian_clusters(30, 3, 2, 0.01, 3).points;
        let ctx = SparkContext::new(ClusterConfig::local());
        let cfg = IsomapConfig { k: 2, block: 8, ..Default::default() };
        let be = Backend::Native;
        let kg = knn::build(&ctx, &x, &cfg, &be).unwrap();
        assert!(!crate::eval::connectivity(&kg.lists));
        let a = apsp::solve(kg.graph, kg.q, &cfg, &be).unwrap();
        let err = center(a, 30, 8, &be).unwrap_err();
        assert!(format!("{err:#}").contains("disconnected"));
    }
}
