//! End-to-end exact Isomap (paper Alg. 1) over the dataflow engine.
//!
//! `Y = Q_d · Λ_d^{∘½}` from the top-`d` eigenpairs of the double-centered
//! squared-geodesic matrix. (Alg. 2 of the paper types the eigenvalue
//! scaling as `diag(R^{∘½})` *and* Alg. 1 squares it again — a typo chain;
//! we implement the standard classical-MDS scaling `√λ`, which reproduces
//! their Procrustes result.)

use super::{centering, eigen, knn, num_blocks, panels};
use crate::backend::Backend;
use crate::config::{ClusterConfig, FeatureMode, GeodesicsMode, IsomapConfig};
use crate::engine::metrics::OffloadOpSnapshot;
use crate::engine::SparkContext;
use crate::linalg::Matrix;
use anyhow::{Context, Result};

/// Everything a caller needs from a run.
#[derive(Debug)]
pub struct IsomapOutput {
    /// The `n × d` embedding.
    pub embedding: Matrix,
    /// Top-`d` eigenvalue estimates of the centered feature matrix.
    pub eigenvalues: Vec<f64>,
    /// Power iterations used / convergence flag.
    pub eigen_iterations: usize,
    pub eigen_converged: bool,
    /// Logical block count `q = ⌈n/b⌉`.
    pub q: usize,
    /// Connected components of the kNN graph (must be 1 for a valid run).
    pub graph_components: usize,
    /// Which geodesics path ran (`dense-fw` blocked Floyd–Warshall or
    /// `sparse-dijkstra` over the CSR graph).
    pub geodesics: GeodesicsMode,
    /// Which kNN front end ran (`exact` all-pairs or `rp-forest`), with
    /// the forest's candidate counters when approximate.
    pub knn: knn::KnnPath,
    /// Which feature-matrix residency ran (`materialized` blocks or
    /// `implicit` streamed panels).
    pub feature: FeatureMode,
    /// High-water mark of cluster-wide resident bytes over the run — the
    /// measured side of the memory model: O(n²) materialized, O(n·k + b·n)
    /// implicit.
    pub peak_resident_bytes: u64,
    /// Implicit mode: geodesic panels produced by running Dijkstra
    /// (0 in materialized mode).
    pub panel_recomputes: usize,
    /// Implicit mode: panels served from the durable spill instead of
    /// recomputed (0 without `--checkpoint-dir`).
    pub panel_spill_reads: usize,
    /// Virtual wall-clock of the simulated cluster, seconds.
    pub virtual_secs: f64,
    /// Total bytes shuffled across the simulated network.
    pub shuffle_bytes: u64,
    /// Measured single-core compute seconds (all tasks).
    pub compute_secs: f64,
    /// Per-stage metrics table (text).
    pub metrics_table: String,
    /// Per-op PJRT offload counters at pipeline end (`None` for the
    /// native backend). With artifacts present for block size `b`, every
    /// ragged block op is served through the padded path and `missed`
    /// stays 0 — the offload-coverage acceptance criterion.
    pub offload: Option<Vec<OffloadOpSnapshot>>,
    /// Measured ground truth of the distributed geodesic stage when the
    /// run used real worker processes (`--workers`); `None` for
    /// single-process runs. The run report prints its wall-clock next to
    /// the virtual-clock projection.
    pub dist: Option<crate::dist::DistReport>,
}

/// Run the full pipeline on a fresh context. Convenience wrapper over
/// [`run_with`] using the native backend.
pub fn run(x: &Matrix, cfg: &IsomapConfig, cluster: &ClusterConfig) -> Result<IsomapOutput> {
    run_with(x, cfg, cluster, &Backend::Native)
}

/// Run the full pipeline with an explicit compute backend.
pub fn run_with(
    x: &Matrix,
    cfg: &IsomapConfig,
    cluster: &ClusterConfig,
    backend: &Backend,
) -> Result<IsomapOutput> {
    let n = x.nrows();
    cfg.validate(n)?;
    let ctx = SparkContext::new(cluster.clone());

    // Real worker processes, if configured. Only the sparse geodesic
    // panel stage has a remote task vocabulary (it dominates the exact
    // pipeline's compute), so dist runs require that path explicitly
    // rather than silently falling back to local execution.
    let remote = if cluster.dist_workers.is_empty() {
        None
    } else {
        if cfg.geodesics != GeodesicsMode::SparseDijkstra || cfg.feature != FeatureMode::Materialized
        {
            anyhow::bail!(
                "--workers requires --geodesics sparse-dijkstra with the materialized feature \
                 path: the distributed stage ships geodesic row panels to worker processes"
            );
        }
        Some(
            crate::dist::RemoteCluster::connect(&crate::dist::DistConfig {
                workers: cluster.dist_workers.clone(),
                task_timeout_secs: cluster.dist_task_timeout_secs,
                connect_timeout_secs: cluster.dist_connect_timeout_secs,
                max_attempts: cluster.fault_max_attempts,
            })
            .context("dist: connect to workers")?,
        )
    };

    // Stages 1–4 through the configured feature residency.
    //
    // Materialized (the default): kNN, then the squared-geodesic feature
    // matrix as resident blocks (dense: neighborhood-graph blocks ->
    // blocked Floyd–Warshall; sparse: kNN lists -> CSR -> pooled
    // multi-source Dijkstra row panels), double centering over the blocks,
    // power iteration over the centered RDD.
    //
    // Implicit: kNN lists -> CSR only. The panel source folds one panel
    // sweep into the centering means, then recomputes (or spill-re-reads)
    // panels inside every power-iteration matvec, centering on the fly —
    // the dense feature matrix is never resident. Bit-identical to the
    // materialized sparse-dijkstra run on the same graph.
    let (graph_components, knn_path, eig, panel_recomputes, panel_spill_reads) = match cfg.feature
    {
        FeatureMode::Implicit => {
            let kl = knn::build_lists(&ctx, x, cfg, backend).context("kNN stage")?;
            let components = crate::eval::components(&kl.lists);
            let src = panels::Implicit::build(&ctx, &kl.lists, n, cfg, backend)
                .context("implicit feature stage")?;
            let eig = eigen::power_iteration(&src, cfg.d, cfg.tol, cfg.max_iter)
                .context("eigendecomposition stage")?;
            (components, kl.path, eig, src.recomputes(), src.spill_reads())
        }
        FeatureMode::Materialized => {
            let (components, knn_path, a) = match cfg.geodesics {
                GeodesicsMode::DenseFw => {
                    let kg = knn::build(&ctx, x, cfg, backend).context("kNN stage")?;
                    let components = crate::eval::components(&kg.lists);
                    let a =
                        super::apsp::solve(kg.graph, kg.q, cfg, backend).context("APSP stage")?;
                    (components, kg.path, a)
                }
                GeodesicsMode::SparseDijkstra => {
                    let kl = knn::build_lists(&ctx, x, cfg, backend).context("kNN stage")?;
                    let components = crate::eval::components(&kl.lists);
                    let a = match &remote {
                        Some(rc) => super::apsp::solve_sparse_dist(&ctx, rc, &kl.lists, n, cfg)
                            .context("distributed geodesics stage")?,
                        None => super::apsp::solve_sparse(&ctx, &kl.lists, n, cfg)
                            .context("sparse geodesics stage")?,
                    };
                    (components, kl.path, a)
                }
            };

            // Stage 3: double centering.
            let (centered, _mu) =
                centering::center(a, n, cfg.block, backend).context("centering stage")?;

            // Stage 4: spectral decomposition.
            let eig = eigen::simultaneous_power_iteration(
                &centered, n, cfg.block, cfg.d, cfg.tol, cfg.max_iter, backend,
            )
            .context("eigendecomposition stage")?;
            (components, knn_path, eig, 0, 0)
        }
    };

    // Y = Q_d · diag(√λ)  (λ clamped at 0: tiny negatives can appear for
    // non-Euclidean geodesic matrices).
    let mut embedding = Matrix::zeros(n, cfg.d);
    for i in 0..n {
        for j in 0..cfg.d {
            embedding[(i, j)] = eig.q[(i, j)] * eig.eigenvalues[j].max(0.0).sqrt();
        }
    }

    Ok(IsomapOutput {
        embedding,
        eigenvalues: eig.eigenvalues,
        eigen_iterations: eig.iterations,
        eigen_converged: eig.converged,
        q: num_blocks(n, cfg.block),
        graph_components,
        geodesics: cfg.geodesics,
        knn: knn_path,
        feature: cfg.feature,
        peak_resident_bytes: ctx.peak_resident_bytes(),
        panel_recomputes,
        panel_spill_reads,
        virtual_secs: ctx.virtual_now(),
        shuffle_bytes: ctx.total_shuffle_bytes(),
        compute_secs: ctx.total_compute_real(),
        metrics_table: ctx
            .metrics_report(&["knn", "geo", "apsp", "center", "eigen", "feat", "checkpoint"]),
        offload: backend.offload_snapshot(),
        dist: remote.map(|rc| rc.report()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::data::swiss_roll;
    use crate::eval::procrustes;

    #[test]
    fn matches_reference_isomap() {
        // The distributed pipeline and the dense single-node reference must
        // produce the same embedding up to a similarity transform.
        let ds = swiss_roll::euler_isometric(60, 31);
        let cfg = IsomapConfig { k: 7, d: 2, block: 16, ..Default::default() };
        let out = run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
        let reference = baselines::reference_isomap(&ds.points, 7, 2);
        let err = procrustes(&reference.embedding, &out.embedding);
        assert!(err < 1e-8, "procrustes vs reference = {err}");
    }

    #[test]
    fn recovers_swiss_roll_latents() {
        // Dense enough that the kNN graph has no coil shortcuts.
        let ds = swiss_roll::euler_isometric(600, 13);
        let cfg = IsomapConfig { k: 10, d: 2, block: 128, ..Default::default() };
        let out = run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
        assert_eq!(out.graph_components, 1);
        assert!(out.eigen_converged);
        let err = procrustes(ds.ground_truth.as_ref().unwrap(), &out.embedding);
        // Paper reports 2.67e-5 at n=50k; n=600 lands in the low 1e-3s.
        assert!(err < 1e-2, "procrustes vs ground truth = {err}");
        // Rectangle spectrum: λ1/λ2 ≈ (31/6)² — assert a clear gap.
        assert!(out.eigenvalues[0] > 5.0 * out.eigenvalues[1]);
    }

    #[test]
    fn output_shape_and_spectrum() {
        let ds = swiss_roll::euler_isometric(40, 17);
        let cfg = IsomapConfig { k: 6, d: 3, block: 16, ..Default::default() };
        let out = run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
        assert_eq!(out.embedding.nrows(), 40);
        assert_eq!(out.embedding.ncols(), 3);
        assert!(out.eigenvalues[0] >= out.eigenvalues[1]);
        assert!(out.eigenvalues[1] >= out.eigenvalues[2]);
        assert!(out.virtual_secs >= 0.0);
        assert!(out.metrics_table.contains("apsp"));
        assert!(out.offload.is_none(), "native backend has no offload counters");
    }

    #[test]
    fn rejects_invalid_config() {
        let ds = swiss_roll::euler_isometric(20, 1);
        let cfg = IsomapConfig { k: 25, ..Default::default() };
        assert!(run(&ds.points, &cfg, &ClusterConfig::local()).is_err());
    }

    #[test]
    fn rp_forest_pipeline_recovers_latents() {
        // The fully sub-quadratic pipeline — rp-forest candidates + sparse
        // Dijkstra geodesics — must still unroll the swiss roll.
        use crate::config::KnnMode;
        let ds = swiss_roll::euler_isometric(600, 13);
        let cfg = IsomapConfig {
            k: 10,
            d: 2,
            block: 128,
            knn: KnnMode::RpForest,
            geodesics: GeodesicsMode::SparseDijkstra,
            ..Default::default()
        };
        let out = run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
        assert_eq!(out.graph_components, 1);
        assert!(out.knn.describe().contains("rp-forest"), "knn: {}", out.knn.describe());
        assert!(out.metrics_table.contains("knn:rpforest"));
        let err = procrustes(ds.ground_truth.as_ref().unwrap(), &out.embedding);
        assert!(err < 1e-2, "procrustes vs ground truth = {err}");
    }

    #[test]
    fn sparse_mode_matches_dense_mode() {
        // The two geodesics paths compute the same feature matrix up to
        // floating-point path-association, so the embeddings must agree to
        // high precision (and the sparse run must report its path and a
        // populated `geo` stage in place of `apsp` work).
        let ds = swiss_roll::euler_isometric(120, 31);
        let dense_cfg = IsomapConfig { k: 8, d: 2, block: 32, ..Default::default() };
        let sparse_cfg = IsomapConfig {
            geodesics: GeodesicsMode::SparseDijkstra,
            ..dense_cfg.clone()
        };
        let dense = run(&ds.points, &dense_cfg, &ClusterConfig::local()).unwrap();
        let sparse = run(&ds.points, &sparse_cfg, &ClusterConfig::local()).unwrap();
        assert_eq!(dense.geodesics, GeodesicsMode::DenseFw);
        assert_eq!(sparse.geodesics, GeodesicsMode::SparseDijkstra);
        let err = procrustes(&dense.embedding, &sparse.embedding);
        assert!(err < 1e-8, "dense vs sparse procrustes = {err}");
        assert!(sparse.metrics_table.contains("geo"));
        // No APSP shuffle rounds ran on the sparse path.
        assert_eq!(sparse.graph_components, 1);
    }
}
