//! Feature-matrix sources for the pipeline's back half (centering +
//! power iteration): resident blocks or streamed geodesic panels.
//!
//! Centering and simultaneous power iteration never need the squared-
//! geodesic feature matrix `A` as a value — only two folds over it: the
//! column sums (for the centering means) and the per-iteration product
//! `V = A·Q`. [`FeatureSource`] abstracts exactly that access pattern, and
//! two implementations provide it:
//!
//! * [`Materialized`] — today's upper-triangular [`BlockRdd`] of resident
//!   blocks, `O(n²)` memory, the default and the reference semantics.
//! * [`Implicit`] — recomputes (or spills once and re-reads) `b × n`
//!   geodesic row panels on demand from the CSR kNN graph via pooled
//!   multi-source Dijkstra. The dense feature matrix is never
//!   materialized: peak memory is `O(n·k)` for the CSR graph plus
//!   `O(b·n)` for the one live panel, at the price of one Dijkstra sweep
//!   per power iteration (or one disk read with `--checkpoint-dir`).
//!
//! **Bit-determinism contract.** `Implicit` replays the *exact* blocked
//! computation of the materialized sparse-Dijkstra path, panel by panel:
//! the same [`dijkstra::multi_source`] rows, the same squared block
//! slices, the same per-block kernels, and a per-key accumulation order
//! that mirrors `flat_map` emission order plus `reduce_by_key` fold order
//! (first record *becomes* the accumulator; later records fold in arrival
//! order). The embedding is therefore bit-identical to the materialized
//! run on the same graph — for any worker count, under fault injection,
//! and across the spill/recompute variants — which is what lets CI `cmp`
//! the two runs' CSVs byte for byte.

use super::{block_range, centering, num_blocks};
use crate::backend::Backend;
use crate::config::IsomapConfig;
use crate::engine::clock::Task;
use crate::engine::durable::CheckpointStore;
use crate::engine::executor::run_tasks_with_policy;
use crate::engine::metrics::StageMetrics;
use crate::engine::{BlockId, BlockRdd, SparkContext};
use crate::graph::{dijkstra, CsrGraph};
use crate::kernels::centering::{col_sums, row_sums};
use crate::kernels::kselect::Neighbor;
use crate::linalg::Matrix;
use crate::util::Stopwatch;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Stage name charged for every panel recompute / spill re-read; fault
/// injection, retry, and the metrics table all see panels under this key.
pub const PANEL_STAGE: &str = "feat:panel";

/// Elements of `V` below which the per-iteration collect+paste stays on
/// the driver thread: a scoped pool spawn costs tens of µs, so the copy
/// must be ≥ ~1 MiB (2¹⁷ f64) before fanning it out pays.
const PARALLEL_PASTE_MIN: usize = 1 << 17;

/// Read access to the centered feature matrix, shaped as the only two
/// things the back half of the pipeline ever does with it.
pub trait FeatureSource {
    /// Number of points (rows of the virtual `n × n` feature matrix).
    fn n(&self) -> usize;

    /// One power-iteration step `V = A·Q` over the *centered* features,
    /// including the per-iteration broadcast of `Q` to the executors.
    fn matvec(&self, q: &Matrix) -> Result<Matrix>;

    /// Human description for run reports.
    fn describe(&self) -> String;
}

/// Square a geodesic panel element-wise in place (`d → d²`, the feature
/// entries double centering consumes). Shared with the materialized
/// sparse path so both square with the identical per-element operation.
pub(crate) fn square_panel(panel: &mut Matrix) {
    for v in panel.as_mut_slice() {
        *v *= *v;
    }
}

// ---------------------------------------------------------------------------
// Materialized: resident upper-triangular blocks (the default).
// ---------------------------------------------------------------------------

/// The resident-block source: today's centered upper-triangular
/// [`BlockRdd`], wrapped behind [`FeatureSource`]. Each matvec is the
/// engine's blocked product — broadcast `Q`, `flat_map` per-block GEMMs,
/// `reduce_by_key` into per-block-row `V` slices, collect + paste.
pub struct Materialized<'a> {
    a: &'a BlockRdd<Matrix>,
    n: usize,
    b: usize,
    backend: &'a Backend,
}

impl<'a> Materialized<'a> {
    /// Wrap a centered feature RDD (`n` points in blocks of `b`).
    pub fn new(a: &'a BlockRdd<Matrix>, n: usize, b: usize, backend: &'a Backend) -> Self {
        Self { a, n, b, backend }
    }
}

impl FeatureSource for Materialized<'_> {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, q: &Matrix) -> Result<Matrix> {
        let (n, b, backend) = (self.n, self.b, self.backend);
        let d = q.ncols();
        let ctx = self.a.context();

        // Driver broadcasts the whole Qᶦ⁻¹ to all executors.
        ctx.broadcast("eigen:q", (n as u64) * (d as u64) * 8);

        // Executors: blocked product V = A·Q. Block (I,J) contributes
        // A^{(I,J)}·Q_J to V_I and, off-diagonal, (A^{(I,J)})ᵀ·Q_I to V_J
        // (the transposed yield for upper-triangular storage).
        let q_ref = &q;
        let products = self.a.flat_map("eigen:matvec", move |id, blk| {
            let (rs, re) = block_range(n, b, id.i);
            let (cs, ce) = block_range(n, b, id.j);
            let qj = q_ref.slice(cs, ce, 0, d);
            let mut c = Matrix::zeros(re - rs, d);
            backend.gemm_acc(blk, &qj, &mut c);
            let mut out = vec![(BlockId::new(id.i, 0), c)];
            if id.i != id.j {
                let qi = q_ref.slice(rs, re, 0, d);
                let mut ct = Matrix::zeros(ce - cs, d);
                backend.gemm_t_acc(blk, &qi, &mut ct);
                out.push((BlockId::new(id.j, 0), ct));
            }
            out
        });
        let v_blocks = products.reduce_by_key("eigen:reduce", self.a.partitioner(), |mut x, y| {
            for (xa, ya) in x.as_mut_slice().iter_mut().zip(y.as_slice()) {
                *xa += ya;
            }
            x
        });

        // Driver: collect V. The V blocks tile the rows exactly (one per
        // block-row, BTreeMap-sorted by index). Above the copy-size
        // threshold, V's row-major buffer is carved into disjoint spans
        // and the paste runs on the worker pool instead of a serial
        // driver loop; tiny V (the practical d ≤ 4 embeddings) stays
        // serial — a scoped thread spawn per iteration would dwarf the
        // memcpy it parallelizes.
        let collected = v_blocks.collect();
        let mut v = Matrix::zeros(n, d);
        let workers = ctx.parallelism().max(1);
        if workers == 1 || n * d < PARALLEL_PASTE_MIN {
            for (id, blk) in &collected {
                let (rs, _) = block_range(n, b, id.i);
                v.paste(rs, 0, blk);
            }
        } else {
            let mut tasks = Vec::with_capacity(collected.len());
            let mut rest: &mut [f64] = v.as_mut_slice();
            let mut next_row = 0usize;
            for (id, blk) in &collected {
                let (rs, re) = block_range(n, b, id.i);
                debug_assert_eq!(rs, next_row, "eigen: V blocks must tile the rows");
                let (span, tail) = std::mem::take(&mut rest).split_at_mut((re - rs) * d);
                tasks.push((span, blk));
                rest = tail;
                next_row = re;
            }
            debug_assert_eq!(next_row, n, "eigen: V blocks must cover all rows");
            let policy = ctx.task_policy();
            run_tasks_with_policy(policy.as_ref(), "eigen:paste", workers, tasks, |(span, blk)| {
                span.copy_from_slice(blk.as_slice())
            });
        }
        Ok(v)
    }

    fn describe(&self) -> String {
        format!("materialized (resident upper-triangular blocks, b = {})", self.b)
    }
}

// ---------------------------------------------------------------------------
// Implicit: geodesic row panels recomputed / re-read on demand.
// ---------------------------------------------------------------------------

/// Content fingerprint binding spilled panels to their input graph: FNV
/// over `n`, `b`, and every CSR adjacency entry. A `--checkpoint-dir`
/// reused across different datasets or block sizes hashes to a different
/// job key and simply finds no spill.
fn graph_fingerprint(csr: &CsrGraph, n: usize, b: usize) -> u64 {
    let mut h = crate::data::io::Fnv1a64::new();
    h.update(&(n as u64).to_le_bytes());
    h.update(&(b as u64).to_le_bytes());
    for u in 0..csr.n() {
        let (cols, weights) = csr.neighbors(u);
        h.update(&(cols.len() as u64).to_le_bytes());
        for (&v, &w) in cols.iter().zip(weights) {
            h.update(&v.to_le_bytes());
            h.update(&w.to_le_bytes());
        }
    }
    h.finish()
}

/// Fold a partial-sums vector into a per-block-row accumulator with the
/// engine's `reduce_by_key` semantics: the first record *becomes* the
/// accumulator (no zero-init, so `0 + (−0)` sign hazards never arise),
/// later records add element-wise in arrival order.
fn fold_sums(acc: &mut Option<Vec<f64>>, partial: Vec<f64>) {
    match acc {
        None => *acc = Some(partial),
        Some(a) => {
            for (x, y) in a.iter_mut().zip(&partial) {
                *x += y;
            }
        }
    }
}

/// Add a per-block contribution into `V`'s row span for block-row `key`,
/// mirroring `eigen:reduce`: the first contribution is copied in
/// wholesale, later ones add element-wise over the row-major span.
fn fold_matvec(v: &mut Matrix, touched: &mut [bool], key: usize, rs: usize, d: usize, c: &Matrix) {
    let span = &mut v.as_mut_slice()[rs * d..rs * d + c.nrows() * d];
    if touched[key] {
        for (x, y) in span.iter_mut().zip(c.as_slice()) {
            *x += y;
        }
    } else {
        span.copy_from_slice(c.as_slice());
        touched[key] = true;
    }
}

/// The panel-streamed source (`--feature implicit`): squared-geodesic
/// `b × n` row panels produced on demand from the CSR kNN graph, centered
/// on the fly inside each matvec. Requires `--geodesics sparse-dijkstra`
/// (validated by [`IsomapConfig::validate`]) — the dense Floyd–Warshall
/// path must materialize every block to run at all.
///
/// With `--checkpoint-dir` set, the construction sweep additionally
/// spills each squared panel through [`CheckpointStore`] (checksummed,
/// manifest-last), and later sweeps re-read instead of recomputing; a
/// missing or corrupt spill silently degrades to recompute. Both
/// variants produce bit-identical panels — durable blocks round-trip
/// bit-exactly through the little-endian f64 format.
pub struct Implicit<'a> {
    ctx: SparkContext,
    csr: CsrGraph,
    n: usize,
    b: usize,
    /// Logical block count `q = ⌈n/b⌉`.
    qb: usize,
    /// Broadcast column means of the squared-geodesic matrix.
    mu: Vec<f64>,
    /// Grand mean `μ̂`.
    grand: f64,
    backend: &'a Backend,
    /// Durable spill target + content-bound job key, when configured.
    spill: Option<(CheckpointStore, String)>,
    /// Panels produced by running Dijkstra (including the build sweep).
    recomputes: AtomicUsize,
    /// Panels served from the durable spill instead.
    spill_reads: AtomicUsize,
}

impl<'a> Implicit<'a> {
    /// Build the source from kNN lists: CSR construction + connectivity
    /// check, then one panel sweep folding column sums into the centering
    /// means (spilling each squared panel when a checkpoint store is
    /// configured). Charges the same `center:means` broadcast as the
    /// materialized centering stage.
    pub fn build(
        ctx: &SparkContext,
        lists: &[Vec<Neighbor>],
        n: usize,
        cfg: &IsomapConfig,
        backend: &'a Backend,
    ) -> Result<Self> {
        if lists.len() != n {
            bail!("implicit features: {} kNN lists for n = {n} points", lists.len());
        }
        let csr = CsrGraph::from_knn_lists(lists).context("implicit features: CSR construction")?;
        csr.require_connected().context("implicit features")?;
        let b = cfg.block;
        let qb = num_blocks(n, b);
        let spill = ctx.checkpoint_store().map(|store| {
            let job = format!("feat-{:016x}", graph_fingerprint(&csr, n, b));
            (store, job)
        });
        // The CSR graph is broadcast state: every executor holds a copy.
        ctx.set_resident("feat:csr", vec![csr.nbytes(); ctx.nodes()])
            .context("implicit features: CSR graph")?;

        let src = Self {
            ctx: ctx.clone(),
            csr,
            n,
            b,
            qb,
            mu: Vec::new(),
            grand: 0.0,
            backend,
            spill,
            recomputes: AtomicUsize::new(0),
            spill_reads: AtomicUsize::new(0),
        };

        // Column-sums sweep, replaying the materialized `center:sums` +
        // `center:reduce` record order exactly: panels ascending, blocks
        // (I,J), J ≥ I within each panel, column sums keyed J then row
        // sums keyed I — so each key sees col partials from blocks
        // (0,K)…(K,K) followed by row partials from (K,K+1)…(K,q−1),
        // which is the flat_map arrival order the reduce folds in.
        let mut sums: Vec<Option<Vec<f64>>> = (0..qb).map(|_| None).collect();
        src.sweep(true, &mut |i, rows, panel| {
            for j in i..qb {
                let (cs, ce) = block_range(n, b, j);
                let blk = panel.slice(0, rows, cs, ce);
                fold_sums(&mut sums[j], col_sums(&blk));
                if i != j {
                    fold_sums(&mut sums[i], row_sums(&blk));
                }
            }
            Ok(())
        })?;
        let collected = sums
            .into_iter()
            .enumerate()
            .map(|(i, s)| (i, s.expect("every block row contributes column sums")));
        let (mu, grand) = centering::means_from_sums(collected, n, b)?;
        src.ctx.broadcast("center:means", (n as u64) * 8 + 8);

        Ok(Self { mu, grand, ..src })
    }

    /// Broadcast column means (diagnostics; bit-identical to the means
    /// the materialized centering stage computes on the same graph).
    pub fn mu(&self) -> &[f64] {
        &self.mu
    }

    /// Grand mean `μ̂` of the squared-geodesic matrix.
    pub fn grand(&self) -> f64 {
        self.grand
    }

    /// Panels produced by running Dijkstra (any sweep).
    pub fn recomputes(&self) -> usize {
        self.recomputes.load(Ordering::Relaxed)
    }

    /// Panels served from the durable spill.
    pub fn spill_reads(&self) -> usize {
        self.spill_reads.load(Ordering::Relaxed)
    }

    /// Squared-geodesic panel for block-row `i` by pooled multi-source
    /// Dijkstra, charged to [`PANEL_STAGE`] for fault injection/retry.
    fn recompute_panel(&self, i: usize) -> Matrix {
        let (rs, re) = block_range(self.n, self.b, i);
        let sources: Vec<usize> = (rs..re).collect();
        let policy = self.ctx.task_policy();
        let workers = self.ctx.parallelism();
        let mut panel =
            dijkstra::multi_source_stage(&self.csr, &sources, workers, policy.as_ref(), PANEL_STAGE);
        square_panel(&mut panel);
        self.recomputes.fetch_add(1, Ordering::Relaxed);
        panel
    }

    /// Squared panel `i`: served from the durable spill when present and
    /// valid (checksums + shape), recomputed otherwise.
    fn panel_squared(&self, i: usize) -> Matrix {
        let (rs, re) = block_range(self.n, self.b, i);
        if let Some((store, job)) = &self.spill {
            if let Ok(mut blocks) = store.load(job, i) {
                if blocks.len() == 1 {
                    let (_, panel) = blocks.pop().expect("len checked");
                    if panel.nrows() == re - rs && panel.ncols() == self.n {
                        self.spill_reads.fetch_add(1, Ordering::Relaxed);
                        return panel;
                    }
                }
            }
        }
        self.recompute_panel(i)
    }

    /// One full pass over the panels, ascending. `per_panel` receives
    /// `(block_row, rows, squared_panel)`. Handles the residency model
    /// (one live panel at a time, on its block-row's node), the
    /// [`PANEL_STAGE`] accounting (measured durations replayed on the
    /// virtual cluster + driver charge), and — on the build sweep
    /// (`save`) — the durable spill, reported as a `feat:spill` row.
    fn sweep(
        &self,
        save: bool,
        per_panel: &mut dyn FnMut(usize, usize, &Matrix) -> Result<()>,
    ) -> Result<()> {
        let qb = self.qb;
        let mut tasks = Vec::with_capacity(qb);
        let mut compute_real = 0.0;
        let mut spill_secs = 0.0;
        let mut spill_tasks = 0usize;
        for i in 0..qb {
            let (rs, re) = block_range(self.n, self.b, i);
            let sw = Stopwatch::start();
            let panel = if save { self.recompute_panel(i) } else { self.panel_squared(i) };
            let mut per = vec![0u64; self.ctx.nodes()];
            per[self.ctx.node_of(i, qb)] = (panel.nrows() * panel.ncols() * 8) as u64;
            self.ctx.set_resident(PANEL_STAGE, per).context("implicit features: live panel")?;
            per_panel(i, re - rs, &panel)?;
            if save {
                if let Some((store, job)) = &self.spill {
                    let ssw = Stopwatch::start();
                    let bytes = store
                        .save(job, i, &[(BlockId::new(i, 0), &panel)])
                        .with_context(|| format!("spill feature panel {i}"))?;
                    self.ctx.resilience().record_spill(bytes);
                    spill_secs += ssw.secs();
                    spill_tasks += 1;
                }
            }
            self.ctx.clear_resident(PANEL_STAGE);
            let secs = sw.secs();
            compute_real += secs;
            tasks.push(Task { node: self.ctx.node_of(i, qb), duration: secs });
        }
        let virtual_span = self.ctx.run_stage(&tasks);
        let driver_time = self.ctx.charge_driver(PANEL_STAGE, qb, 0);
        self.ctx.push_metrics(StageMetrics {
            name: PANEL_STAGE.to_string(),
            tasks: qb,
            compute_real,
            virtual_span,
            shuffle_bytes: 0,
            network_time: 0.0,
            driver_time,
        });
        if spill_tasks > 0 {
            // Informational: the spill time is also inside the panel
            // durations above; this row isolates the disk share.
            self.ctx.push_metrics(StageMetrics {
                name: "feat:spill".to_string(),
                tasks: spill_tasks,
                compute_real: 0.0,
                virtual_span: 0.0,
                shuffle_bytes: 0,
                network_time: 0.0,
                driver_time: spill_secs,
            });
        }
        Ok(())
    }
}

impl FeatureSource for Implicit<'_> {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, q: &Matrix) -> Result<Matrix> {
        let (n, b, qb) = (self.n, self.b, self.qb);
        let d = q.ncols();
        self.ctx.broadcast("eigen:q", (n as u64) * (d as u64) * 8);

        // Per-key contribution order mirrors the materialized path: for
        // block-row K, transposed yields from blocks (0,K)…(K−1,K), then
        // direct yields from (K,K)…(K,q−1) — exactly the `eigen:matvec`
        // emission order the `eigen:reduce` fold consumes.
        let mut v = Matrix::zeros(n, d);
        let mut touched = vec![false; qb];
        self.sweep(false, &mut |i, rows, panel| {
            let (rs, re) = block_range(n, b, i);
            for j in i..qb {
                let (cs, ce) = block_range(n, b, j);
                let mut blk = panel.slice(0, rows, cs, ce);
                // Centering on the fly: −½(a − μ_r − μ_c + μ̂), the same
                // kernel the materialized `center:apply` stage ran once.
                self.backend.center_block(&mut blk, &self.mu[rs..re], &self.mu[cs..ce], self.grand);
                let qj = q.slice(cs, ce, 0, d);
                let mut c = Matrix::zeros(re - rs, d);
                self.backend.gemm_acc(&blk, &qj, &mut c);
                fold_matvec(&mut v, &mut touched, i, rs, d, &c);
                if i != j {
                    let qi = q.slice(rs, re, 0, d);
                    let mut ct = Matrix::zeros(ce - cs, d);
                    self.backend.gemm_t_acc(&blk, &qi, &mut ct);
                    fold_matvec(&mut v, &mut touched, j, cs, d, &ct);
                }
            }
            Ok(())
        })?;
        Ok(v)
    }

    fn describe(&self) -> String {
        let variant = if self.spill.is_some() {
            "spilled once, re-read per pass"
        } else {
            "recomputed per pass"
        };
        format!(
            "implicit ({}×{} geodesic panels {variant}; dense features never resident)",
            self.b, self.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, GeodesicsMode};
    use crate::coordinator::{apsp, knn};
    use crate::data::swiss_roll;
    use crate::linalg::qr::qr_thin;

    fn swiss_setup(n: usize, b: usize, workers: usize) -> (SparkContext, Matrix, IsomapConfig) {
        let ds = swiss_roll::euler_isometric(n, 13);
        let ctx = SparkContext::new(ClusterConfig {
            parallelism: workers,
            ..ClusterConfig::local()
        });
        let cfg = IsomapConfig {
            k: 8,
            block: b,
            geodesics: GeodesicsMode::SparseDijkstra,
            ..Default::default()
        };
        (ctx, ds.points, cfg)
    }

    #[test]
    fn materialized_matvec_matches_dense_product() {
        let (ctx, x, cfg) = swiss_setup(60, 16, 1);
        let be = Backend::Native;
        let kl = knn::build_lists(&ctx, &x, &cfg, &be).unwrap();
        let a = apsp::solve_sparse(&ctx, &kl.lists, 60, &cfg).unwrap();
        let dense = crate::coordinator::dense_from_blocks(&a, 60, 16);
        let src = Materialized::new(&a, 60, 16, &be);
        let (q0, _) = qr_thin(&Matrix::eye(60, 3));
        let got = src.matvec(&q0).unwrap();
        let want = dense.matmul(&q0);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn implicit_means_and_matvec_bitwise_match_materialized() {
        // Ragged blocks on purpose: 90 = 2·32 + 26.
        let (ctx, x, cfg) = swiss_setup(90, 32, 1);
        let be = Backend::Native;
        let kl = knn::build_lists(&ctx, &x, &cfg, &be).unwrap();

        let a = apsp::solve_sparse(&ctx, &kl.lists, 90, &cfg).unwrap();
        let (centered, mu) = centering::center(a, 90, 32, &be).unwrap();
        let mat = Materialized::new(&centered, 90, 32, &be);

        let imp = Implicit::build(&ctx, &kl.lists, 90, &cfg, &be).unwrap();
        assert_eq!(imp.mu().len(), mu.len());
        for (a, b) in imp.mu().iter().zip(&mu) {
            assert_eq!(a.to_bits(), b.to_bits(), "means must be bit-identical");
        }

        let (q0, _) = qr_thin(&Matrix::eye(90, 2));
        let vm = mat.matvec(&q0).unwrap();
        let vi = imp.matvec(&q0).unwrap();
        for (a, b) in vm.as_slice().iter().zip(vi.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "matvec must be bit-identical");
        }
        assert_eq!(imp.recomputes(), 3 * 2); // build sweep + one matvec sweep
        assert_eq!(imp.spill_reads(), 0);
    }

    #[test]
    fn implicit_worker_count_is_invisible() {
        let run = |workers: usize| -> Vec<u64> {
            let (ctx, x, cfg) = swiss_setup(70, 16, workers);
            let be = Backend::Native;
            let kl = knn::build_lists(&ctx, &x, &cfg, &be).unwrap();
            let imp = Implicit::build(&ctx, &kl.lists, 70, &cfg, &be).unwrap();
            let (q0, _) = qr_thin(&Matrix::eye(70, 2));
            let v = imp.matvec(&q0).unwrap();
            v.as_slice().iter().map(|x| x.to_bits()).collect()
        };
        let serial = run(1);
        for workers in [2, 8] {
            assert_eq!(run(workers), serial, "workers = {workers}");
        }
    }

    #[test]
    fn implicit_charges_the_panel_stage() {
        let (ctx, x, cfg) = swiss_setup(40, 16, 1);
        let be = Backend::Native;
        let kl = knn::build_lists(&ctx, &x, &cfg, &be).unwrap();
        let imp = Implicit::build(&ctx, &kl.lists, 40, &cfg, &be).unwrap();
        let (q0, _) = qr_thin(&Matrix::eye(40, 2));
        let _ = imp.matvec(&q0).unwrap();
        let feat = ctx.stage_aggregate("feat");
        // One build sweep + one matvec sweep over q = 3 panels each.
        assert_eq!(feat.tasks, 6, "feat stage tasks = {}", feat.tasks);
        assert!(ctx.peak_resident_bytes() > 0);
    }

    #[test]
    fn implicit_rejects_disconnected_graph() {
        let x = crate::data::clusters::gaussian_clusters(30, 3, 2, 0.01, 3).points;
        let ctx = SparkContext::new(ClusterConfig::local());
        let cfg = IsomapConfig { k: 2, block: 8, ..Default::default() };
        let kl = knn::build_lists(&ctx, &x, &cfg, &Backend::Native).unwrap();
        let err = Implicit::build(&ctx, &kl.lists, 30, &cfg, &Backend::Native).unwrap_err();
        assert!(format!("{err:#}").contains("disconnected"), "{err:#}");
    }

    #[test]
    fn spilled_panels_round_trip_bitwise() {
        let dir = std::env::temp_dir().join(format!("isospark-panel-spill-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let run = |spill: bool| -> (Vec<u64>, usize, usize) {
            let ds = swiss_roll::euler_isometric(50, 13);
            let cluster = ClusterConfig {
                checkpoint_dir: spill.then(|| dir.to_string_lossy().into_owned()),
                ..ClusterConfig::local()
            };
            let ctx = SparkContext::new(cluster);
            let cfg = IsomapConfig {
                k: 8,
                block: 16,
                geodesics: GeodesicsMode::SparseDijkstra,
                ..Default::default()
            };
            let be = Backend::Native;
            let kl = knn::build_lists(&ctx, &ds.points, &cfg, &be).unwrap();
            let imp = Implicit::build(&ctx, &kl.lists, 50, &cfg, &be).unwrap();
            let (q0, _) = qr_thin(&Matrix::eye(50, 2));
            let v = imp.matvec(&q0).unwrap();
            let bits = v.as_slice().iter().map(|x| x.to_bits()).collect();
            (bits, imp.recomputes(), imp.spill_reads())
        };
        let (clean, rec_clean, reads_clean) = run(false);
        let (spilled, rec_spill, reads_spill) = run(true);
        assert_eq!(clean, spilled, "spill variant must be bit-identical");
        assert_eq!((rec_clean, reads_clean), (8, 0)); // q = 4, two sweeps
        assert_eq!((rec_spill, reads_spill), (4, 4)); // matvec sweep reads
        let _ = std::fs::remove_dir_all(&dir);
    }
}
