//! Blocked kNN search and neighborhood-graph construction (paper §III-A).
//!
//! 1-D decompose `X` into `q` point blocks; enumerate only the
//! upper-triangular block pairs `(I,J), J ≥ I` (exploiting distance-matrix
//! symmetry — the paper's alternative to the wasteful `cartesian`);
//! materialize the distance block matrix `M`; heap-select per-block `L_k`
//! lists (scanning columns of each block for the under-diagonal
//! transposes); merge lists per point; finally reuse `M`'s blocks to store
//! the neighborhood graph `G` (∞-filled, kNN distances set symmetrically).
//!
//! This is the *exact* front end. `--knn rp-forest` swaps the all-pairs
//! distance stage for the seeded random-projection forest in
//! [`crate::knn_approx`] — same output shape, `O(T·n·leaf)` instead of
//! `O(n²)` distance FLOPs — and both [`build`] and [`build_lists`] fork on
//! [`IsomapConfig::knn`], so every caller (exact pipeline, landmark,
//! streaming) gets the approximate path for free. [`KnnPath`] records
//! which front end ran, carrying the forest's candidate counters for the
//! run reports.

use super::{block_range, default_partitions, num_blocks};
use crate::backend::Backend;
use crate::config::{IsomapConfig, KnnMode};
use crate::engine::executor::run_tasks_with_policy;
use crate::engine::partitioner::UpperTriangularPartitioner;
use crate::engine::{BlockId, BlockRdd, SparkContext};
use crate::kernels::kselect::{cols_topk, merge_topk, row_topk, Neighbor};
use crate::knn_approx::{RpForestParams, RpForestStats};
use crate::linalg::Matrix;
use anyhow::Result;
use std::sync::Arc;

/// Points below which the driver-side lists scatter stays serial: the
/// parallel path re-moves every list once (bucketing) plus a scoped pool
/// spawn, which only amortizes once tens of thousands of `Vec` handles
/// are being placed.
const PARALLEL_SCATTER_MIN: usize = 1 << 16;

/// Which front end produced a set of kNN lists, plus its evidence — the
/// `run`/`fit` reports surface this next to the geodesics mode.
#[derive(Clone, Debug)]
pub enum KnnPath {
    /// All-pairs blocked distance stage (the reference answer).
    Exact,
    /// rp-forest candidates, exactly rescored ([`crate::knn_approx`]);
    /// carries the forest's candidate counters and recall proxy.
    RpForest(RpForestStats),
}

impl KnnPath {
    /// One-line human summary for run reports.
    pub fn describe(&self) -> String {
        match self {
            KnnPath::Exact => KnnMode::Exact.describe().to_string(),
            KnnPath::RpForest(stats) => stats.describe(),
        }
    }
}

/// Output of the kNN stage.
pub struct KnnGraph {
    /// Upper-triangular blocks of the neighborhood graph `G` (∞ = no edge,
    /// 0 diagonal).
    pub graph: BlockRdd<Matrix>,
    /// Logical block count `q`.
    pub q: usize,
    /// Global kNN lists (collected to the driver for connectivity checks
    /// and L-Isomap; `n·k` entries, small even at paper scale).
    pub lists: Vec<Vec<Neighbor>>,
    /// Which front end produced the lists.
    pub path: KnnPath,
}

/// Output of the lists-only kNN stage ([`build_lists`]): the global kNN
/// lists without the dense blocked neighborhood graph — the input the
/// sparse-geodesics path (`crate::graph`: CSR + pooled multi-source
/// Dijkstra) consumes. The distance blocks `M` are still computed (that
/// is the paper's kNN algorithm) but are dropped as soon as the lists are
/// merged; the ∞-filled graph blocks `G` are never built.
pub struct KnnLists {
    /// Global kNN lists (`n·k` entries).
    pub lists: Vec<Vec<Neighbor>>,
    /// Logical block count `q`.
    pub q: usize,
    /// Which front end produced the lists.
    pub path: KnnPath,
}

/// Intermediates shared by [`build`] and [`build_lists`]: the pipeline up
/// to (and including) the driver-side assembly of the global lists.
struct ListsStage {
    /// Distance blocks `M` (the dense path reuses their buffers as graph
    /// storage).
    m: BlockRdd<Matrix>,
    /// Per-point merged top-k lists, still distributed.
    knn_lists: BlockRdd<Vec<Neighbor>>,
    /// Collected global lists.
    lists: Vec<Vec<Neighbor>>,
    q: usize,
}

/// Run the blocked kNN stage through the neighborhood-graph fill.
pub fn build(
    ctx: &SparkContext,
    x: &Matrix,
    cfg: &IsomapConfig,
    backend: &Backend,
) -> Result<KnnGraph> {
    let n = x.nrows();
    let b = cfg.block;

    if cfg.knn == KnnMode::RpForest {
        // rp-forest front end feeding the dense geodesics path: the
        // distance blocks M were never materialized, so the graph blocks
        // are freshly allocated and filled from the collected lists.
        let (lists, stats) = rp_lists(ctx, x, cfg)?;
        let q = num_blocks(n, b);
        let parts = default_partitions(q, ctx.cluster().total_cores());
        let part: Arc<dyn crate::engine::Partitioner> =
            Arc::new(UpperTriangularPartitioner::new(q, parts));
        let base_blocks: Vec<(BlockId, Matrix)> = (0..q)
            .flat_map(|i| {
                let (rs, re) = block_range(n, b, i);
                (i..q).map(move |j| {
                    let (cs, ce) = block_range(n, b, j);
                    // Content is irrelevant: graph_fill rewrites wholesale.
                    (BlockId::new(i, j), Matrix::zeros(re - rs, ce - cs))
                })
            })
            .collect();
        let base = ctx.parallelize("knn:graph_base", base_blocks, Arc::clone(&part));
        let list_blocks: Vec<(BlockId, Vec<Neighbor>)> = lists
            .iter()
            .enumerate()
            .map(|(g, list)| (BlockId::new(g / b, g % b), list.clone()))
            .collect();
        let lists_rdd = ctx.parallelize("knn:lists", list_blocks, part);
        let graph = fill_graph(n, b, base, &lists_rdd);
        graph.persist("G")?;
        return Ok(KnnGraph { graph, q, lists, path: KnnPath::RpForest(stats) });
    }

    let st = lists_stage(ctx, x, cfg, backend)?;
    // Neighborhood-graph fill reusing M's blocks as storage.
    let graph = fill_graph(n, b, st.m, &st.knn_lists);
    graph.persist("G")?;
    ctx.clear_resident("M");

    Ok(KnnGraph { graph, q: st.q, lists: st.lists, path: KnnPath::Exact })
}

/// Neighborhood-graph fill shared by both front ends: scatter every list
/// entry to its upper-triangular block (`knn:edges` — edge (i,j) lands in
/// the block with `bi ≤ bj`), then rewrite the base blocks wholesale —
/// ∞ everywhere, 0 diagonal, kNN distances set symmetrically. Base block
/// content is irrelevant; uniquely-held buffers are recycled in place by
/// `make_mut` without a copy.
fn fill_graph(
    n: usize,
    b: usize,
    base: BlockRdd<Matrix>,
    knn_lists: &BlockRdd<Vec<Neighbor>>,
) -> BlockRdd<Matrix> {
    let edges = knn_lists.flat_map("knn:edges", |id, list| {
        let (s, _) = block_range(n, b, id.i);
        let gi = s + id.j;
        let mut out = Vec::with_capacity(list.len());
        for &(dist, gj) in list {
            let (bi, li) = (gi / b, gi % b);
            let (bj, lj) = (gj / b, gj % b);
            if bi <= bj {
                out.push((BlockId::new(bi, bj), (li, lj, dist)));
            } else {
                out.push((BlockId::new(bj, bi), (lj, li, dist)));
            }
        }
        out
    });
    base.join_update("knn:graph_fill", edges, |id, blk, es| {
        let blk = blk.make_mut();
        for v in blk.as_mut_slice() {
            *v = f64::INFINITY;
        }
        if id.i == id.j {
            for r in 0..blk.nrows() {
                blk[(r, r)] = 0.0;
            }
        }
        for (li, lj, d) in es {
            if d < blk[(li, lj)] {
                blk[(li, lj)] = d;
                if id.i == id.j {
                    blk[(lj, li)] = d;
                }
            }
        }
    })
}

/// Run the blocked kNN stage but stop at the global lists: no `knn:edges`
/// shuffle, no graph-fill stage, and the distance blocks are unpersisted
/// immediately — the dense blocked neighborhood graph is never
/// materialized. This is the front end of the sparse-geodesics path.
pub fn build_lists(
    ctx: &SparkContext,
    x: &Matrix,
    cfg: &IsomapConfig,
    backend: &Backend,
) -> Result<KnnLists> {
    if cfg.knn == KnnMode::RpForest {
        let (lists, stats) = rp_lists(ctx, x, cfg)?;
        let q = num_blocks(x.nrows(), cfg.block);
        return Ok(KnnLists { lists, q, path: KnnPath::RpForest(stats) });
    }
    let st = lists_stage(ctx, x, cfg, backend)?;
    ctx.clear_resident("M");
    Ok(KnnLists { lists: st.lists, q: st.q, path: KnnPath::Exact })
}

/// The rp-forest front end run as an engine stage: build + query on the
/// physical worker pool, accounted as `knn:rpforest` — one virtual task
/// per tree (the unit of fan-out), measured wall time split evenly across
/// them, plus the driver's per-task scheduling charge. No simulated
/// shuffle: the forest is a driver-coordinated stage like `geo:dijkstra`,
/// not an RDD lineage.
fn rp_lists(
    ctx: &SparkContext,
    x: &Matrix,
    cfg: &IsomapConfig,
) -> Result<(Vec<Vec<Neighbor>>, RpForestStats)> {
    let params = RpForestParams {
        trees: cfg.rp_trees,
        leaf_size: cfg.rp_leaf_resolved(),
        seed: cfg.seed,
    };
    let sw = crate::util::Stopwatch::start();
    let policy = ctx.task_policy();
    let (lists, stats) = crate::knn_approx::knn_lists_with_policy(
        x,
        cfg.k,
        &params,
        ctx.parallelism(),
        policy.as_ref(),
    )?;
    let secs = sw.secs();
    let tasks: Vec<crate::engine::clock::Task> = (0..params.trees)
        .map(|t| crate::engine::clock::Task {
            node: ctx.node_of(t, params.trees),
            duration: secs / params.trees as f64,
        })
        .collect();
    let virtual_span = ctx.run_stage(&tasks);
    let driver_time = ctx.charge_driver("knn:rpforest", params.trees, 0);
    ctx.push_metrics(crate::engine::metrics::StageMetrics {
        name: "knn:rpforest".to_string(),
        tasks: params.trees,
        compute_real: secs,
        virtual_span,
        shuffle_bytes: 0,
        network_time: 0.0,
        driver_time,
    });
    Ok((lists, stats))
}

/// The shared kNN front end: distance blocks, per-block top-k, global
/// list merge, and the driver-side lists assembly.
fn lists_stage(
    ctx: &SparkContext,
    x: &Matrix,
    cfg: &IsomapConfig,
    backend: &Backend,
) -> Result<ListsStage> {
    let n = x.nrows();
    let b = cfg.block;
    let q = num_blocks(n, b);
    let parts = default_partitions(q, ctx.cluster().total_cores());
    let part: Arc<dyn crate::engine::Partitioner> =
        Arc::new(UpperTriangularPartitioner::new(q, parts));

    // 1-D decomposition: block I holds rows [I·b, min((I+1)b, n)).
    let point_blocks: Vec<(BlockId, Matrix)> = (0..q)
        .map(|i| {
            let (s, e) = block_range(n, b, i);
            (BlockId::new(i, i), x.slice(s, e, 0, x.ncols()))
        })
        .collect();
    let points = ctx.parallelize("knn:points", point_blocks, Arc::clone(&part));

    // Pair enumeration: block I is the left member of (I,J) for J ≥ I and
    // the right member of (K,I) for K < I. Logical replication (q copies
    // of each block) deliberately exposes the parallelism of the distance
    // computation, as in the paper — but the q copies are `Arc` handles to
    // one buffer, so the fan-out is a refcount bump per destination while
    // the simulated shuffle still pays full per-copy bytes.
    let pairs = points.flat_map_arc("knn:pairs", |id, xi| {
        let i = id.i;
        let mut out = Vec::with_capacity(q);
        for j in i..q {
            out.push((BlockId::new(i, j), (i, Arc::clone(xi))));
        }
        for k in 0..i {
            out.push((BlockId::new(k, i), (i, Arc::clone(xi))));
        }
        out
    });
    let grouped = pairs.group_by_key("knn:pairgroup", Arc::clone(&part));

    // Distance blocks M^{(I,J)} = ‖x_i − x_j‖₂ (BLAS-offloaded in the
    // paper; Pallas/native kernel here).
    let m = grouped.map_values("knn:dist", |id, members| {
        // Index both members by origin in one pass (was: two linear
        // `find()` scans over the grouped members per block).
        let mut xi = None;
        let mut xj = None;
        for (origin, pts) in members {
            if *origin == id.i {
                xi = Some(pts);
            }
            if *origin == id.j {
                xj = Some(pts);
            }
        }
        let xi = xi.expect("left member");
        if id.i == id.j {
            backend.dist_block_sym(xi)
        } else {
            backend.dist_block(xi, xj.expect("right member"))
        }
    });
    m.persist("M")?;

    // Per-block L_k lists. Keys are (block-row, local-row): rows of block
    // (I,J) contribute to points of block I; columns contribute to points
    // of block J (the transposed under-diagonal blocks, never materialized).
    let k = cfg.k;
    let local = m.flat_map("knn:topk_local", |id, blk| {
        let (ri, _) = block_range(n, b, id.i);
        let (cj, _) = block_range(n, b, id.j);
        let mut out = Vec::new();
        for r in 0..blk.nrows() {
            let exclude = if id.i == id.j { Some(ri + r) } else { None };
            out.push((BlockId::new(id.i, r), row_topk(blk.row(r), k, cj, exclude)));
        }
        if id.i != id.j {
            // Column side (the never-materialized under-diagonal
            // transposes): one cache-blocked transpose into per-thread
            // scratch, then contiguous-row selection — replaces the
            // per-column strided gather + `Vec` allocation.
            for (c, list) in cols_topk(blk, k, ri).into_iter().enumerate() {
                out.push((BlockId::new(id.j, c), list));
            }
        }
        out
    });
    let knn_lists =
        local.reduce_by_key("knn:topk_merge", Arc::clone(&part), |a, c| merge_topk(k, &[a, c]));

    // Collect the (small) global lists for connectivity/eval use. Above
    // the size threshold the driver-side scatter runs on the worker pool:
    // entries are bucketed by destination chunk so each worker owns a
    // disjoint slice of `lists` (deterministic for any pool size —
    // ownership, not arrival order, decides placement). Small n keeps the
    // old one-pass serial scatter: a pool spawn costs more than moving a
    // few thousand `Vec` handles.
    let collected = knn_lists.collect();
    let workers = ctx.parallelism().max(1);
    let mut lists: Vec<Vec<Neighbor>> = vec![Vec::new(); n];
    if workers == 1 || n < PARALLEL_SCATTER_MIN {
        for (id, list) in collected {
            let (s, _) = block_range(n, b, id.i);
            lists[s + id.j] = list;
        }
    } else {
        let chunk = n.div_ceil(workers).max(1);
        let mut buckets: Vec<Vec<(usize, Vec<Neighbor>)>> = Vec::new();
        buckets.resize_with(n.div_ceil(chunk), Vec::new);
        for (id, list) in collected {
            let (s, _) = block_range(n, b, id.i);
            let g = s + id.j;
            buckets[g / chunk].push((g % chunk, list));
        }
        let tasks: Vec<_> = lists.chunks_mut(chunk).zip(buckets).collect();
        let policy = ctx.task_policy();
        run_tasks_with_policy(
            policy.as_ref(),
            "knn:lists_scatter",
            workers,
            tasks,
            |(slice, items)| {
                for (off, list) in std::mem::take(items) {
                    slice[off] = list;
                }
            },
        );
    }

    Ok(ListsStage { m, knn_lists, lists, q })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::config::ClusterConfig;
    use crate::data::swiss_roll;

    fn run_knn(n: usize, b: usize, k: usize) -> (Matrix, KnnGraph, Matrix) {
        let ds = swiss_roll::euler_isometric(n, 11);
        let ctx = SparkContext::new(ClusterConfig::local());
        let cfg = IsomapConfig { k, block: b, ..Default::default() };
        let g = build(&ctx, &ds.points, &cfg, &Backend::Native).unwrap();
        // Materialize the dense graph from blocks.
        let mut dense = Matrix::full(n, n, f64::INFINITY);
        for (id, blk) in g.graph.iter() {
            let (rs, _) = block_range(n, b, id.i);
            let (cs, _) = block_range(n, b, id.j);
            for r in 0..blk.nrows() {
                for c in 0..blk.ncols() {
                    dense[(rs + r, cs + c)] = blk[(r, c)];
                }
            }
        }
        (ds.points, g, dense)
    }

    fn symmetrized_reference(x: &Matrix, k: usize) -> Matrix {
        baselines::knn_graph_dense(&baselines::brute_knn(x, k))
    }

    #[test]
    fn matches_bruteforce_exact_divisible() {
        let (x, _g, dense) = run_knn(48, 16, 5);
        let want = symmetrized_reference(&x, 5);
        // Upper triangle of dense must equal reference upper triangle.
        for i in 0..48 {
            for j in i..48 {
                let (a, b) = (dense[(i, j)], want[(i, j)]);
                if a.is_infinite() || b.is_infinite() {
                    assert!(a.is_infinite() && b.is_infinite(), "({i},{j}): {a} vs {b}");
                } else {
                    assert!((a - b).abs() < 1e-10, "({i},{j}): {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn matches_bruteforce_ragged() {
        // n not divisible by b exercises the ragged last block.
        let (x, _g, dense) = run_knn(53, 16, 4);
        let want = symmetrized_reference(&x, 4);
        for i in 0..53 {
            for j in i..53 {
                let (a, b) = (dense[(i, j)], want[(i, j)]);
                if a.is_infinite() || b.is_infinite() {
                    assert!(a.is_infinite() && b.is_infinite(), "({i},{j})");
                } else {
                    assert!((a - b).abs() < 1e-10, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn lists_match_bruteforce() {
        let (x, g, _) = run_knn(40, 8, 6);
        let want = baselines::brute_knn(&x, 6);
        for i in 0..40 {
            let got: Vec<usize> = g.lists[i].iter().map(|&(_, j)| j).collect();
            let exp: Vec<usize> = want[i].iter().map(|&(_, j)| j).collect();
            assert_eq!(got, exp, "point {i}");
        }
    }

    #[test]
    fn build_lists_matches_full_build() {
        // The lists-only front end must produce exactly the lists the full
        // build does — it is the same pipeline, stopped before graph-fill.
        let ds = swiss_roll::euler_isometric(60, 11);
        let cfg = IsomapConfig { k: 5, block: 16, ..Default::default() };
        let full = build(
            &SparkContext::new(ClusterConfig::local()),
            &ds.points,
            &cfg,
            &Backend::Native,
        )
        .unwrap();
        let lists_only = build_lists(
            &SparkContext::new(ClusterConfig::local()),
            &ds.points,
            &cfg,
            &Backend::Native,
        )
        .unwrap();
        assert_eq!(lists_only.q, full.q);
        assert_eq!(lists_only.lists, full.lists);
    }

    #[test]
    fn swiss_roll_knn_connected() {
        let (_, g, _) = run_knn(200, 64, 10);
        assert!(crate::eval::connectivity(&g.lists));
    }

    #[test]
    fn rp_forest_lists_recall_and_path() {
        let ds = swiss_roll::euler_isometric(600, 11);
        let cfg = IsomapConfig { k: 8, block: 64, knn: KnnMode::RpForest, ..Default::default() };
        let ctx = SparkContext::new(ClusterConfig::local());
        let kl = build_lists(&ctx, &ds.points, &cfg, &Backend::Native).unwrap();
        assert!(matches!(kl.path, KnnPath::RpForest(_)), "path: {}", kl.path.describe());
        let KnnPath::RpForest(stats) = &kl.path else { unreachable!() };
        assert!(stats.candidate_pairs < 600 * 599 / 2, "must beat all-pairs");
        let exact = baselines::brute_knn(&ds.points, 8);
        let recall = crate::eval::recall_at_k(&kl.lists, &exact, 8);
        assert!(recall >= 0.95, "recall@8 = {recall}");
        // The stage is accounted in the run metrics.
        assert!(ctx.metrics_report(&["knn"]).contains("knn:rpforest"));
    }

    #[test]
    fn rp_forest_dense_graph_consistent_with_lists() {
        // rp-forest + dense-fw: the graph blocks must encode exactly the
        // forest's lists (symmetrized), just as the exact path's do.
        let ds = swiss_roll::euler_isometric(90, 13);
        let cfg = IsomapConfig { k: 5, block: 32, knn: KnnMode::RpForest, ..Default::default() };
        let ctx = SparkContext::new(ClusterConfig::local());
        let g = build(&ctx, &ds.points, &cfg, &Backend::Native).unwrap();
        assert!(matches!(g.path, KnnPath::RpForest(_)));
        let mut dense = Matrix::full(90, 90, f64::INFINITY);
        for (id, blk) in g.graph.iter() {
            let (rs, _) = block_range(90, 32, id.i);
            let (cs, _) = block_range(90, 32, id.j);
            for r in 0..blk.nrows() {
                for c in 0..blk.ncols() {
                    dense[(rs + r, cs + c)] = blk[(r, c)];
                }
            }
        }
        let upper = |i: usize, j: usize| if i <= j { dense[(i, j)] } else { dense[(j, i)] };
        for i in 0..90 {
            assert_eq!(upper(i, i), 0.0);
            for &(d, j) in &g.lists[i] {
                assert!((upper(i, j) - d).abs() < 1e-12, "edge ({i},{j})");
            }
        }
    }

    #[test]
    fn exact_path_reports_exact() {
        let (_, g, _) = run_knn(40, 16, 4);
        assert!(matches!(g.path, KnnPath::Exact));
        assert!(g.path.describe().contains("exact"));
    }

    #[test]
    fn diagonal_zero_and_block_count() {
        let (_, g, dense) = run_knn(30, 10, 3);
        assert_eq!(g.q, 3);
        assert_eq!(g.graph.len(), 6); // UT blocks of q=3
        for i in 0..30 {
            assert_eq!(dense[(i, i)], 0.0);
        }
    }
}
