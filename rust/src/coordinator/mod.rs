//! The Isomap pipeline over the dataflow engine — the paper's system
//! contribution (§III): blocked kNN, communication-avoiding blocked
//! Floyd–Warshall APSP, distributed double centering, and simultaneous
//! power iteration with driver-side QR, glued end-to-end by
//! [`isomap::run`]. [`landmark`] adds the L-Isomap variant the paper
//! discusses in §V as the approximate alternative.

pub mod apsp;
pub mod centering;
pub mod eigen;
pub mod isomap;
pub mod knn;
pub mod landmark;
pub mod lle;
pub mod panels;
pub mod streaming;

/// Row range `[start, end)` of block `i` in a 1-D decomposition of `n`
/// points into blocks of size `b` (the last block may be ragged).
pub fn block_range(n: usize, b: usize, i: usize) -> (usize, usize) {
    let start = i * b;
    (start, ((i + 1) * b).min(n))
}

/// Number of logical blocks `q = ⌈n/b⌉`.
pub fn num_blocks(n: usize, b: usize) -> usize {
    n.div_ceil(b)
}

/// Default partition count: the paper sets `p'` so that `B = Q/p'` blocks
/// land on each partition; we default to one partition per cluster core,
/// capped by the number of upper-triangular blocks.
pub fn default_partitions(q: usize, total_cores: usize) -> usize {
    crate::engine::partitioner::ut_count(q).min(total_cores.max(1))
}

/// Split a dense symmetric matrix into its upper-triangular logical blocks
/// (benches and tests feed graphs straight into [`apsp::solve`] this way).
pub fn blocks_from_dense(
    g: &crate::linalg::Matrix,
    b: usize,
) -> Vec<(crate::engine::BlockId, crate::linalg::Matrix)> {
    let n = g.nrows();
    let q = num_blocks(n, b);
    let mut out = Vec::with_capacity(crate::engine::partitioner::ut_count(q));
    for i in 0..q {
        for j in i..q {
            let (rs, re) = block_range(n, b, i);
            let (cs, ce) = block_range(n, b, j);
            out.push((crate::engine::BlockId::new(i, j), g.slice(rs, re, cs, ce)));
        }
    }
    out
}

/// Reassemble a dense symmetric matrix from upper-triangular blocks.
pub fn dense_from_blocks(
    rdd: &crate::engine::BlockRdd<crate::linalg::Matrix>,
    n: usize,
    b: usize,
) -> crate::linalg::Matrix {
    let mut out = crate::linalg::Matrix::zeros(n, n);
    for (id, blk) in rdd.iter() {
        let (rs, _) = block_range(n, b, id.i);
        let (cs, _) = block_range(n, b, id.j);
        for r in 0..blk.nrows() {
            for c in 0..blk.ncols() {
                out[(rs + r, cs + c)] = blk[(r, c)];
                out[(cs + c, rs + r)] = blk[(r, c)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges() {
        assert_eq!(block_range(10, 4, 0), (0, 4));
        assert_eq!(block_range(10, 4, 2), (8, 10)); // ragged tail
        assert_eq!(num_blocks(10, 4), 3);
        assert_eq!(num_blocks(8, 4), 2);
    }

    #[test]
    fn partitions_capped() {
        assert_eq!(default_partitions(2, 500), 3); // Q = 3
        assert_eq!(default_partitions(10, 4), 4);
        assert_eq!(default_partitions(10, 0), 1);
    }
}
