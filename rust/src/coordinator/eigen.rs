//! Simultaneous power iteration (paper §III-D, Alg. 2).
//!
//! The driver owns the tall-skinny `Q (n×d)` and runs BLAS QR on it; the
//! per-iteration blocked product `V = A·Q` is delegated to a
//! [`FeatureSource`] — resident upper-triangular blocks
//! ([`panels::Materialized`], the paper's layout: each block `(I,J)`
//! contributes `A^{(I,J)}·Q_J` to `V_I` and, when off-diagonal,
//! `(A^{(I,J)})ᵀ·Q_I` to `V_J`) or streamed geodesic panels
//! ([`panels::Implicit`], which never materializes `A`). `Q` is broadcast
//! each iteration — small for practical `d` — so no block pairing/shuffle
//! of `A` is ever needed. Convergence: `‖Qᶦ − Qᶦ⁻¹‖_F < t` or `l`
//! iterations.

use super::panels::{self, FeatureSource};
use crate::backend::Backend;
use crate::engine::BlockRdd;
use crate::linalg::qr::qr_thin;
use crate::linalg::Matrix;
use anyhow::{bail, Result};

/// Result of the spectral stage.
#[derive(Debug)]
pub struct EigenOutput {
    /// Top-`d` eigenvectors (orthonormal columns, sign-fixed).
    pub q: Matrix,
    /// Corresponding eigenvalue estimates (diag of R).
    pub eigenvalues: Vec<f64>,
    /// Power iterations executed.
    pub iterations: usize,
    /// Whether the Frobenius test converged before `max_iter`.
    pub converged: bool,
}

/// Run simultaneous power iteration over the centered feature matrix held
/// in resident blocks — the historical entry point, now a thin wrapper
/// over [`power_iteration`] with a [`panels::Materialized`] source.
pub fn simultaneous_power_iteration(
    a: &BlockRdd<Matrix>,
    n: usize,
    b: usize,
    d: usize,
    tol: f64,
    max_iter: usize,
    backend: &Backend,
) -> Result<EigenOutput> {
    let src = panels::Materialized::new(a, n, b, backend);
    power_iteration(&src, d, tol, max_iter)
}

/// Run simultaneous power iteration against any [`FeatureSource`]. The
/// driver-side loop (QR, convergence test, sign fix) is identical for
/// every source; only the `A·Q` product differs. Sources are responsible
/// for their own stage accounting, so the metrics table shows where each
/// iteration's time actually went.
pub fn power_iteration(
    src: &dyn FeatureSource,
    d: usize,
    tol: f64,
    max_iter: usize,
) -> Result<EigenOutput> {
    let n = src.n();
    if d == 0 || d > n {
        bail!("eigen: d={d} out of range for n={n}");
    }

    // V¹ = I_{n×d}; Q¹ from its QR (== the first d basis vectors).
    let (mut q, mut r) = qr_thin(&Matrix::eye(n, d));
    let mut iterations = 0;
    let mut converged = false;

    for it in 1..=max_iter {
        iterations = it;
        let v = src.matvec(&q)?;
        let (qn, rn) = qr_thin(&v);
        let delta = qn.fro_dist(&q);
        q = qn;
        r = rn;
        if delta < tol {
            converged = true;
            break;
        }
    }

    // Eigenvalue estimates from R's diagonal; fix eigenvector signs
    // (largest-|entry| positive) for reproducibility.
    let eigenvalues: Vec<f64> = (0..d).map(|j| r[(j, j)]).collect();
    for j in 0..d {
        let mut imax = 0;
        for i in 0..n {
            if q[(i, j)].abs() > q[(imax, j)].abs() {
                imax = i;
            }
        }
        if q[(imax, j)] < 0.0 {
            for i in 0..n {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }

    Ok(EigenOutput { q, eigenvalues, iterations, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::coordinator::block_range;
    use crate::engine::partitioner::UpperTriangularPartitioner;
    use crate::engine::{BlockId, SparkContext};
    use crate::linalg::jacobi;
    use crate::util::Rng;
    use std::sync::Arc;

    /// Symmetric matrix with a known, well-separated spectrum
    /// (λ_i = 100/1.5^i), split into UT blocks on a local context.
    fn blocked_symmetric(n: usize, b: usize, seed: u64) -> (BlockRdd<Matrix>, Matrix) {
        let mut rng = Rng::seed(seed);
        let mut g = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                g[(i, j)] = rng.gaussian();
            }
        }
        let (qq, _) = crate::linalg::qr::qr_thin(&g);
        let mut lam = Matrix::zeros(n, n);
        for i in 0..n {
            lam[(i, i)] = 100.0 / 1.5f64.powi(i as i32);
        }
        let m = qq.matmul(&lam).matmul(&qq.transpose());
        let q = n.div_ceil(b);
        let part = Arc::new(UpperTriangularPartitioner::new(q, q));
        let ctx = SparkContext::new(ClusterConfig::local());
        let mut blocks = Vec::new();
        for i in 0..q {
            for j in i..q {
                let (rs, re) = block_range(n, b, i);
                let (cs, ce) = block_range(n, b, j);
                blocks.push((BlockId::new(i, j), m.slice(rs, re, cs, ce)));
            }
        }
        (ctx.parallelize("a", blocks, part), m)
    }

    #[test]
    fn recovers_top_eigenpairs() {
        let (rdd, dense) = blocked_symmetric(40, 8, 3);
        let out =
            simultaneous_power_iteration(&rdd, 40, 8, 3, 1e-10, 500, &Backend::Native).unwrap();
        assert!(out.converged, "did not converge in 500 iterations");
        let (want_vals, want_vecs) = jacobi::top_d(&dense, 3);
        for j in 0..3 {
            assert!(
                (out.eigenvalues[j] - want_vals[j]).abs() / want_vals[j].abs() < 1e-6,
                "eigenvalue {j}: {} vs {}",
                out.eigenvalues[j],
                want_vals[j]
            );
            // Eigenvector up to sign (both sign-fixed the same way).
            for i in 0..40 {
                assert!(
                    (out.q[(i, j)] - want_vecs[(i, j)]).abs() < 1e-5,
                    "vec {j} entry {i}"
                );
            }
        }
    }

    #[test]
    fn ragged_blocks_work() {
        let (rdd, dense) = blocked_symmetric(37, 8, 4);
        let out =
            simultaneous_power_iteration(&rdd, 37, 8, 2, 1e-10, 500, &Backend::Native).unwrap();
        let (want_vals, _) = jacobi::top_d(&dense, 2);
        assert!((out.eigenvalues[0] - want_vals[0]).abs() / want_vals[0] < 1e-6);
    }

    #[test]
    fn iteration_cap_respected() {
        let (rdd, _) = blocked_symmetric(24, 8, 5);
        let out = simultaneous_power_iteration(&rdd, 24, 8, 2, 1e-30, 3, &Backend::Native).unwrap();
        assert_eq!(out.iterations, 3);
        assert!(!out.converged);
    }

    #[test]
    fn q_columns_orthonormal() {
        let (rdd, _) = blocked_symmetric(30, 7, 6);
        let out =
            simultaneous_power_iteration(&rdd, 30, 7, 3, 1e-10, 300, &Backend::Native).unwrap();
        let qtq = out.q.transpose().matmul(&out.q);
        assert!(qtq.max_abs_diff(&Matrix::eye(3, 3)) < 1e-8);
    }

    #[test]
    fn rejects_bad_d() {
        let (rdd, _) = blocked_symmetric(10, 5, 7);
        assert!(simultaneous_power_iteration(&rdd, 10, 5, 0, 1e-9, 10, &Backend::Native).is_err());
        assert!(simultaneous_power_iteration(&rdd, 10, 5, 11, 1e-9, 10, &Backend::Native).is_err());
    }
}
