//! Locally Linear Embedding — the extension the paper's §VI singles out
//! ("other non-linear spectral decomposition methods, like e.g. LLE, share
//! the same computational backbone, with a minimal effort our software
//! could be extended").
//!
//! Shares the distributed kNN stage with Isomap; then:
//!   1. per point, reconstruction weights from the local Gram system
//!      `C·w = 1` (regularized, normalized to Σw = 1);
//!   2. the embedding matrix `M = (I−W)ᵀ(I−W)` — symmetric PSD with the
//!      constant vector in its null space — assembled into the same
//!      upper-triangular block layout;
//!   3. the *bottom* non-constant eigenvectors of `M` by simultaneous
//!      **shift-invert** iteration: `V ← (M + εI)⁻¹·V` with a driver-side
//!      LU factorization, deflating the constant direction by
//!      column-centering each iterate before the QR step.
//!
//! Why shift-invert rather than the paper's pure power iteration on the
//! spectral complement σI − M: M's bottom eigenvalues are *clustered near
//! zero* (gaps ~1e-4 against a Gershgorin σ of O(1)), so complement power
//! iteration needs 10⁴–10⁵ matvecs to separate them — measured: |corr|
//! with the latent coordinate stalls at 0.24 after 300 iterations, vs
//! >0.95 in ~20 shift-invert steps. Production LLE at scale would use
//! shift-invert Lanczos; the O(n³) driver factorization here plays the
//! same role the paper's driver-side QR plays for Isomap (acceptable for
//! small d·n driver state — a scalability simplification we document
//! rather than hide).

use super::knn;
use crate::backend::Backend;
use crate::config::{ClusterConfig, IsomapConfig};
use crate::engine::SparkContext;
use crate::linalg::qr::qr_thin;
use crate::linalg::{solve, Matrix};
use anyhow::{bail, Context, Result};

/// LLE output.
#[derive(Debug)]
pub struct LleOutput {
    /// The `n × d` embedding (bottom non-constant eigenvectors of M).
    pub embedding: Matrix,
    /// The corresponding (smallest, near-zero) eigenvalues of M.
    pub eigenvalues: Vec<f64>,
    /// Power iterations used by the spectral stage.
    pub iterations: usize,
}

/// Regularization scale for the local Gram systems (Saul & Roweis use
/// 1e-3·tr(C) when k > D).
const REG: f64 = 1e-3;

/// Run distributed LLE.
pub fn run(
    x: &Matrix,
    cfg: &IsomapConfig,
    cluster: &ClusterConfig,
    backend: &Backend,
) -> Result<LleOutput> {
    let n = x.nrows();
    cfg.validate(n)?;
    let ctx = SparkContext::new(cluster.clone());

    // Stage 1: distributed kNN (shared with Isomap).
    let kg = knn::build(&ctx, x, cfg, backend).context("kNN stage")?;
    if crate::eval::components(&kg.lists) != 1 {
        bail!("kNN graph disconnected; increase k");
    }

    // Stage 2: reconstruction weights per point (driver-side small solves;
    // k×k systems are tiny — the paper's QR-on-driver argument applies).
    let k = cfg.k;
    let mut w_rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
    for i in 0..n {
        let nbrs: Vec<usize> = kg.lists[i].iter().map(|&(_, j)| j).collect();
        // C[a][b] = (x_i − x_a)·(x_i − x_b)
        let mut c = Matrix::zeros(k, k);
        for a in 0..k {
            for b in a..k {
                let mut acc = 0.0;
                for t in 0..x.ncols() {
                    acc += (x[(i, t)] - x[(nbrs[a], t)]) * (x[(i, t)] - x[(nbrs[b], t)]);
                }
                c[(a, b)] = acc;
                c[(b, a)] = acc;
            }
        }
        let trace: f64 = (0..k).map(|a| c[(a, a)]).sum();
        let reg = REG * trace.max(1e-12) / k as f64;
        for a in 0..k {
            c[(a, a)] += reg;
        }
        let w = solve::solve(&c, &vec![1.0; k])
            .with_context(|| format!("local Gram solve for point {i}"))?;
        let s: f64 = w.iter().sum();
        if s.abs() < 1e-300 {
            bail!("degenerate reconstruction weights at point {i}");
        }
        w_rows.push(nbrs.into_iter().zip(w.into_iter().map(|v| v / s)).collect());
    }

    // Stage 3: assemble M = (I−W)ᵀ(I−W) into UT blocks.
    // M = I − W − Wᵀ + WᵀW; accumulate sparse then blockify.
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        m[(i, i)] += 1.0;
        for &(j, wij) in &w_rows[i] {
            m[(i, j)] -= wij;
            m[(j, i)] -= wij;
            for &(l, wil) in &w_rows[i] {
                m[(j, l)] += wij * wil;
            }
        }
    }

    // Stage 4: bottom non-constant eigenvectors by simultaneous
    // shift-invert iteration (see module docs for why not complement
    // power iteration). ε keeps M + εI comfortably non-singular without
    // distorting the eigenvector basis.
    let d = cfg.d;
    let eps = 1e-8;
    let mut shifted = m.clone();
    for i in 0..n {
        shifted[(i, i)] += eps;
    }
    let lu = crate::linalg::solve::Lu::factor(&shifted).context("factor M + εI")?;

    let mut qmat = centered_eye(n, d);
    let (q0, _) = qr_thin(&qmat);
    qmat = q0;
    let mut iterations = 0;
    for it in 1..=cfg.max_iter {
        iterations = it;
        let mut v = Matrix::zeros(n, d);
        for j in 0..d {
            let col = qmat.col(j);
            let sol = lu.solve(&col)?;
            for i in 0..n {
                v[(i, j)] = sol[i];
            }
        }
        // Deflate the constant direction.
        center_columns(&mut v);
        let (qn, _) = qr_thin(&v);
        let delta = qn.fro_dist(&qmat);
        qmat = qn;
        if delta < cfg.tol {
            break;
        }
    }

    // Rayleigh quotients give the eigenvalues of M for the converged Q.
    let mut eigenvalues = Vec::with_capacity(d);
    for j in 0..d {
        let col = qmat.col(j);
        let mut mq = vec![0.0; n];
        for i in 0..n {
            let mut acc = 0.0;
            for t in 0..n {
                acc += m[(i, t)] * col[t];
            }
            mq[i] = acc;
        }
        eigenvalues.push(col.iter().zip(&mq).map(|(a, b)| a * b).sum::<f64>());
    }
    // LLE convention: scale eigenvectors by √n so coordinates are O(1).
    let mut embedding = qmat;
    embedding.scale((n as f64).sqrt());
    Ok(LleOutput { embedding, eigenvalues, iterations })
}

/// First `d` basis vectors, column-centered (start orthogonal to 1).
fn centered_eye(n: usize, d: usize) -> Matrix {
    let mut v = Matrix::eye(n, d);
    center_columns(&mut v);
    v
}

fn center_columns(v: &mut Matrix) {
    let n = v.nrows();
    for j in 0..v.ncols() {
        let mean: f64 = (0..n).map(|i| v[(i, j)]).sum::<f64>() / n as f64;
        for i in 0..n {
            v[(i, j)] -= mean;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::swiss_roll;

    #[test]
    fn weights_reconstruct_points() {
        // Internal invariant probed through the public run: after LLE, the
        // embedding must exist and be finite; weight invariants are
        // checked below via the M-matrix null-space property.
        let ds = swiss_roll::euler_isometric(150, 3);
        let cfg = IsomapConfig { k: 10, d: 2, block: 32, ..Default::default() };
        let out = run(&ds.points, &cfg, &ClusterConfig::local(), &Backend::Native).unwrap();
        assert_eq!(out.embedding.nrows(), 150);
        assert!(out.embedding.as_slice().iter().all(|v| v.is_finite()));
        // Bottom eigenvalues of M are near zero (null space adjacency).
        for &ev in &out.eigenvalues {
            assert!(ev.abs() < 1.0, "eigenvalue {ev} not near the bottom of the spectrum");
        }
    }

    #[test]
    fn embedding_orthogonal_to_constant() {
        let ds = swiss_roll::euler_isometric(120, 5);
        let cfg = IsomapConfig { k: 8, d: 2, block: 32, ..Default::default() };
        let out = run(&ds.points, &cfg, &ClusterConfig::local(), &Backend::Native).unwrap();
        for j in 0..2 {
            let s: f64 = (0..120).map(|i| out.embedding[(i, j)]).sum();
            assert!(s.abs() < 1e-6, "column {j} not deflated: sum={s}");
        }
    }

    #[test]
    fn unrolls_swiss_roll_monotonically() {
        // LLE is not isometric, so Procrustes is inappropriate; instead
        // check the embedding orders points along the roll: correlation of
        // some embedding axis with the latent arc length is strong.
        let ds = swiss_roll::euler_isometric(400, 7);
        let cfg = IsomapConfig { k: 10, d: 2, block: 64, max_iter: 300, ..Default::default() };
        let out = run(&ds.points, &cfg, &ClusterConfig::local(), &Backend::Native).unwrap();
        let truth = ds.ground_truth.as_ref().unwrap();
        let n = 400;
        let corr = |a: &[f64], b: &[f64]| -> f64 {
            let m = a.len() as f64;
            let (ma, mb) = (a.iter().sum::<f64>() / m, b.iter().sum::<f64>() / m);
            let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
            for (x, y) in a.iter().zip(b) {
                cov += (x - ma) * (y - mb);
                va += (x - ma) * (x - ma);
                vb += (y - mb) * (y - mb);
            }
            cov / (va * vb).sqrt()
        };
        let s: Vec<f64> = (0..n).map(|i| truth[(i, 0)]).collect();
        let best = (0..2)
            .map(|j| {
                let e: Vec<f64> = (0..n).map(|i| out.embedding[(i, j)]).collect();
                corr(&e, &s).abs()
            })
            .fold(0.0, f64::max);
        assert!(best > 0.7, "no embedding axis tracks the roll: |corr|={best}");
    }

    #[test]
    fn rejects_disconnected() {
        let x = crate::data::clusters::gaussian_clusters(40, 3, 2, 0.01, 3).points;
        let cfg = IsomapConfig { k: 2, d: 2, block: 16, ..Default::default() };
        assert!(run(&x, &cfg, &ClusterConfig::local(), &Backend::Native).is_err());
    }
}
