//! Single-node exact baselines.
//!
//! These are the dense, textbook implementations the distributed pipeline
//! is validated against (the paper validates against sequential
//! Matlab/Python Isomap, which "scales to n = 4000"): brute-force kNN,
//! Dijkstra APSP over the sparse neighborhood graph, and a full dense
//! Isomap using the Jacobi eigensolver. Also used by ablation benches.

use crate::kernels::kselect::{row_topk, Neighbor};
use crate::kernels::{centering, sqdist};
use crate::linalg::{jacobi, Matrix};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Brute-force kNN: for each point the k nearest others (ascending).
pub fn brute_knn(x: &Matrix, k: usize) -> Vec<Vec<Neighbor>> {
    let n = x.nrows();
    let d = sqdist::dist_block_sym(x);
    (0..n).map(|i| row_topk(d.row(i), k, 0, Some(i))).collect()
}

/// Symmetric dense neighborhood-graph matrix from kNN lists: edge weight
/// is the Euclidean distance if either endpoint selected the other,
/// `f64::INFINITY` otherwise, 0 on the diagonal.
pub fn knn_graph_dense(knn: &[Vec<Neighbor>]) -> Matrix {
    let n = knn.len();
    let mut g = Matrix::full(n, n, f64::INFINITY);
    for i in 0..n {
        g[(i, i)] = 0.0;
        for &(dist, j) in &knn[i] {
            if dist < g[(i, j)] {
                g[(i, j)] = dist;
                g[(j, i)] = dist;
            }
        }
    }
    g
}

/// Adjacency-list form of a dense graph (finite off-diagonal entries).
fn adjacency(g: &Matrix) -> Vec<Vec<(usize, f64)>> {
    let n = g.nrows();
    let mut adj = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j && g[(i, j)].is_finite() {
                adj[i].push((j, g[(i, j)]));
            }
        }
    }
    adj
}

#[derive(PartialEq)]
struct HeapItem(f64, usize);

impl Eq for HeapItem {}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed comparison on distance.
        other.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
    }
}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Dijkstra single-source shortest paths over a dense graph matrix.
pub fn dijkstra(g: &Matrix, src: usize) -> Vec<f64> {
    let adj = adjacency(g);
    dijkstra_adj(&adj, src)
}

fn dijkstra_adj(adj: &[Vec<(usize, f64)>], src: usize) -> Vec<f64> {
    let n = adj.len();
    let mut dist = vec![f64::INFINITY; n];
    let mut heap = BinaryHeap::new();
    dist[src] = 0.0;
    heap.push(HeapItem(0.0, src));
    while let Some(HeapItem(d, u)) = heap.pop() {
        if d > dist[u] {
            continue;
        }
        for &(v, w) in &adj[u] {
            let nd = d + w;
            if nd < dist[v] {
                dist[v] = nd;
                heap.push(HeapItem(nd, v));
            }
        }
    }
    dist
}

/// Dijkstra-based APSP (the paper cites it as ill-suited for Spark but it
/// is an exactness oracle here).
pub fn dijkstra_apsp(g: &Matrix) -> Matrix {
    let n = g.nrows();
    let adj = adjacency(g);
    let mut out = Matrix::zeros(n, n);
    for s in 0..n {
        let d = dijkstra_adj(&adj, s);
        out.row_mut(s).copy_from_slice(&d);
    }
    out
}

/// APSP by repeated min-plus squaring of the adjacency matrix
/// (`A^n` over the tropical semiring) — the alternative the paper
/// considers before settling on blocked Floyd–Warshall. O(n³ log n).
pub fn minplus_power_apsp(g: &Matrix) -> Matrix {
    let n = g.nrows();
    let mut a = g.clone();
    let mut span = 1usize;
    while span < n {
        a = crate::kernels::minplus::minplus(&a, &a);
        span *= 2;
    }
    a
}

/// Output of the dense reference Isomap.
pub struct ReferenceOutput {
    pub embedding: Matrix,
    pub eigenvalues: Vec<f64>,
    pub geodesics: Matrix,
}

/// Full dense exact Isomap (brute kNN → Dijkstra APSP → double centering →
/// Jacobi eigendecomposition). Ground truth for the distributed pipeline;
/// practical for n up to a few hundred.
pub fn reference_isomap(x: &Matrix, k: usize, d: usize) -> ReferenceOutput {
    let knn = brute_knn(x, k);
    let g = knn_graph_dense(&knn);
    let geo = dijkstra_apsp(&g);
    let mut a = geo.map(|v| v * v);
    centering::center_full_direct(&mut a);
    let (vals, q) = jacobi::top_d(&a, d);
    let mut y = Matrix::zeros(x.nrows(), d);
    for i in 0..x.nrows() {
        for j in 0..d {
            y[(i, j)] = q[(i, j)] * vals[j].max(0.0).sqrt();
        }
    }
    ReferenceOutput { embedding: y, eigenvalues: vals, geodesics: geo }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::swiss_roll;
    use crate::util::Rng;

    fn random_points(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x[(i, j)] = rng.gaussian();
            }
        }
        x
    }

    #[test]
    fn brute_knn_sizes_and_no_self() {
        let x = random_points(30, 4, 1);
        let knn = brute_knn(&x, 5);
        assert_eq!(knn.len(), 30);
        for (i, list) in knn.iter().enumerate() {
            assert_eq!(list.len(), 5);
            assert!(list.iter().all(|&(_, j)| j != i));
            // ascending
            for w in list.windows(2) {
                assert!(w[0].0 <= w[1].0);
            }
        }
    }

    #[test]
    fn graph_is_symmetric() {
        let x = random_points(25, 3, 2);
        let g = knn_graph_dense(&brute_knn(&x, 4));
        assert!(g.is_symmetric(0.0) || {
            // infinities compare equal on both sides
            (0..25).all(|i| (0..25).all(|j| {
                let a = g[(i, j)];
                let b = g[(j, i)];
                (a.is_infinite() && b.is_infinite()) || (a - b).abs() == 0.0
            }))
        });
    }

    #[test]
    fn dijkstra_matches_floyd_warshall() {
        let x = random_points(20, 3, 3);
        let g = knn_graph_dense(&brute_knn(&x, 4));
        let d1 = dijkstra_apsp(&g);
        let d2 = crate::kernels::floyd_warshall::floyd_warshall(&g);
        for i in 0..20 {
            for j in 0..20 {
                let (a, b) = (d1[(i, j)], d2[(i, j)]);
                if a.is_infinite() {
                    assert!(b.is_infinite());
                } else {
                    assert!((a - b).abs() < 1e-10, "({i},{j}): {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn minplus_power_matches_dijkstra() {
        let x = random_points(16, 3, 4);
        let g = knn_graph_dense(&brute_knn(&x, 4));
        let d1 = dijkstra_apsp(&g);
        let d2 = minplus_power_apsp(&g);
        for i in 0..16 {
            for j in 0..16 {
                let (a, b) = (d1[(i, j)], d2[(i, j)]);
                if a.is_infinite() {
                    assert!(b.is_infinite());
                } else {
                    assert!((a - b).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn reference_isomap_unrolls_swiss_roll() {
        // On a small swiss roll the 2-D embedding must correlate strongly
        // with the latent coordinates (checked properly in eval tests; here
        // just shape + finite sanity).
        let ds = swiss_roll::euler_isometric(120, 7);
        let out = reference_isomap(&ds.points, 8, 2);
        assert_eq!(out.embedding.nrows(), 120);
        assert_eq!(out.embedding.ncols(), 2);
        assert!(out.embedding.as_slice().iter().all(|v| v.is_finite()));
        assert!(out.eigenvalues[0] >= out.eigenvalues[1]);
        assert!(out.eigenvalues[1] > 0.0);
    }
}
