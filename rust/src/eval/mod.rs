//! Embedding quality metrics.
//!
//! * [`procrustes`] — the paper's headline correctness number (Fig. 4:
//!   2.67e-5 on Swiss50): similarity-transform-invariant disparity between
//!   the embedding and the latent ground truth.
//! * [`residual_variance`] — Tenenbaum et al.'s Isomap fit metric.
//! * [`connectivity`] — the k used must give a single connected component
//!   (paper §IV: "k large enough to deliver single connected component").

use crate::linalg::{jacobi, Matrix};

/// Column-center a matrix and scale to unit Frobenius norm. Returns the
/// transformed copy.
fn standardize(m: &Matrix) -> Matrix {
    let mu = m.col_means();
    let mut out = m.clone();
    for i in 0..m.nrows() {
        for (x, &c) in out.row_mut(i).iter_mut().zip(&mu) {
            *x -= c;
        }
    }
    let norm = out.fro_norm();
    if norm > 0.0 {
        out.scale(1.0 / norm);
    }
    out
}

/// SVD of a small matrix `A = U Σ Vᵀ` via Jacobi on `AᵀA` (adequate for the
/// d×d cross-covariances used by Procrustes; d is 2–3 here).
fn small_svd(a: &Matrix) -> (Matrix, Vec<f64>, Matrix) {
    let ata = a.transpose().matmul(a);
    let (mut evals, v) = jacobi::eigh(&ata, 100, 1e-14);
    for e in &mut evals {
        *e = e.max(0.0);
    }
    let svals: Vec<f64> = evals.iter().map(|e| e.sqrt()).collect();
    // U = A V Σ⁻¹ (columns with ~0 singular value are left as zeros; they
    // contribute nothing to the Procrustes rotation).
    let av = a.matmul(&v);
    let mut u = Matrix::zeros(a.nrows(), svals.len());
    for j in 0..svals.len() {
        if svals[j] > 1e-300 {
            for i in 0..a.nrows() {
                u[(i, j)] = av[(i, j)] / svals[j];
            }
        }
    }
    (u, svals, v)
}

/// Procrustes disparity between `x` (ground truth) and `y` (embedding):
/// both are standardized, `y` is optimally rotated/reflected and scaled
/// onto `x`, and the sum of squared residuals is returned (scipy's
/// `procrustes` definition; 0 = perfect).
pub fn procrustes(x: &Matrix, y: &Matrix) -> f64 {
    assert_eq!(x.nrows(), y.nrows(), "point counts differ");
    assert_eq!(x.ncols(), y.ncols(), "dimensionalities differ");
    let xs = standardize(x);
    let ys = standardize(y);
    // Optimal rotation R = U Vᵀ from SVD of YᵀX; optimal scale = Σσ.
    let m = ys.transpose().matmul(&xs);
    let (u, s, v) = small_svd(&m);
    let r = u.matmul(&v.transpose());
    let scale: f64 = s.iter().sum();
    // disparity = ‖X − s·Y·R‖²_F = 1 − scale² (after standardization).
    let mut yr = ys.matmul(&r);
    yr.scale(scale);
    let mut disparity = 0.0;
    for (a, b) in xs.as_slice().iter().zip(yr.as_slice()) {
        disparity += (a - b) * (a - b);
    }
    disparity
}

/// Residual variance `1 − ρ²(geodesic distances, embedding distances)`
/// over all pairs (or a subsample cap for large n).
pub fn residual_variance(geodesics: &Matrix, y: &Matrix, max_pairs: usize) -> f64 {
    let n = y.nrows();
    let mut gs = Vec::new();
    let mut es = Vec::new();
    let total_pairs = n * (n - 1) / 2;
    let stride = (total_pairs / max_pairs.max(1)).max(1);
    let mut c = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            c += 1;
            if c % stride != 0 {
                continue;
            }
            let g = geodesics[(i, j)];
            if !g.is_finite() {
                continue;
            }
            let e: f64 = y
                .row(i)
                .iter()
                .zip(y.row(j))
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            gs.push(g);
            es.push(e);
        }
    }
    1.0 - correlation(&gs, &es).powi(2)
}

fn correlation(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va * vb).sqrt()
}

/// Trustworthiness and continuity (Venna & Kaski): rank-based quality of a
/// non-isometric embedding (the right metric for LLE, where Procrustes is
/// inappropriate). Both are in [0, 1]; 1 = perfect neighborhood
/// preservation. `max_points` caps the O(n²·log n) cost by subsampling.
pub fn trustworthiness_continuity(
    x: &Matrix,
    y: &Matrix,
    k: usize,
    max_points: usize,
) -> (f64, f64) {
    assert_eq!(x.nrows(), y.nrows());
    let n_all = x.nrows();
    let stride = (n_all / max_points.max(1)).max(1);
    let idx: Vec<usize> = (0..n_all).step_by(stride).collect();
    let n = idx.len();
    assert!(k < n, "k={k} too large for {n} sampled points");

    // Rank tables in both spaces.
    let ranks = |m: &Matrix| -> Vec<Vec<usize>> {
        let mut out = Vec::with_capacity(n);
        for &i in &idx {
            let mut d: Vec<(f64, usize)> = idx
                .iter()
                .enumerate()
                .filter(|&(_, &j)| j != i)
                .map(|(pos, &j)| {
                    let dist: f64 = m
                        .row(i)
                        .iter()
                        .zip(m.row(j))
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    (dist, pos)
                })
                .collect();
            d.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            // rank_of[pos] = rank of sampled point `pos` from i (1-based).
            let mut rank_of = vec![0usize; n];
            for (r, &(_, pos)) in d.iter().enumerate() {
                rank_of[pos] = r + 1;
            }
            out.push(rank_of);
        }
        out
    };
    let rx = ranks(x);
    let ry = ranks(y);

    let norm = 2.0 / (n as f64 * k as f64 * (2.0 * n as f64 - 3.0 * k as f64 - 1.0));
    let mut t_pen = 0.0;
    let mut c_pen = 0.0;
    for i in 0..n {
        for pos in 0..n {
            if rx[i][pos] == 0 && ry[i][pos] == 0 {
                continue; // self
            }
            // In embedding kNN but not in data kNN -> trustworthiness.
            if ry[i][pos] >= 1 && ry[i][pos] <= k && rx[i][pos] > k {
                t_pen += (rx[i][pos] - k) as f64;
            }
            // In data kNN but not in embedding kNN -> continuity.
            if rx[i][pos] >= 1 && rx[i][pos] <= k && ry[i][pos] > k {
                c_pen += (ry[i][pos] - k) as f64;
            }
        }
    }
    (1.0 - norm * t_pen, 1.0 - norm * c_pen)
}

/// Recall@k of approximate kNN lists against exact lists: the fraction of
/// true k-nearest neighbors the approximate index recovered, averaged over
/// all points. Membership is judged on neighbor *indices* — an approximate
/// hit counts whenever the exact top-k contains the same point, regardless
/// of list position. Lists longer than `k` are truncated; shorter lists
/// (an approximate index that could not fill its quota) simply score the
/// hits they have. 1.0 = perfect recovery.
///
/// This is the harness the rp-forest tests and `benches/stage_knn.rs` use
/// to hold the approximate front end to the ≥ 0.95 acceptance bar.
pub fn recall_at_k(
    approx: &[Vec<(f64, usize)>],
    exact: &[Vec<(f64, usize)>],
    k: usize,
) -> f64 {
    assert_eq!(approx.len(), exact.len(), "list counts differ");
    assert!(k > 0, "recall@0 is undefined");
    if approx.is_empty() {
        return 1.0;
    }
    let mut hits = 0usize;
    let mut truth = 0usize;
    for (a, e) in approx.iter().zip(exact) {
        let want: Vec<usize> = e.iter().take(k).map(|&(_, j)| j).collect();
        truth += want.len();
        hits += a.iter().take(k).filter(|&&(_, j)| want.contains(&j)).count();
    }
    hits as f64 / truth.max(1) as f64
}

/// Number of connected components of a kNN graph given as neighbor lists.
pub fn components(knn: &[Vec<(f64, usize)>]) -> usize {
    let n = knn.len();
    // Union-find over symmetrized edges.
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
        while parent[x] != x {
            parent[x] = parent[parent[x]];
            x = parent[x];
        }
        x
    }
    for (i, list) in knn.iter().enumerate() {
        for &(_, j) in list {
            let (a, b) = (find(&mut parent, i), find(&mut parent, j));
            if a != b {
                parent[a] = b;
            }
        }
    }
    (0..n).filter(|&i| find(&mut parent, i) == i).count()
}

/// True when the kNN graph is a single connected component.
pub fn connectivity(knn: &[Vec<(f64, usize)>]) -> bool {
    components(knn) == 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x[(i, j)] = rng.gaussian();
            }
        }
        x
    }

    #[test]
    fn procrustes_identity_zero() {
        let x = random(40, 2, 1);
        assert!(procrustes(&x, &x) < 1e-12);
    }

    #[test]
    fn procrustes_invariant_to_similarity_transform() {
        let x = random(50, 2, 2);
        // Rotate by θ, scale by 3, translate.
        let th: f64 = 0.7;
        let mut y = Matrix::zeros(50, 2);
        for i in 0..50 {
            let (a, b) = (x[(i, 0)], x[(i, 1)]);
            y[(i, 0)] = 3.0 * (a * th.cos() - b * th.sin()) + 5.0;
            y[(i, 1)] = 3.0 * (a * th.sin() + b * th.cos()) - 2.0;
        }
        assert!(procrustes(&x, &y) < 1e-12);
    }

    #[test]
    fn procrustes_invariant_to_reflection() {
        let x = random(30, 2, 3);
        let mut y = x.clone();
        for i in 0..30 {
            y[(i, 0)] = -y[(i, 0)];
        }
        assert!(procrustes(&x, &y) < 1e-12);
    }

    #[test]
    fn procrustes_detects_distortion() {
        let x = random(30, 2, 4);
        let y = random(30, 2, 99);
        assert!(procrustes(&x, &y) > 0.1);
    }

    #[test]
    fn residual_variance_perfect_for_euclidean() {
        let y = random(40, 2, 5);
        let mut geo = Matrix::zeros(40, 40);
        for i in 0..40 {
            for j in 0..40 {
                let d: f64 = y
                    .row(i)
                    .iter()
                    .zip(y.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                geo[(i, j)] = d;
            }
        }
        assert!(residual_variance(&geo, &y, 10_000) < 1e-12);
    }

    #[test]
    fn trustworthiness_perfect_for_identity() {
        let x = random(60, 3, 11);
        let (t, c) = trustworthiness_continuity(&x, &x, 5, 1000);
        assert!((t - 1.0).abs() < 1e-12, "t={t}");
        assert!((c - 1.0).abs() < 1e-12, "c={c}");
    }

    #[test]
    fn trustworthiness_detects_scrambling() {
        let x = random(80, 3, 12);
        let y = random(80, 2, 999); // unrelated embedding
        let (t, c) = trustworthiness_continuity(&x, &y, 5, 1000);
        assert!(t < 0.8, "t={t}");
        assert!(c < 0.8, "c={c}");
    }

    #[test]
    fn trustworthiness_invariant_to_rigid_motion() {
        let x = random(50, 2, 13);
        let mut y = x.clone();
        let th: f64 = 1.1;
        for i in 0..50 {
            let (a, b) = (x[(i, 0)], x[(i, 1)]);
            y[(i, 0)] = a * th.cos() - b * th.sin() + 3.0;
            y[(i, 1)] = a * th.sin() + b * th.cos() - 7.0;
        }
        let (t, c) = trustworthiness_continuity(&x, &y, 6, 1000);
        assert!((t - 1.0).abs() < 1e-12 && (c - 1.0).abs() < 1e-12);
    }

    #[test]
    fn recall_perfect_and_partial() {
        let exact = vec![
            vec![(0.1, 1), (0.2, 2), (0.3, 3)],
            vec![(0.1, 0), (0.2, 3), (0.3, 2)],
        ];
        assert_eq!(recall_at_k(&exact, &exact, 3), 1.0);
        // Second list misses one of three true neighbors.
        let approx = vec![
            vec![(0.1, 1), (0.2, 2), (0.3, 3)],
            vec![(0.1, 0), (0.2, 3), (0.35, 9)],
        ];
        let r = recall_at_k(&approx, &exact, 3);
        assert!((r - 5.0 / 6.0).abs() < 1e-12, "r={r}");
        // Distances are irrelevant — only index membership counts.
        let rescored = vec![
            vec![(9.0, 3), (8.0, 2), (7.0, 1)],
            vec![(9.0, 2), (8.0, 3), (7.0, 0)],
        ];
        assert_eq!(recall_at_k(&rescored, &exact, 3), 1.0);
    }

    #[test]
    fn recall_truncates_to_k_and_tolerates_short_lists() {
        let exact = vec![vec![(0.1, 1), (0.2, 2), (0.3, 3), (0.4, 4)]];
        // Only the first k entries of each list participate.
        let approx = vec![vec![(0.1, 1), (0.2, 5), (0.3, 2), (0.4, 3)]];
        assert_eq!(recall_at_k(&approx, &exact, 2), 0.5);
        // A short approximate list scores the hits it has.
        let short = vec![vec![(0.1, 2)]];
        assert!((recall_at_k(&short, &exact, 4) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn components_counts() {
        // Two triangles, disjoint.
        let knn = vec![
            vec![(1.0, 1), (1.0, 2)],
            vec![(1.0, 0)],
            vec![(1.0, 0)],
            vec![(1.0, 4)],
            vec![(1.0, 3), (1.0, 5)],
            vec![(1.0, 4)],
        ];
        assert_eq!(components(&knn), 2);
        assert!(!connectivity(&knn));
        let joined = {
            let mut k = knn.clone();
            k[2].push((1.0, 3));
            k
        };
        assert!(connectivity(&joined));
    }
}
