//! # isospark — exact Isomap on a Spark-like blocked dataflow engine
//!
//! Reproduction of *"Scalable Manifold Learning for Big Data with Apache
//! Spark"* (Schoeneman & Zola, 2018) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: a blocked
//!   dataflow engine with an upper-triangular partitioner, lineage tracking
//!   and checkpointing, a simulated multi-node cluster with a GbE network
//!   model, and the four Isomap stages (kNN, APSP, centering, spectral
//!   decomposition) expressed over it ([`coordinator`], [`engine`]).
//! * **Sparse geodesics** — [`graph`] keeps the geodesic stage `O(n·k)`-
//!   sparse: a CSR view of the kNN graph plus a pooled multi-source
//!   Dijkstra. The exact pipeline selects it with `--geodesics
//!   sparse-dijkstra` (the dense APSP RDD is never built); the landmark
//!   and streaming fits always use it.
//! * **L2/L1 (python/compile)** — JAX block ops backed by Pallas kernels,
//!   AOT-lowered to HLO text once at build time (`make artifacts`).
//! * **Runtime bridge** — [`runtime`] loads the HLO artifacts through the
//!   PJRT C API (`xla` crate) so the Rust hot path executes the very
//!   kernels authored in Pallas; [`backend`] abstracts PJRT vs. the native
//!   Rust kernels in [`kernels`].
//! * **Serving** — [`model`] persists a fitted streaming model as a
//!   versioned on-disk artifact, and [`serve`] exposes it over HTTP with
//!   micro-batched out-of-sample projection (`isospark fit --save` /
//!   `isospark serve`).
//! * **Distribution** — [`dist`] makes the cluster real: an `isospark
//!   worker` TCP runtime plus a driver-side [`dist::RemoteCluster`] that
//!   ships the geodesic panel stage to worker processes over a
//!   checksummed block-shuffle protocol, with retry-on-worker-loss,
//!   bit-identical to the single-process run (`--workers`).
//!
//! The full architecture guide — dataflow walkthrough, the simulated-
//! cluster vs. real-thread-pool distinction, the PJRT offload boundary
//! and padded-execution policy, and a per-directory module map — lives in
//! `docs/ARCHITECTURE.md` at the repository root.
//!
//! ## Quickstart
//!
//! ```no_run
//! use isospark::prelude::*;
//!
//! let roll = isospark::data::swiss_roll::euler_isometric(500, 42);
//! let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
//! let cluster = ClusterConfig::local();
//! let out = isospark::coordinator::isomap::run(&roll.points, &cfg, &cluster).unwrap();
//! assert_eq!(out.embedding.ncols(), 2);
//! ```

pub mod backend;
pub mod baselines;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod engine;
pub mod eval;
pub mod graph;
pub mod kernels;
pub mod knn_approx;
pub mod linalg;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;

/// Convenience re-exports for the common entry points.
pub mod prelude {
    pub use crate::backend::Backend;
    pub use crate::config::{ClusterConfig, GeodesicsMode, IsomapConfig, KnnMode};
    pub use crate::coordinator::isomap::{self, IsomapOutput};
    pub use crate::engine::block::BlockId;
    pub use crate::engine::context::SparkContext;
    pub use crate::graph::CsrGraph;
    pub use crate::linalg::matrix::Matrix;
    pub use crate::model::FittedModel;
}
