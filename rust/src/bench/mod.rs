//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use [`Bencher`] to run warmups + timed iterations
//! and print criterion-style lines plus a machine-readable JSON report.

use crate::util::json::Json;
use crate::util::Stopwatch;

/// Statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_secs", Json::num(self.mean_secs)),
            ("std_secs", Json::num(self.std_secs)),
            ("min_secs", Json::num(self.min_secs)),
            ("max_secs", Json::num(self.max_secs)),
        ])
    }
}

/// Bench runner with a global time budget per case.
pub struct Bencher {
    /// Max seconds to spend measuring one case.
    pub budget_secs: f64,
    /// Max timed iterations.
    pub max_iters: usize,
    /// Warmup iterations.
    pub warmup: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { budget_secs: 3.0, max_iters: 20, warmup: 1, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-profile configuration for expensive end-to-end cases.
    pub fn heavy() -> Self {
        Self { budget_secs: 10.0, max_iters: 5, warmup: 0, results: Vec::new() }
    }

    /// Fully custom configuration.
    pub fn with(budget_secs: f64, max_iters: usize, warmup: usize) -> Self {
        Self { budget_secs, max_iters, warmup, results: Vec::new() }
    }

    /// Time `f`, printing a summary line. Returns the mean seconds.
    pub fn case(&mut self, name: &str, mut f: impl FnMut()) -> f64 {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let budget = Stopwatch::start();
        while samples.len() < self.max_iters
            && (samples.is_empty() || budget.secs() < self.budget_secs)
        {
            let sw = Stopwatch::start();
            f();
            samples.push(sw.secs());
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_secs: mean,
            std_secs: var.sqrt(),
            min_secs: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max_secs: samples.iter().cloned().fold(0.0, f64::max),
        };
        println!(
            "{:<48} {:>12} ± {:<10} ({} iters)",
            result.name,
            crate::util::fmt::human_duration(result.mean_secs),
            crate::util::fmt::human_duration(result.std_secs),
            result.iters
        );
        self.results.push(result);
        mean
    }

    /// Report a derived (not directly timed) scalar in the same output
    /// stream, e.g. a simulated Table-I cell.
    pub fn report_value(&mut self, name: &str, value: f64, unit: &str) {
        println!("{name:<48} {value:>12.4} {unit}");
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: 0,
            mean_secs: value,
            std_secs: 0.0,
            min_secs: value,
            max_secs: value,
        });
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Dump results as JSON (written next to the bench output for the
    /// EXPERIMENTS.md tables).
    pub fn json(&self) -> String {
        Json::arr(self.results.iter().map(BenchResult::to_json).collect()).to_string()
    }
}

/// Merge one bench's kernel-throughput cases into a shared JSON report
/// (`BENCH_kernels.json`). Each bench owns a named section and re-runs
/// replace only their own section, so `stage_apsp` and `stage_knn` can
/// both contribute to a single file regardless of which ran last; sections
/// are kept sorted by name so the file is deterministic.
pub fn write_kernel_section(path: &str, section: &str, cases: Vec<Json>) {
    let mut sections: Vec<Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.get("sections").and_then(|a| a.as_arr().map(|x| x.to_vec())))
        .unwrap_or_default();
    sections.retain(|s| s.get("bench").and_then(Json::as_str) != Some(section));
    sections.push(Json::obj(vec![
        ("bench", Json::str(section)),
        ("cases", Json::arr(cases)),
    ]));
    sections.sort_by(|a, b| {
        let ka = a.get("bench").and_then(Json::as_str).unwrap_or("");
        let kb = b.get("bench").and_then(Json::as_str).unwrap_or("");
        ka.cmp(kb)
    });
    let out = Json::obj(vec![("sections", Json::arr(sections))]);
    if let Err(e) = std::fs::write(path, out.to_string()) {
        // The kernel report is acceptance evidence — never fail silently.
        eprintln!("warning: could not write {path}: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_collects_stats() {
        let mut b = Bencher { budget_secs: 0.2, max_iters: 5, warmup: 1, results: vec![] };
        let mean = b.case("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(mean >= 0.0);
        let r = &b.results()[0];
        assert!(r.iters >= 1 && r.iters <= 5);
        assert!(r.min_secs <= r.mean_secs && r.mean_secs <= r.max_secs + 1e-12);
    }

    #[test]
    fn kernel_sections_merge_and_replace() {
        let path = std::env::temp_dir()
            .join(format!("bench_kernels_test_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let case = |v: f64| Json::obj(vec![("speedup", Json::num(v))]);
        write_kernel_section(&path, "stage_knn", vec![case(2.0)]);
        write_kernel_section(&path, "stage_apsp", vec![case(3.0)]);
        // Re-running a section replaces it without touching the other.
        write_kernel_section(&path, "stage_apsp", vec![case(4.0)]);
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let sections = parsed.get("sections").unwrap().as_arr().unwrap();
        assert_eq!(sections.len(), 2);
        assert_eq!(sections[0].get("bench").unwrap().as_str(), Some("stage_apsp"));
        let apsp_cases = sections[0].get("cases").unwrap().as_arr().unwrap();
        assert_eq!(apsp_cases[0].get("speedup").unwrap().as_f64(), Some(4.0));
        assert_eq!(sections[1].get("bench").unwrap().as_str(), Some("stage_knn"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn json_roundtrips() {
        let mut b = Bencher { budget_secs: 0.05, max_iters: 2, warmup: 0, results: vec![] };
        b.case("x", || {});
        b.report_value("table1:swiss50:p2", 294.92, "virtual-min");
        let parsed = Json::parse(&b.json()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 2);
    }
}
