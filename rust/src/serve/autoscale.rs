//! Feedback controllers for the serve tier: adaptive micro-batch sizing
//! and worker-pool autoscaling.
//!
//! Both controllers are sampled by one control thread on a fixed
//! interval ([`crate::serve::CONTROL_INTERVAL`]) and only ever *observe*
//! monitoring data — the embed-latency histogram window and the queue
//! depths. Neither can change output bits: the batch cap only decides
//! how many queued requests share one pooled `map_points_with` call
//! (per-row results are independent of batch composition), and the pool
//! size only decides how many HTTP workers parse sockets.
//!
//! * [`BatchController`] — AIMD-flavored cap on rows drained per batch.
//!   While the windowed p95 is above `target_p95_us` the cap halves
//!   (shrink fast under pressure, down to `floor`); while it is below
//!   half the target the cap doubles (grow back toward `ceiling`). An
//!   idle window reads as p95 = 0 and therefore also grows — that is the
//!   re-convergence path after a load spike passes.
//! * [`PoolAutoscaler`] — ±1-worker steps between `min..=max`. Scale up
//!   immediately when the observed backlog exceeds the effective worker
//!   count; scale down only after `DOWN_COOLDOWN` consecutive
//!   near-idle intervals (backlog ≤ 1 *and* embed arrival under
//!   2 req/s), so a brief lull never thrashes the pool. Scale-down is
//!   advisory: the serve loop turns it into a *retire ticket* a worker
//!   consumes at its next idle wakeup.

use crate::engine::metrics::LatencySnapshot;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Consecutive near-idle control intervals before one scale-down step.
pub const DOWN_COOLDOWN: u64 = 10;
/// Arrival rate (embeds/second) below which an interval counts as idle.
const IDLE_ARRIVAL_QPS: f64 = 2.0;
/// Backlog at or below which an interval counts as idle (1 tolerates a
/// monitoring client's own connection).
const IDLE_BACKLOG: usize = 1;

/// What the batch controller did with its cap this window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchDecision {
    Grow(usize),
    Shrink(usize),
    Hold,
}

/// Adaptive drain-cap controller. `cap()` is read by the batch executor
/// before every drain; `observe_window` is called by the control thread
/// with the latency histogram's last window.
#[derive(Debug)]
pub struct BatchController {
    floor: usize,
    ceiling: usize,
    /// 0 disables adaptation (cap pinned at `ceiling`).
    target_p95_us: u64,
    cap: AtomicUsize,
    grows: AtomicU64,
    shrinks: AtomicU64,
    windows: AtomicU64,
    last_window_p95_us: AtomicU64,
}

impl BatchController {
    /// `target_p95_ms == 0` disables adaptation. The cap starts at the
    /// ceiling — the legacy fixed-cap behavior — and only moves once
    /// latency evidence says it should.
    pub fn new(floor: usize, ceiling: usize, target_p95_ms: f64) -> Self {
        let ceiling = ceiling.max(1);
        let floor = floor.clamp(1, ceiling);
        BatchController {
            floor,
            ceiling,
            target_p95_us: (target_p95_ms * 1_000.0).round() as u64,
            cap: AtomicUsize::new(ceiling),
            grows: AtomicU64::new(0),
            shrinks: AtomicU64::new(0),
            windows: AtomicU64::new(0),
            last_window_p95_us: AtomicU64::new(0),
        }
    }

    /// Rows the batch executor may drain into one pooled call right now.
    pub fn cap(&self) -> usize {
        self.cap.load(Ordering::Relaxed)
    }

    pub fn floor(&self) -> usize {
        self.floor
    }

    pub fn ceiling(&self) -> usize {
        self.ceiling
    }

    pub fn enabled(&self) -> bool {
        self.target_p95_us > 0
    }

    /// Feed one control-interval window of the embed-latency histogram.
    pub fn observe_window(&self, window: &LatencySnapshot) -> BatchDecision {
        if !self.enabled() {
            return BatchDecision::Hold;
        }
        self.windows.fetch_add(1, Ordering::Relaxed);
        let p95 = if window.count == 0 { 0.0 } else { window.percentile_us(0.95) };
        self.last_window_p95_us.store(p95 as u64, Ordering::Relaxed);
        let cur = self.cap.load(Ordering::Relaxed);
        if p95 > self.target_p95_us as f64 {
            let next = (cur / 2).max(self.floor);
            if next != cur {
                self.cap.store(next, Ordering::Relaxed);
                self.shrinks.fetch_add(1, Ordering::Relaxed);
                return BatchDecision::Shrink(next);
            }
        } else if p95 * 2.0 < self.target_p95_us as f64 {
            let next = (cur * 2).min(self.ceiling);
            if next != cur {
                self.cap.store(next, Ordering::Relaxed);
                self.grows.fetch_add(1, Ordering::Relaxed);
                return BatchDecision::Grow(next);
            }
        }
        BatchDecision::Hold
    }

    /// `/metrics` fragment.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("enabled", Json::Bool(self.enabled())),
            ("cap", Json::num(self.cap() as f64)),
            ("floor", Json::num(self.floor as f64)),
            ("ceiling", Json::num(self.ceiling as f64)),
            ("target_p95_us", Json::num(self.target_p95_us as f64)),
            (
                "last_window_p95_us",
                Json::num(self.last_window_p95_us.load(Ordering::Relaxed) as f64),
            ),
            ("grows", Json::num(self.grows.load(Ordering::Relaxed) as f64)),
            ("shrinks", Json::num(self.shrinks.load(Ordering::Relaxed) as f64)),
            ("windows", Json::num(self.windows.load(Ordering::Relaxed) as f64)),
        ])
    }
}

/// What the pool autoscaler asks the serve loop to do this interval.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Spawn one worker.
    Up,
    /// Issue one retire ticket.
    Down,
    Hold,
}

/// ±1-step worker-pool controller between `min..=max`.
#[derive(Debug)]
pub struct PoolAutoscaler {
    min: usize,
    max: usize,
    idle_intervals: AtomicU64,
    scale_ups: AtomicU64,
    scale_downs: AtomicU64,
    /// Gauges for `/metrics` (arrival stored as milli-qps).
    last_backlog: AtomicU64,
    last_arrival_mqps: AtomicU64,
}

impl PoolAutoscaler {
    pub fn new(min: usize, max: usize) -> Self {
        let min = min.max(1);
        PoolAutoscaler {
            min,
            max: max.max(min),
            idle_intervals: AtomicU64::new(0),
            scale_ups: AtomicU64::new(0),
            scale_downs: AtomicU64::new(0),
            last_backlog: AtomicU64::new(0),
            last_arrival_mqps: AtomicU64::new(0),
        }
    }

    pub fn bounds(&self) -> (usize, usize) {
        (self.min, self.max)
    }

    pub fn enabled(&self) -> bool {
        self.max > self.min
    }

    /// One control interval: `active` live workers of which
    /// `pending_retires` already hold a ticket, `backlog` connections +
    /// queued embeds awaiting a worker, `arrival_qps` embed requests per
    /// second over the interval.
    pub fn observe(
        &self,
        active: usize,
        pending_retires: usize,
        backlog: usize,
        arrival_qps: f64,
    ) -> ScaleDecision {
        self.last_backlog.store(backlog as u64, Ordering::Relaxed);
        self.last_arrival_mqps.store((arrival_qps * 1_000.0) as u64, Ordering::Relaxed);
        if !self.enabled() {
            return ScaleDecision::Hold;
        }
        let effective = active.saturating_sub(pending_retires).max(self.min.min(active));
        if backlog > effective && effective < self.max {
            self.idle_intervals.store(0, Ordering::Relaxed);
            self.scale_ups.fetch_add(1, Ordering::Relaxed);
            return ScaleDecision::Up;
        }
        if backlog <= IDLE_BACKLOG && arrival_qps < IDLE_ARRIVAL_QPS {
            let idle = self.idle_intervals.fetch_add(1, Ordering::Relaxed) + 1;
            if idle >= DOWN_COOLDOWN && effective > self.min {
                self.idle_intervals.store(0, Ordering::Relaxed);
                self.scale_downs.fetch_add(1, Ordering::Relaxed);
                return ScaleDecision::Down;
            }
        } else {
            self.idle_intervals.store(0, Ordering::Relaxed);
        }
        ScaleDecision::Hold
    }

    /// `/metrics` fragment; `active`/`pending_retires` live in the serve
    /// loop, so the caller passes them in.
    pub fn to_json(&self, active: usize, pending_retires: usize) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("enabled", Json::Bool(self.enabled())),
            ("min", Json::num(self.min as f64)),
            ("max", Json::num(self.max as f64)),
            ("active", Json::num(active as f64)),
            ("pending_retires", Json::num(pending_retires as f64)),
            ("scale_ups", Json::num(self.scale_ups.load(Ordering::Relaxed) as f64)),
            ("scale_downs", Json::num(self.scale_downs.load(Ordering::Relaxed) as f64)),
            ("last_backlog", Json::num(self.last_backlog.load(Ordering::Relaxed) as f64)),
            (
                "last_arrival_qps",
                Json::num(self.last_arrival_mqps.load(Ordering::Relaxed) as f64 / 1_000.0),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::metrics::LatencyHistogram;

    fn window_with(lat_us: u64, count: usize) -> LatencySnapshot {
        let h = LatencyHistogram::new();
        for _ in 0..count {
            h.record_us(lat_us);
        }
        h.snapshot()
    }

    #[test]
    fn batch_cap_shrinks_under_pressure_to_floor() {
        let c = BatchController::new(4, 64, 1.0); // target p95 = 1000µs
        assert_eq!(c.cap(), 64, "starts at ceiling");
        let slow = window_with(5_000, 100); // p95 = 5000µs > target
        assert_eq!(c.observe_window(&slow), BatchDecision::Shrink(32));
        assert_eq!(c.observe_window(&slow), BatchDecision::Shrink(16));
        assert_eq!(c.observe_window(&slow), BatchDecision::Shrink(8));
        assert_eq!(c.observe_window(&slow), BatchDecision::Shrink(4));
        // Clamped at the floor: further pressure holds.
        assert_eq!(c.observe_window(&slow), BatchDecision::Hold);
        assert_eq!(c.cap(), 4);
    }

    #[test]
    fn batch_cap_regrows_when_fast_or_idle() {
        let c = BatchController::new(4, 64, 1.0);
        let slow = window_with(5_000, 100);
        while c.observe_window(&slow) != BatchDecision::Hold {}
        assert_eq!(c.cap(), 4);
        // Fast windows (p95 < target/2) double the cap back up...
        let fast = window_with(100, 100); // p95 = 100µs, 2·100 < 1000
        assert_eq!(c.observe_window(&fast), BatchDecision::Grow(8));
        // ...and so do idle windows (p95 reads as 0) — re-convergence.
        let idle = LatencyHistogram::new().snapshot();
        assert_eq!(c.observe_window(&idle), BatchDecision::Grow(16));
        assert_eq!(c.observe_window(&idle), BatchDecision::Grow(32));
        assert_eq!(c.observe_window(&idle), BatchDecision::Grow(64));
        assert_eq!(c.observe_window(&idle), BatchDecision::Hold);
        assert_eq!(c.cap(), 64);
    }

    #[test]
    fn batch_cap_holds_in_the_dead_band() {
        let c = BatchController::new(4, 64, 1.0);
        // p95 = 1000µs: not above target, not below half of it.
        let mid = window_with(700, 100); // bucket upper bound 1000µs
        assert_eq!(c.observe_window(&mid), BatchDecision::Hold);
        assert_eq!(c.cap(), 64);
    }

    #[test]
    fn disabled_controller_pins_cap_at_ceiling() {
        let c = BatchController::new(4, 64, 0.0);
        assert!(!c.enabled());
        let slow = window_with(5_000, 100);
        assert_eq!(c.observe_window(&slow), BatchDecision::Hold);
        assert_eq!(c.cap(), 64);
    }

    #[test]
    fn pool_scales_up_on_backlog_within_bounds() {
        let s = PoolAutoscaler::new(1, 4);
        // Backlog above the worker count: up, repeatedly, until max.
        assert_eq!(s.observe(1, 0, 8, 100.0), ScaleDecision::Up);
        assert_eq!(s.observe(2, 0, 8, 100.0), ScaleDecision::Up);
        assert_eq!(s.observe(3, 0, 8, 100.0), ScaleDecision::Up);
        // At max: hold even with backlog.
        assert_eq!(s.observe(4, 0, 8, 100.0), ScaleDecision::Hold);
    }

    #[test]
    fn pool_scales_down_only_after_cooldown() {
        let s = PoolAutoscaler::new(1, 4);
        for i in 0..DOWN_COOLDOWN - 1 {
            assert_eq!(s.observe(4, 0, 0, 0.0), ScaleDecision::Hold, "interval {i}");
        }
        assert_eq!(s.observe(4, 0, 0, 0.0), ScaleDecision::Down);
        // Counter reset: the next step-down needs a full cooldown again.
        assert_eq!(s.observe(3, 1, 0, 0.0), ScaleDecision::Hold);
    }

    #[test]
    fn busy_interval_resets_the_idle_counter() {
        let s = PoolAutoscaler::new(1, 4);
        for _ in 0..DOWN_COOLDOWN - 1 {
            assert_eq!(s.observe(2, 0, 0, 0.0), ScaleDecision::Hold);
        }
        // A burst of arrivals (no backlog yet) resets the cooldown.
        assert_eq!(s.observe(2, 0, 1, 50.0), ScaleDecision::Hold);
        for _ in 0..DOWN_COOLDOWN - 1 {
            assert_eq!(s.observe(2, 0, 0, 0.0), ScaleDecision::Hold);
        }
        assert_eq!(s.observe(2, 0, 0, 0.0), ScaleDecision::Down);
    }

    #[test]
    fn pool_never_retires_below_min() {
        let s = PoolAutoscaler::new(2, 4);
        for _ in 0..DOWN_COOLDOWN * 3 {
            let d = s.observe(2, 0, 0, 0.0);
            assert_eq!(d, ScaleDecision::Hold, "at min, never Down");
        }
        // Pending retires count against the effective size.
        for _ in 0..DOWN_COOLDOWN * 3 {
            let d = s.observe(3, 1, 0, 0.0);
            assert_eq!(d, ScaleDecision::Hold, "3 active - 1 retiring = min");
        }
    }

    #[test]
    fn fixed_pool_is_inert() {
        let s = PoolAutoscaler::new(4, 4);
        assert!(!s.enabled());
        assert_eq!(s.observe(4, 0, 100, 1e6), ScaleDecision::Hold);
        for _ in 0..DOWN_COOLDOWN * 2 {
            assert_eq!(s.observe(4, 0, 0, 0.0), ScaleDecision::Hold);
        }
    }

    #[test]
    fn monitoring_client_does_not_block_scale_down() {
        // A /metrics poller keeps ~1 connection around: backlog 1 with
        // no embed arrivals must still count as idle.
        let s = PoolAutoscaler::new(1, 4);
        for _ in 0..DOWN_COOLDOWN - 1 {
            assert_eq!(s.observe(3, 0, 1, 0.5), ScaleDecision::Hold);
        }
        assert_eq!(s.observe(3, 0, 1, 0.5), ScaleDecision::Down);
    }
}
