//! Minimal loopback HTTP client: keep-alive connections, JSON helpers,
//! and the load generator behind `isospark bench-serve` and the
//! `serve_latency` bench. Tests use it to assert that what comes back over
//! a real TCP socket is bit-identical to an in-process `map_points`.

use super::{matrix_from_json, matrix_to_json, percentile};
use crate::linalg::Matrix;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

/// One keep-alive connection to the server.
pub struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Conn {
    pub fn connect(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(Conn { stream, buf: Vec::new() })
    }

    /// Issue one request and read the full response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let b = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: isospark\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            b.len()
        );
        self.stream.write_all(head.as_bytes()).context("send request head")?;
        self.stream.write_all(b.as_bytes()).context("send request body")?;
        loop {
            if let Some((code, body, used)) = parse_response(&self.buf)? {
                self.buf.drain(..used);
                return Ok((code, body));
            }
            let mut tmp = [0u8; 8192];
            let n = self.stream.read(&mut tmp).context("read response")?;
            if n == 0 {
                bail!("server closed the connection mid-response");
            }
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }
}

/// Parse one complete response (status + content-length body) from the
/// front of `buf`; `None` when incomplete.
fn parse_response(buf: &[u8]) -> Result<Option<(u16, String, usize)>> {
    let Some(head_end) = buf.windows(4).position(|w| w == b"\r\n\r\n") else {
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("response head not UTF-8")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let code: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow!("bad status line {status_line:?}"))?;
    let mut body_len = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                body_len = value.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    let total = head_end + 4 + body_len;
    if buf.len() < total {
        return Ok(None);
    }
    let body = String::from_utf8_lossy(&buf[head_end + 4..total]).into_owned();
    Ok(Some((code, body, total)))
}

/// `GET path` on a fresh connection, parsing the JSON body.
pub fn get_json(addr: &str, path: &str) -> Result<(u16, Json)> {
    let mut c = Conn::connect(addr)?;
    let (code, body) = c.request("GET", path, None)?;
    let j = Json::parse(&body)
        .map_err(|e| anyhow!("non-JSON body from {path} (status {code}): {e}; body: {body:.200}"))?;
    Ok((code, j))
}

/// `POST path` with a JSON body on a fresh connection.
pub fn post_json(addr: &str, path: &str, body: &Json) -> Result<(u16, Json)> {
    let mut c = Conn::connect(addr)?;
    let (code, text) = c.request("POST", path, Some(&body.to_string()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow!("non-JSON body from {path} (status {code}): {e}; body: {text:.200}"))?;
    Ok((code, j))
}

/// Embed `pts` over an existing connection.
pub fn embed_on(conn: &mut Conn, pts: &Matrix) -> Result<Matrix> {
    let body = Json::obj(vec![("points", matrix_to_json(pts))]).to_string();
    let (code, text) = conn.request("POST", "/v1/embed", Some(&body))?;
    if code != 200 {
        bail!("embed failed with status {code}: {text:.200}");
    }
    let j = Json::parse(&text).map_err(|e| anyhow!("bad embed response: {e}"))?;
    let emb = j.get("embedding").ok_or_else(|| anyhow!("embed response missing \"embedding\""))?;
    matrix_from_json(emb).map_err(|e| anyhow!("bad embedding matrix: {e}"))
}

/// Embed `pts` on a fresh connection.
pub fn embed(addr: &str, pts: &Matrix) -> Result<Matrix> {
    let mut c = Conn::connect(addr)?;
    embed_on(&mut c, pts)
}

/// Aggregate result of one loopback load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub requests: usize,
    pub wall_secs: f64,
    pub qps: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl LoadReport {
    pub fn to_json(&self, name: &str, clients: usize, pts_per_request: usize) -> Json {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("clients", Json::num(clients as f64)),
            ("pts_per_request", Json::num(pts_per_request as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("qps", Json::num(self.qps)),
            ("mean_us", Json::num(self.mean_us)),
            ("p50_us", Json::num(self.p50_us)),
            ("p95_us", Json::num(self.p95_us)),
            ("p99_us", Json::num(self.p99_us)),
            ("max_us", Json::num(self.max_us)),
        ])
    }
}

/// Drive `clients` keep-alive connections, each sending
/// `requests_per_client` embed requests of `pts_per_request` rows drawn
/// from `pool` (offsets staggered per client so concurrent requests carry
/// different payloads). Returns exact client-side latency percentiles.
pub fn loopback_load(
    addr: &str,
    clients: usize,
    requests_per_client: usize,
    pts_per_request: usize,
    pool: &Matrix,
) -> Result<LoadReport> {
    if pool.nrows() < pts_per_request {
        bail!("query pool has {} rows < {pts_per_request} per request", pool.nrows());
    }
    let span = pool.nrows() - pts_per_request + 1;
    let sw = Instant::now();
    let per_client: Vec<Result<Vec<f64>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || -> Result<Vec<f64>> {
                    let mut conn = Conn::connect(addr)?;
                    let mut lats = Vec::with_capacity(requests_per_client);
                    for r in 0..requests_per_client {
                        let start = (c * 131 + r * pts_per_request) % span;
                        let pts = pool.slice(start, start + pts_per_request, 0, pool.ncols());
                        let t = Instant::now();
                        let emb = embed_on(&mut conn, &pts)?;
                        lats.push(t.elapsed().as_secs_f64() * 1e6);
                        if emb.nrows() != pts_per_request {
                            bail!("embed returned {} rows, want {pts_per_request}", emb.nrows());
                        }
                    }
                    Ok(lats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("load client panicked"))))
            .collect()
    });
    let wall = sw.elapsed().as_secs_f64();
    let mut lats: Vec<f64> = Vec::with_capacity(clients * requests_per_client);
    for r in per_client {
        lats.extend(r.context("load client failed")?);
    }
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = lats.len();
    Ok(LoadReport {
        requests: n,
        wall_secs: wall,
        qps: if wall > 0.0 { n as f64 / wall } else { 0.0 },
        mean_us: if n == 0 { 0.0 } else { lats.iter().sum::<f64>() / n as f64 },
        p50_us: percentile(&lats, 0.50),
        p95_us: percentile(&lats, 0.95),
        p99_us: percentile(&lats, 0.99),
        max_us: lats.last().copied().unwrap_or(0.0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_response_frames() {
        let raw = b"HTTP/1.1 200 OK\r\nContent-Length: 4\r\n\r\nbodyNEXT";
        let (code, body, used) = parse_response(raw).unwrap().unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "body");
        assert_eq!(&raw[used..], b"NEXT");
        assert!(parse_response(b"HTTP/1.1 200 OK\r\nContent-Length: 9\r\n\r\nshort")
            .unwrap()
            .is_none());
        assert!(parse_response(b"GARBAGE\r\n\r\n").is_err());
    }
}
