//! Minimal loopback HTTP client: keep-alive connections, JSON helpers,
//! and the load generator behind `isospark bench-serve` and the
//! `serve_latency` bench. Tests use it to assert that what comes back over
//! a real TCP socket is bit-identical to an in-process `map_points`.

use super::{matrix_from_json, matrix_to_json, percentile};
use crate::linalg::Matrix;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// One keep-alive connection to the server.
pub struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Conn {
    pub fn connect(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(Conn { stream, buf: Vec::new() })
    }

    /// Issue one request and read the full response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let r = self.request_response(method, path, body)?;
        Ok((r.status, r.body))
    }

    /// Issue one request and read the full response including headers
    /// (needed by load-shedding clients that honor `Retry-After`).
    pub fn request_response(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<Response> {
        let b = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: isospark\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            b.len()
        );
        self.stream.write_all(head.as_bytes()).context("send request head")?;
        self.stream.write_all(b.as_bytes()).context("send request body")?;
        loop {
            if let Some((resp, used)) = parse_response_full(&self.buf)? {
                self.buf.drain(..used);
                return Ok(resp);
            }
            let mut tmp = [0u8; 8192];
            let n = self.stream.read(&mut tmp).context("read response")?;
            if n == 0 {
                bail!("server closed the connection mid-response");
            }
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }
}

/// One parsed HTTP response: status, headers (names lower-cased), body.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl Response {
    /// First header with the given (lower-case) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// Parse one complete response (status + content-length body) from the
/// front of `buf`; `None` when incomplete.
fn parse_response(buf: &[u8]) -> Result<Option<(u16, String, usize)>> {
    Ok(parse_response_full(buf)?.map(|(r, used)| (r.status, r.body, used)))
}

/// [`parse_response`] keeping the headers.
fn parse_response_full(buf: &[u8]) -> Result<Option<(Response, usize)>> {
    let Some(head_end) = buf.windows(4).position(|w| w == b"\r\n\r\n") else {
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("response head not UTF-8")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let code: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow!("bad status line {status_line:?}"))?;
    let mut headers = Vec::new();
    let mut body_len = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                body_len = value.parse().context("bad Content-Length")?;
            }
            headers.push((name, value));
        }
    }
    let total = head_end + 4 + body_len;
    if buf.len() < total {
        return Ok(None);
    }
    let body = String::from_utf8_lossy(&buf[head_end + 4..total]).into_owned();
    Ok(Some((Response { status: code, headers, body }, total)))
}

/// `GET path` on a fresh connection, parsing the JSON body.
pub fn get_json(addr: &str, path: &str) -> Result<(u16, Json)> {
    let mut c = Conn::connect(addr)?;
    let (code, body) = c.request("GET", path, None)?;
    let j = Json::parse(&body)
        .map_err(|e| anyhow!("non-JSON body from {path} (status {code}): {e}; body: {body:.200}"))?;
    Ok((code, j))
}

/// `POST path` with a JSON body on a fresh connection.
pub fn post_json(addr: &str, path: &str, body: &Json) -> Result<(u16, Json)> {
    let mut c = Conn::connect(addr)?;
    let (code, text) = c.request("POST", path, Some(&body.to_string()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow!("non-JSON body from {path} (status {code}): {e}; body: {text:.200}"))?;
    Ok((code, j))
}

/// Embed `pts` over an existing connection via `path` (the legacy
/// `/v1/embed` or a model-scoped `/v1/models/<name>/embed`).
pub fn embed_path_on(conn: &mut Conn, path: &str, pts: &Matrix) -> Result<Matrix> {
    let body = Json::obj(vec![("points", matrix_to_json(pts))]).to_string();
    let (code, text) = conn.request("POST", path, Some(&body))?;
    if code != 200 {
        bail!("embed failed with status {code}: {text:.200}");
    }
    let j = Json::parse(&text).map_err(|e| anyhow!("bad embed response: {e}"))?;
    let emb = j.get("embedding").ok_or_else(|| anyhow!("embed response missing \"embedding\""))?;
    matrix_from_json(emb).map_err(|e| anyhow!("bad embedding matrix: {e}"))
}

/// Embed `pts` over an existing connection (legacy default-model path).
pub fn embed_on(conn: &mut Conn, pts: &Matrix) -> Result<Matrix> {
    embed_path_on(conn, "/v1/embed", pts)
}

/// Embed `pts` against the named model on a fresh connection.
pub fn embed_model(addr: &str, model: &str, pts: &Matrix) -> Result<Matrix> {
    let mut c = Conn::connect(addr)?;
    embed_path_on(&mut c, &format!("/v1/models/{model}/embed"), pts)
}

/// Embed `pts` on a fresh connection.
pub fn embed(addr: &str, pts: &Matrix) -> Result<Matrix> {
    let mut c = Conn::connect(addr)?;
    embed_on(&mut c, pts)
}

/// Aggregate result of one loopback load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub requests: usize,
    pub wall_secs: f64,
    pub qps: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl LoadReport {
    pub fn to_json(&self, name: &str, clients: usize, pts_per_request: usize) -> Json {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("clients", Json::num(clients as f64)),
            ("pts_per_request", Json::num(pts_per_request as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("qps", Json::num(self.qps)),
            ("mean_us", Json::num(self.mean_us)),
            ("p50_us", Json::num(self.p50_us)),
            ("p95_us", Json::num(self.p95_us)),
            ("p99_us", Json::num(self.p99_us)),
            ("max_us", Json::num(self.max_us)),
        ])
    }
}

/// Drive `clients` keep-alive connections, each sending
/// `requests_per_client` embed requests of `pts_per_request` rows drawn
/// from `pool` (offsets staggered per client so concurrent requests carry
/// different payloads). Returns exact client-side latency percentiles.
pub fn loopback_load(
    addr: &str,
    clients: usize,
    requests_per_client: usize,
    pts_per_request: usize,
    pool: &Matrix,
) -> Result<LoadReport> {
    if pool.nrows() < pts_per_request {
        bail!("query pool has {} rows < {pts_per_request} per request", pool.nrows());
    }
    let span = pool.nrows() - pts_per_request + 1;
    let sw = Instant::now();
    let per_client: Vec<Result<Vec<f64>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || -> Result<Vec<f64>> {
                    let mut conn = Conn::connect(addr)?;
                    let mut lats = Vec::with_capacity(requests_per_client);
                    for r in 0..requests_per_client {
                        let start = (c * 131 + r * pts_per_request) % span;
                        let pts = pool.slice(start, start + pts_per_request, 0, pool.ncols());
                        let t = Instant::now();
                        let emb = embed_on(&mut conn, &pts)?;
                        lats.push(t.elapsed().as_secs_f64() * 1e6);
                        if emb.nrows() != pts_per_request {
                            bail!("embed returned {} rows, want {pts_per_request}", emb.nrows());
                        }
                    }
                    Ok(lats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("load client panicked"))))
            .collect()
    });
    let wall = sw.elapsed().as_secs_f64();
    let mut lats: Vec<f64> = Vec::with_capacity(clients * requests_per_client);
    for r in per_client {
        lats.extend(r.context("load client failed")?);
    }
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = lats.len();
    Ok(LoadReport {
        requests: n,
        wall_secs: wall,
        qps: if wall > 0.0 { n as f64 / wall } else { 0.0 },
        mean_us: if n == 0 { 0.0 } else { lats.iter().sum::<f64>() / n as f64 },
        p50_us: percentile(&lats, 0.50),
        p95_us: percentile(&lats, 0.95),
        p99_us: percentile(&lats, 0.99),
        max_us: lats.last().copied().unwrap_or(0.0),
    })
}

/// One step of paced (open-loop) load: requests are launched on a fixed
/// schedule derived from `target_qps` rather than back-to-back, so the
/// offered load is controlled even when the server slows down — the gap
/// between offered and achieved QPS is exactly the saturation signal the
/// soak ladder looks for.
#[derive(Clone, Debug)]
pub struct PacedReport {
    pub target_qps: f64,
    pub wall_secs: f64,
    /// Requests that got any HTTP response.
    pub sent: usize,
    pub ok: usize,
    /// 429/503 rejections.
    pub shed: usize,
    /// Transport failures + unexpected statuses.
    pub errors: usize,
    /// Successful embeds per second.
    pub achieved_qps: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    /// Whether every observed rejection carried a `Retry-After` header.
    pub shed_has_retry_after: bool,
}

impl PacedReport {
    pub fn shed_fraction(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.shed as f64 / self.sent as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("target_qps", Json::num(self.target_qps)),
            ("achieved_qps", Json::num(self.achieved_qps)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("sent", Json::num(self.sent as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("shed_fraction", Json::num(self.shed_fraction())),
            ("mean_us", Json::num(self.mean_us)),
            ("p50_us", Json::num(self.p50_us)),
            ("p95_us", Json::num(self.p95_us)),
            ("p99_us", Json::num(self.p99_us)),
            ("max_us", Json::num(self.max_us)),
        ])
    }
}

#[derive(Default)]
struct ClientTally {
    sent: usize,
    ok: usize,
    shed: usize,
    errors: usize,
    lats_us: Vec<f64>,
    all_shed_had_retry_after: bool,
    saw_shed: bool,
}

/// Hold `target_qps` of embed load against `path` for `secs` seconds.
/// Pacing is spread over enough client threads that one slow response
/// does not stall the whole schedule; a client whose connection dies
/// reconnects and keeps pacing. Shed responses (429/503) are counted,
/// not retried — the ladder wants to *see* the shed rate.
pub fn paced_load(
    addr: &str,
    path: &str,
    target_qps: f64,
    secs: f64,
    pts_per_request: usize,
    pool: &Matrix,
) -> Result<PacedReport> {
    if pool.nrows() < pts_per_request {
        bail!("query pool has {} rows < {pts_per_request} per request", pool.nrows());
    }
    if !(target_qps > 0.0) || !(secs > 0.0) {
        bail!("paced load needs positive target_qps and secs");
    }
    let span = pool.nrows() - pts_per_request + 1;
    // ~5 in-flight-capable requests per client keeps pacing honest up to
    // a few hundred ms of per-request latency.
    let clients = ((target_qps / 5.0).ceil() as usize).clamp(1, 32);
    let interval = clients as f64 / target_qps;
    let sw = Instant::now();
    let per_client: Vec<Result<ClientTally>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || -> Result<ClientTally> {
                    let mut conn = Conn::connect(addr)?;
                    let mut t =
                        ClientTally { all_shed_had_retry_after: true, ..ClientTally::default() };
                    let start = Instant::now();
                    // Stagger client schedules across one interval.
                    let mut next = interval * c as f64 / clients as f64;
                    let mut r = 0usize;
                    while start.elapsed().as_secs_f64() < secs {
                        let now = start.elapsed().as_secs_f64();
                        if now < next {
                            std::thread::sleep(Duration::from_secs_f64((next - now).min(0.05)));
                            continue;
                        }
                        next += interval;
                        let off = (c * 131 + r * pts_per_request) % span;
                        r += 1;
                        let pts = pool.slice(off, off + pts_per_request, 0, pool.ncols());
                        let body =
                            Json::obj(vec![("points", matrix_to_json(&pts))]).to_string();
                        let t0 = Instant::now();
                        match conn.request_response("POST", path, Some(&body)) {
                            Ok(resp) => {
                                t.sent += 1;
                                match resp.status {
                                    200 => {
                                        t.ok += 1;
                                        t.lats_us.push(t0.elapsed().as_secs_f64() * 1e6);
                                    }
                                    429 | 503 => {
                                        t.shed += 1;
                                        t.saw_shed = true;
                                        if resp.header("retry-after").is_none() {
                                            t.all_shed_had_retry_after = false;
                                        }
                                    }
                                    _ => t.errors += 1,
                                }
                            }
                            Err(_) => {
                                // Connection killed (stall cutoff, server
                                // stopping): reconnect and keep pacing.
                                t.errors += 1;
                                match Conn::connect(addr) {
                                    Ok(fresh) => conn = fresh,
                                    Err(_) => break,
                                }
                            }
                        }
                    }
                    Ok(t)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("paced client panicked"))))
            .collect()
    });
    let wall = sw.elapsed().as_secs_f64();
    let mut agg = ClientTally { all_shed_had_retry_after: true, ..ClientTally::default() };
    for r in per_client {
        let t = r.context("paced load client failed")?;
        agg.sent += t.sent;
        agg.ok += t.ok;
        agg.shed += t.shed;
        agg.errors += t.errors;
        agg.saw_shed |= t.saw_shed;
        agg.all_shed_had_retry_after &= t.all_shed_had_retry_after;
        agg.lats_us.extend(t.lats_us);
    }
    agg.lats_us.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = agg.lats_us.len();
    Ok(PacedReport {
        target_qps,
        wall_secs: wall,
        sent: agg.sent,
        ok: agg.ok,
        shed: agg.shed,
        errors: agg.errors,
        achieved_qps: if wall > 0.0 { agg.ok as f64 / wall } else { 0.0 },
        mean_us: if n == 0 { 0.0 } else { agg.lats_us.iter().sum::<f64>() / n as f64 },
        p50_us: percentile(&agg.lats_us, 0.50),
        p95_us: percentile(&agg.lats_us, 0.95),
        p99_us: percentile(&agg.lats_us, 0.99),
        max_us: agg.lats_us.last().copied().unwrap_or(0.0),
        shed_has_retry_after: !agg.saw_shed || agg.all_shed_had_retry_after,
    })
}

/// Result of walking the QPS ladder: every step, plus the knee — the last
/// rung the server held *healthily* (achieved ≥ 90% of offered, shed ≤ 5%).
#[derive(Clone, Debug)]
pub struct SoakOutcome {
    pub steps: Vec<PacedReport>,
    /// Achieved QPS at the knee (0 when even the first rung collapsed).
    pub knee_qps: f64,
    /// Client-side p95 latency (µs) at the knee.
    pub knee_p95_us: f64,
    /// True when the ladder ended by overload rather than by `qps_max`.
    pub saturated: bool,
}

impl SoakOutcome {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("knee_qps", Json::num(self.knee_qps)),
            ("knee_p95_us", Json::num(self.knee_p95_us)),
            ("saturated", Json::Bool(self.saturated)),
            ("steps", Json::arr(self.steps.iter().map(PacedReport::to_json).collect())),
        ])
    }
}

/// Soak the server: hold `start_qps` for `secs_per_step`, then double the
/// offered load until either the server stops keeping up (achieved < 90%
/// of offered, or > 5% shed) or `qps_max` is reached. The knee of the
/// latency/throughput curve is the last healthy rung.
pub fn soak(
    addr: &str,
    path: &str,
    start_qps: f64,
    qps_max: f64,
    secs_per_step: f64,
    pts_per_request: usize,
    pool: &Matrix,
) -> Result<SoakOutcome> {
    let mut steps: Vec<PacedReport> = Vec::new();
    let mut qps = start_qps.max(1.0);
    let mut knee: Option<(f64, f64)> = None;
    let mut saturated = false;
    loop {
        let step = paced_load(addr, path, qps, secs_per_step, pts_per_request, pool)?;
        let healthy = step.achieved_qps >= 0.9 * step.target_qps && step.shed_fraction() <= 0.05;
        let (aq, p95) = (step.achieved_qps, step.p95_us);
        steps.push(step);
        if healthy {
            knee = Some((aq, p95));
        } else {
            saturated = true;
            break;
        }
        if qps >= qps_max {
            break;
        }
        qps = (qps * 2.0).min(qps_max);
    }
    let (knee_qps, knee_p95_us) = knee.unwrap_or((0.0, 0.0));
    Ok(SoakOutcome { steps, knee_qps, knee_p95_us, saturated })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_response_frames() {
        let raw = b"HTTP/1.1 200 OK\r\nContent-Length: 4\r\n\r\nbodyNEXT";
        let (code, body, used) = parse_response(raw).unwrap().unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "body");
        assert_eq!(&raw[used..], b"NEXT");
        assert!(parse_response(b"HTTP/1.1 200 OK\r\nContent-Length: 9\r\n\r\nshort")
            .unwrap()
            .is_none());
        assert!(parse_response(b"GARBAGE\r\n\r\n").is_err());
    }

    #[test]
    fn parse_response_full_keeps_headers() {
        let raw = b"HTTP/1.1 503 Service Unavailable\r\nRetry-After: 7\r\nContent-Length: 2\r\n\r\n{}";
        let (resp, used) = parse_response_full(raw).unwrap().unwrap();
        assert_eq!(resp.status, 503);
        assert_eq!(resp.header("retry-after"), Some("7"));
        assert_eq!(resp.body, "{}");
        assert_eq!(used, raw.len());
    }
}
