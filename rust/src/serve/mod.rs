//! `isospark serve` — an embedding server over a saved [`FittedModel`].
//!
//! The ROADMAP's north star is a fitted manifold that *outlives* the O(n³)
//! batch job and serves projections to clients. This module is that layer:
//! a dependency-free HTTP/1.1 server on `std::net::TcpListener` (request
//! framing hand-rolled in [`http`], as `util::json` hand-rolls JSON)
//! exposing
//!
//! * `POST /v1/embed` — `{"points": [[…],…]}` → `{"embedding": [[…],…]}`,
//!   bit-identical to calling [`FittedModel::map_points`] in-process;
//! * `GET  /healthz` — liveness + model summary;
//! * `GET  /metrics` — request counters, embed latency histogram with
//!   approximate p50/p95/p99, QPS, micro-batching stats, and (when the
//!   server was started with a PJRT backend) the per-op offload-coverage
//!   counters from [`crate::engine::metrics::OffloadStats`];
//! * `POST /v1/reload` — atomically hot-swap the model from disk behind
//!   `RwLock<Arc<FittedModel>>`; a failed load keeps the current model.
//!
//! ## Architecture
//!
//! Connections are accepted by one acceptor thread and claimed by a pool
//! of worker threads from a shared queue — the same
//! dynamic-claiming shape as [`crate::engine::executor`], but long-lived
//! because connections (unlike stage tasks) are open-ended. Workers parse
//! requests and answer everything except `/v1/embed` directly.
//!
//! ## Micro-batching
//!
//! Embed requests do not call the model from the worker: they enqueue the
//! parsed points and block on a response channel. A single batch-executor
//! thread drains *everything currently queued* (up to `max_batch` points),
//! concatenates it into one matrix, runs one
//! [`FittedModel::map_points_with`] call on the worker pool, and scatters
//! the rows back to the waiting requests. While a batch executes, new
//! arrivals pile up and form the next batch — classic adaptive batching:
//! zero added latency when idle, block-sized backend calls under load.
//! Because each row is projected by the same serial code regardless of
//! batch composition, coalescing never changes bits.

pub mod client;
pub mod http;

use crate::backend::Backend;
use crate::model::FittedModel;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address (default loopback; set `0.0.0.0` to expose).
    pub host: String,
    /// TCP port; 0 binds an ephemeral port (see [`ServerHandle::port`]).
    pub port: u16,
    /// HTTP worker threads, which is also the `map_points` pool size
    /// (0 = all cores).
    pub threads: usize,
    /// Maximum points coalesced into one `map_points` call.
    pub max_batch: usize,
    /// Load shedding: maximum embed requests parked in the micro-batch
    /// queue. Arrivals beyond the bound are answered immediately with
    /// `503` + `Retry-After` instead of queueing without limit — bounded
    /// memory and bounded worst-case latency under overload. The default
    /// is generous; `0` sheds everything (useful for tests).
    pub max_queue: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            host: "127.0.0.1".to_string(),
            port: 0,
            threads: 0,
            max_batch: 1024,
            max_queue: 4096,
        }
    }
}

/// Upper bounds (µs) of the embed-latency histogram buckets; one implicit
/// overflow bucket follows.
const LAT_BUCKETS_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000];

/// Wait slice for idle condvar loops; shutdown latency is bounded by it.
const POLL: Duration = Duration::from_millis(250);

/// Socket read slice: how long a worker blocks on one connection before
/// re-checking for queued peers (bounds the scheduling latency a parked
/// idle connection can inflict on a waiting one).
const READ_SLICE: Duration = Duration::from_millis(50);

/// Read slices a connection may stall *mid-request* before it is answered
/// with 408 and dropped (100 × 50 ms = 5 s).
const MAX_STALL_SLICES: u32 = 100;

/// Per-syscall write timeout: the longest a worker can be pinned by a
/// client that stopped reading its response.
const WRITE_LIMIT: Duration = Duration::from_secs(10);

/// Thread-safe server counters (all relaxed atomics — monitoring data).
struct ServerMetrics {
    started: Instant,
    embed: AtomicU64,
    healthz: AtomicU64,
    metrics: AtomicU64,
    reload: AtomicU64,
    errors: AtomicU64,
    shed: AtomicU64,
    batches: AtomicU64,
    batched_points: AtomicU64,
    max_batch_points: AtomicU64,
    lat_count: AtomicU64,
    lat_sum_us: AtomicU64,
    lat_max_us: AtomicU64,
    lat_buckets: [AtomicU64; LAT_BUCKETS_US.len() + 1],
}

impl ServerMetrics {
    fn new() -> Self {
        Self {
            started: Instant::now(),
            embed: AtomicU64::new(0),
            healthz: AtomicU64::new(0),
            metrics: AtomicU64::new(0),
            reload: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_points: AtomicU64::new(0),
            max_batch_points: AtomicU64::new(0),
            lat_count: AtomicU64::new(0),
            lat_sum_us: AtomicU64::new(0),
            lat_max_us: AtomicU64::new(0),
            lat_buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn record_latency_us(&self, us: u64) {
        self.lat_count.fetch_add(1, Ordering::Relaxed);
        self.lat_sum_us.fetch_add(us, Ordering::Relaxed);
        self.lat_max_us.fetch_max(us, Ordering::Relaxed);
        let idx = LAT_BUCKETS_US
            .iter()
            .position(|&le| us <= le)
            .unwrap_or(LAT_BUCKETS_US.len());
        self.lat_buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate quantile from the histogram: the upper bound of the
    /// bucket holding the q-th request (max observed for the overflow
    /// bucket).
    fn percentile_us(&self, q: f64) -> f64 {
        let count = self.lat_count.load(Ordering::Relaxed);
        if count == 0 {
            return 0.0;
        }
        let target = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut cum = 0u64;
        for (i, b) in self.lat_buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return match LAT_BUCKETS_US.get(i) {
                    Some(&le) => le as f64,
                    None => self.lat_max_us.load(Ordering::Relaxed) as f64,
                };
            }
        }
        self.lat_max_us.load(Ordering::Relaxed) as f64
    }

    fn to_json(&self, model: &FittedModel, backend: Option<&Backend>) -> Json {
        let uptime = self.started.elapsed().as_secs_f64();
        let embeds = self.embed.load(Ordering::Relaxed);
        let count = self.lat_count.load(Ordering::Relaxed);
        let mean_us = if count == 0 {
            0.0
        } else {
            self.lat_sum_us.load(Ordering::Relaxed) as f64 / count as f64
        };
        let mut hist: Vec<Json> = LAT_BUCKETS_US
            .iter()
            .enumerate()
            .map(|(i, &le)| {
                Json::obj(vec![
                    ("le_us", Json::num(le as f64)),
                    ("count", Json::num(self.lat_buckets[i].load(Ordering::Relaxed) as f64)),
                ])
            })
            .collect();
        hist.push(Json::obj(vec![
            ("le_us", Json::Null), // overflow bucket
            (
                "count",
                Json::num(self.lat_buckets[LAT_BUCKETS_US.len()].load(Ordering::Relaxed) as f64),
            ),
        ]));
        let batches = self.batches.load(Ordering::Relaxed);
        let batched = self.batched_points.load(Ordering::Relaxed);
        let offload = match backend.and_then(Backend::offload_snapshot) {
            None => Json::Null,
            Some(snap) => Json::arr(
                snap.iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("op", Json::str(s.op.name())),
                            ("exact", Json::num(s.exact as f64)),
                            ("padded", Json::num(s.padded as f64)),
                            ("fallback", Json::num(s.missed as f64)),
                            ("coverage", Json::num(s.coverage())),
                        ])
                    })
                    .collect(),
            ),
        };
        Json::obj(vec![
            ("uptime_secs", Json::num(uptime)),
            (
                "requests",
                Json::obj(vec![
                    ("embed", Json::num(embeds as f64)),
                    ("healthz", Json::num(self.healthz.load(Ordering::Relaxed) as f64)),
                    ("metrics", Json::num(self.metrics.load(Ordering::Relaxed) as f64)),
                    ("reload", Json::num(self.reload.load(Ordering::Relaxed) as f64)),
                    ("errors", Json::num(self.errors.load(Ordering::Relaxed) as f64)),
                    ("shed", Json::num(self.shed.load(Ordering::Relaxed) as f64)),
                ]),
            ),
            ("qps", Json::num(if uptime > 0.0 { embeds as f64 / uptime } else { 0.0 })),
            (
                "embed_latency_us",
                Json::obj(vec![
                    ("count", Json::num(count as f64)),
                    ("mean", Json::num(mean_us)),
                    ("p50", Json::num(self.percentile_us(0.50))),
                    ("p95", Json::num(self.percentile_us(0.95))),
                    ("p99", Json::num(self.percentile_us(0.99))),
                    ("max", Json::num(self.lat_max_us.load(Ordering::Relaxed) as f64)),
                    ("histogram", Json::arr(hist)),
                ]),
            ),
            (
                "batching",
                Json::obj(vec![
                    ("batches", Json::num(batches as f64)),
                    ("points", Json::num(batched as f64)),
                    (
                        "max_points_in_batch",
                        Json::num(self.max_batch_points.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "mean_points_per_batch",
                        Json::num(if batches == 0 { 0.0 } else { batched as f64 / batches as f64 }),
                    ),
                ]),
            ),
            ("model", model_json(model)),
            ("offload", offload),
        ])
    }
}

/// One embed request parked in the micro-batch queue.
struct Pending {
    pts: crate::linalg::Matrix,
    tx: mpsc::Sender<Result<crate::linalg::Matrix, String>>,
}

/// One client connection with its read state; travels through the
/// connection queue between worker visits so keep-alive state (buffered
/// bytes, stall count) survives re-scheduling.
struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
    stalls: u32,
}

struct Shared {
    model: RwLock<Arc<FittedModel>>,
    model_path: Mutex<Option<PathBuf>>,
    backend: Option<Backend>,
    conns: Mutex<VecDeque<Conn>>,
    conns_cv: Condvar,
    queue: Mutex<VecDeque<Pending>>,
    queue_cv: Condvar,
    stop: AtomicBool,
    metrics: ServerMetrics,
    workers: usize,
    max_batch: usize,
    max_queue: usize,
}

/// A running server; dropping the handle leaves the threads running —
/// call [`ServerHandle::shutdown`] for an orderly stop or
/// [`ServerHandle::wait`] to block until the process dies.
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// `host:port` the server is listening on.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// Bound port (useful with `port: 0`).
    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    /// Currently served model.
    pub fn model(&self) -> Arc<FittedModel> {
        self.shared.model.read().unwrap().clone()
    }

    /// Block this thread for the server's lifetime (i.e. forever — the
    /// CLI's foreground mode; the process is stopped by signal).
    pub fn wait(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Orderly shutdown: stop accepting, drain workers, join threads.
    /// In-flight connections are abandoned after at most one poll slice.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.conns_cv.notify_all();
        self.shared.queue_cv.notify_all();
        // Unblock the acceptor's blocking accept().
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start serving `model`. `model_path` seeds the default for
/// `POST /v1/reload`; `backend` is only consulted for the `/metrics`
/// offload-coverage section (projection itself is pure native code).
pub fn start(
    model: FittedModel,
    model_path: Option<PathBuf>,
    backend: Option<Backend>,
    cfg: &ServeConfig,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
        .with_context(|| format!("bind {}:{}", cfg.host, cfg.port))?;
    let addr = listener.local_addr().context("query bound address")?;
    let workers = crate::engine::executor::resolve_workers(cfg.threads);
    let shared = Arc::new(Shared {
        model: RwLock::new(Arc::new(model)),
        model_path: Mutex::new(model_path),
        backend,
        conns: Mutex::new(VecDeque::new()),
        conns_cv: Condvar::new(),
        queue: Mutex::new(VecDeque::new()),
        queue_cv: Condvar::new(),
        stop: AtomicBool::new(false),
        metrics: ServerMetrics::new(),
        workers,
        max_batch: cfg.max_batch.max(1),
        max_queue: cfg.max_queue,
    });
    let mut threads = Vec::with_capacity(workers + 2);
    {
        let sh = Arc::clone(&shared);
        threads.push(
            std::thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || accept_loop(listener, &sh))
                .context("spawn acceptor")?,
        );
    }
    for i in 0..workers {
        let sh = Arc::clone(&shared);
        threads.push(
            std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || worker_loop(&sh))
                .context("spawn worker")?,
        );
    }
    {
        let sh = Arc::clone(&shared);
        threads.push(
            std::thread::Builder::new()
                .name("serve-batch".into())
                .spawn(move || batch_loop(&sh))
                .context("spawn batch executor")?,
        );
    }
    Ok(ServerHandle { addr, shared, threads })
}

fn accept_loop(listener: TcpListener, sh: &Shared) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if sh.stop.load(Ordering::Relaxed) {
                    return;
                }
                let conn = Conn { stream, buf: Vec::new(), stalls: 0 };
                sh.conns.lock().unwrap().push_back(conn);
                sh.conns_cv.notify_one();
            }
            Err(_) => {
                if sh.stop.load(Ordering::Relaxed) {
                    return;
                }
                // Transient accept failure (fd pressure, aborted handshake).
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let conn = {
            let mut q = sh.conns.lock().unwrap();
            loop {
                if sh.stop.load(Ordering::Relaxed) {
                    return;
                }
                if let Some(c) = q.pop_front() {
                    break c;
                }
                q = sh.conns_cv.wait_timeout(q, POLL).unwrap().0;
            }
        };
        // Serve the connection for one scheduling slice. A keep-alive
        // connection that is still open afterwards goes back to the queue
        // with its read state, so `threads` workers multiplex any number
        // of connections instead of each worker being pinned to one
        // (which would starve connection `threads + 1` indefinitely).
        if let Some(conn) = serve_slice(sh, conn) {
            sh.conns.lock().unwrap().push_back(conn);
            sh.conns_cv.notify_one();
        }
    }
}

/// Serve one connection until it is closed or until it should yield the
/// worker. Yield happens when the connection has nothing ready *and*
/// other connections are waiting; while the queue is empty the worker
/// stays parked here so a lone client never pays re-queue latency.
/// Returns the connection if it should be re-queued.
fn serve_slice(sh: &Shared, mut conn: Conn) -> Option<Conn> {
    if conn.stream.set_read_timeout(Some(READ_SLICE)).is_err() {
        return None;
    }
    // Bound writes too: a client that stops *reading* must not pin this
    // worker in write_all forever once the socket send buffer fills. The
    // timeout is per syscall, so a slow-but-draining client keeps making
    // progress; a stopped one costs at most one timeout, then is dropped.
    if conn.stream.set_write_timeout(Some(WRITE_LIMIT)).is_err() {
        return None;
    }
    let _ = conn.stream.set_nodelay(true);
    let mut scratch = [0u8; 8192];
    let mut served = false;
    loop {
        match http::try_parse(&conn.buf) {
            Err(e) => {
                sh.metrics.errors.fetch_add(1, Ordering::Relaxed);
                let body = Json::obj(vec![("error", Json::str(e))]).to_string();
                let resp = http::response(400, "application/json", body.as_bytes(), false);
                let _ = conn.stream.write_all(&resp);
                return None;
            }
            Ok(Some((req, used))) => {
                conn.buf.drain(..used);
                conn.stalls = 0;
                served = true;
                let keep = !req.wants_close();
                let resp = route(sh, &req, keep);
                if conn.stream.write_all(&resp).is_err() || !keep {
                    return None;
                }
                continue; // drain pipelined requests already buffered
            }
            Ok(None) => {}
        }
        // Fairness point: this connection has no complete request ready.
        // If we have served it at least once this slice and peers are
        // queued, hand the worker over instead of blocking on the socket.
        if served && !sh.conns.lock().unwrap().is_empty() {
            return Some(conn);
        }
        match conn.stream.read(&mut scratch) {
            Ok(0) => return None, // clean EOF
            Ok(n) => {
                conn.buf.extend_from_slice(&scratch[..n]);
                conn.stalls = 0;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if sh.stop.load(Ordering::Relaxed) {
                    return None;
                }
                if conn.buf.is_empty() {
                    // Idle keep-alive: yield to queued peers, else keep
                    // waiting here (no peers ⇒ nothing to be fair to).
                    if !sh.conns.lock().unwrap().is_empty() {
                        return Some(conn);
                    }
                } else {
                    conn.stalls += 1;
                    if conn.stalls > MAX_STALL_SLICES {
                        // Seconds mid-request: dead or glacial client.
                        let resp = http::response(408, "application/json", b"{}", false);
                        let _ = conn.stream.write_all(&resp);
                        return None;
                    }
                    // Mid-request stall with peers waiting: requeue and let
                    // the stall budget keep ticking on later visits.
                    if !sh.conns.lock().unwrap().is_empty() {
                        return Some(conn);
                    }
                }
            }
            Err(_) => return None,
        }
    }
}

fn route(sh: &Shared, req: &http::Request, keep: bool) -> Vec<u8> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            sh.metrics.healthz.fetch_add(1, Ordering::Relaxed);
            let model = sh.model.read().unwrap().clone();
            let body = Json::obj(vec![
                ("status", Json::str("ok")),
                ("uptime_secs", Json::num(sh.metrics.started.elapsed().as_secs_f64())),
                ("model", model_json(&model)),
            ]);
            ok_json(&body, keep)
        }
        ("GET", "/metrics") => {
            sh.metrics.metrics.fetch_add(1, Ordering::Relaxed);
            let model = sh.model.read().unwrap().clone();
            ok_json(&sh.metrics.to_json(&model, sh.backend.as_ref()), keep)
        }
        ("POST", "/v1/embed") => handle_embed(sh, req, keep),
        ("POST", "/v1/reload") => handle_reload(sh, req, keep),
        (_, "/healthz" | "/metrics" | "/v1/embed" | "/v1/reload") => {
            err_json(sh, 405, format!("method {} not allowed here", req.method), keep)
        }
        _ => err_json(sh, 404, format!("no such endpoint {:?}", req.path), keep),
    }
}

fn handle_embed(sh: &Shared, req: &http::Request, keep: bool) -> Vec<u8> {
    let sw = Instant::now();
    sh.metrics.embed.fetch_add(1, Ordering::Relaxed);
    let resp = match embed_inner(sh, &req.body) {
        Ok(body) => ok_json(&body, keep),
        // Every embed 503 (shed, shutdown, drain timeout) is transient by
        // construction, so they all carry a Retry-After hint.
        Err((503, msg)) => {
            sh.metrics.errors.fetch_add(1, Ordering::Relaxed);
            let body = Json::obj(vec![("error", Json::str(msg))]);
            http::response_with_headers(
                503,
                "application/json",
                body.to_string().as_bytes(),
                keep,
                &[("Retry-After", "1")],
            )
        }
        Err((status, msg)) => err_json(sh, status, msg, keep),
    };
    sh.metrics.record_latency_us(sw.elapsed().as_micros() as u64);
    resp
}

fn embed_inner(sh: &Shared, body: &[u8]) -> Result<Json, (u16, String)> {
    let text =
        std::str::from_utf8(body).map_err(|_| (400, "body is not UTF-8".to_string()))?;
    let j = Json::parse(text).map_err(|e| (400, format!("bad JSON body: {e}")))?;
    let pts = j
        .get("points")
        .ok_or_else(|| (400, "missing \"points\" array".to_string()))?;
    let pts = matrix_from_json(pts).map_err(|e| (400, format!("bad points: {e}")))?;
    if pts.nrows() == 0 {
        return Err((400, "empty points array".to_string()));
    }
    let model = sh.model.read().unwrap().clone();
    if pts.ncols() != model.dim() {
        return Err((
            400,
            format!("point dimensionality {} != model D {}", pts.ncols(), model.dim()),
        ));
    }
    let rows = pts.nrows();
    let (tx, rx) = mpsc::channel();
    {
        // The stop check must happen under the queue lock: batch_loop only
        // exits while holding this lock with the queue empty and stop set,
        // so a push that observes !stop here is guaranteed a drainer —
        // otherwise a request enqueued right as the server stops would
        // wait out the full recv timeout with nobody left to serve it.
        let mut q = sh.queue.lock().unwrap();
        if sh.stop.load(Ordering::Relaxed) {
            return Err((503, "server is shutting down".to_string()));
        }
        // Load shedding: a full micro-batch queue answers 503 immediately
        // instead of queueing unboundedly — the client backs off (the
        // response carries Retry-After) and memory stays bounded.
        if q.len() >= sh.max_queue {
            sh.metrics.shed.fetch_add(1, Ordering::Relaxed);
            return Err((
                503,
                format!("embed queue full ({} pending requests); retry shortly", q.len()),
            ));
        }
        q.push_back(Pending { pts, tx });
    }
    sh.queue_cv.notify_one();
    match rx.recv_timeout(Duration::from_secs(60)) {
        Ok(Ok(emb)) => Ok(Json::obj(vec![
            ("embedding", matrix_to_json(&emb)),
            ("points", Json::num(rows as f64)),
            ("d", Json::num(emb.ncols() as f64)),
        ])),
        // Model was hot-swapped between validation and execution and the
        // new model disagrees about D — the client should retry.
        Ok(Err(msg)) => Err((400, msg)),
        Err(_) => Err((503, "embed queue timed out (server overloaded or stopping)".to_string())),
    }
}

fn handle_reload(sh: &Shared, req: &http::Request, keep: bool) -> Vec<u8> {
    sh.metrics.reload.fetch_add(1, Ordering::Relaxed);
    let requested: Option<PathBuf> = if req.body.is_empty() {
        None
    } else {
        match std::str::from_utf8(&req.body).ok().and_then(|t| Json::parse(t).ok()) {
            Some(j) => j.get("path").and_then(Json::as_str).map(PathBuf::from),
            None => return err_json(sh, 400, "bad JSON body".to_string(), keep),
        }
    };
    let path = match requested.or_else(|| sh.model_path.lock().unwrap().clone()) {
        Some(p) => p,
        None => {
            return err_json(
                sh,
                400,
                "no \"path\" given and the server was started without a model path".to_string(),
                keep,
            )
        }
    };
    match FittedModel::load(&path) {
        Ok(new_model) => {
            let arc = Arc::new(new_model);
            *sh.model.write().unwrap() = Arc::clone(&arc);
            *sh.model_path.lock().unwrap() = Some(path.clone());
            ok_json(
                &Json::obj(vec![
                    ("status", Json::str("reloaded")),
                    ("path", Json::str(path.display().to_string())),
                    ("model", model_json(&arc)),
                ]),
                keep,
            )
        }
        // The RwLock is only taken on success: a broken artifact on disk
        // can never displace the model that is already serving.
        Err(e) => err_json(sh, 400, format!("reload failed, keeping current model: {e:#}"), keep),
    }
}

/// Batch-executor loop: drain the queue, run one pooled `map_points`,
/// scatter results. Exits once stopped *and* drained.
fn batch_loop(sh: &Shared) {
    loop {
        let drained: Vec<Pending> = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if !q.is_empty() {
                    break;
                }
                if sh.stop.load(Ordering::Relaxed) {
                    return;
                }
                q = sh.queue_cv.wait_timeout(q, POLL).unwrap().0;
            }
            let mut out = Vec::new();
            let mut rows = 0usize;
            while let Some(p) = q.front() {
                let r = p.pts.nrows();
                if !out.is_empty() && rows + r > sh.max_batch {
                    break;
                }
                rows += r;
                out.push(q.pop_front().unwrap());
            }
            out
        };
        execute_batch(sh, drained);
    }
}

fn execute_batch(sh: &Shared, drained: Vec<Pending>) {
    let model = sh.model.read().unwrap().clone();
    let d_in = model.dim();
    // Requests validated against a model that has since been hot-swapped
    // to a different input dimensionality get individual errors; the rest
    // batch together.
    let mut batch: Vec<Pending> = Vec::with_capacity(drained.len());
    for p in drained {
        if p.pts.ncols() == d_in {
            batch.push(p);
        } else {
            let _ = p.tx.send(Err(format!(
                "model was reloaded: point dimensionality {} != model D {d_in}",
                p.pts.ncols()
            )));
        }
    }
    if batch.is_empty() {
        return;
    }
    let total: usize = batch.iter().map(|p| p.pts.nrows()).sum();
    let mut data = Vec::with_capacity(total * d_in);
    for p in &batch {
        data.extend_from_slice(p.pts.as_slice());
    }
    let big = crate::linalg::Matrix::from_vec(total, d_in, data);
    sh.metrics.batches.fetch_add(1, Ordering::Relaxed);
    sh.metrics.batched_points.fetch_add(total as u64, Ordering::Relaxed);
    sh.metrics.max_batch_points.fetch_max(total as u64, Ordering::Relaxed);
    match model.map_points_with(&big, sh.workers) {
        Ok(emb) => {
            let d_out = emb.ncols();
            let mut row = 0usize;
            for p in &batch {
                let r = p.pts.nrows();
                let slice = emb.slice(row, row + r, 0, d_out);
                row += r;
                let _ = p.tx.send(Ok(slice));
            }
        }
        Err(e) => {
            let msg = format!("projection failed: {e:#}");
            for p in &batch {
                let _ = p.tx.send(Err(msg.clone()));
            }
        }
    }
}

fn ok_json(body: &Json, keep: bool) -> Vec<u8> {
    http::response(200, "application/json", body.to_string().as_bytes(), keep)
}

fn err_json(sh: &Shared, status: u16, msg: String, keep: bool) -> Vec<u8> {
    sh.metrics.errors.fetch_add(1, Ordering::Relaxed);
    let body = Json::obj(vec![("error", Json::str(msg))]);
    http::response(status, "application/json", body.to_string().as_bytes(), keep)
}

/// Model summary used by `/healthz`, `/metrics`, and `/v1/reload`.
pub fn model_json(m: &FittedModel) -> Json {
    Json::obj(vec![
        ("n", Json::num(m.n() as f64)),
        ("dim", Json::num(m.dim() as f64)),
        ("landmarks", Json::num(m.num_landmarks() as f64)),
        ("d", Json::num(m.out_dim() as f64)),
        ("k", Json::num(m.k() as f64)),
    ])
}

/// Matrix → JSON array-of-row-arrays. Rust's float `Display` is
/// shortest-roundtrip, so serialize → parse restores every f64 bit-exactly
/// (the embed endpoint's bit-identity guarantee rides on this).
pub fn matrix_to_json(m: &crate::linalg::Matrix) -> Json {
    Json::arr(
        (0..m.nrows())
            .map(|i| Json::arr(m.row(i).iter().map(|&x| Json::num(x)).collect()))
            .collect(),
    )
}

/// JSON array-of-row-arrays → matrix; rejects ragged/non-numeric input.
pub fn matrix_from_json(j: &Json) -> Result<crate::linalg::Matrix, String> {
    let rows = j.as_arr().ok_or("expected an array of rows")?;
    let mut out: Vec<Vec<f64>> = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let cells = row.as_arr().ok_or_else(|| format!("row {i} is not an array"))?;
        let mut r = Vec::with_capacity(cells.len());
        for (jj, c) in cells.iter().enumerate() {
            r.push(c.as_f64().ok_or_else(|| format!("row {i} col {jj} is not a number"))?);
        }
        if let Some(first) = out.first() {
            if first.len() != r.len() {
                return Err(format!(
                    "ragged rows: row {i} has {} cols, row 0 has {}",
                    r.len(),
                    first.len()
                ));
            }
        }
        out.push(r);
    }
    Ok(crate::linalg::Matrix::from_rows(&out))
}

/// Exact percentile of a **sorted** latency sample (nearest-rank); used by
/// the loopback load generator and `bench-serve`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_json_roundtrip_bits() {
        let m = crate::linalg::Matrix::from_rows(&[
            vec![std::f64::consts::PI, -0.0, 1e-308],
            vec![1.0 / 3.0, 2.5e17, -7.125],
        ]);
        let j = matrix_to_json(&m);
        let text = j.to_string();
        let back = matrix_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.nrows(), 2);
        for (a, b) in m.as_slice().iter().zip(back.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn matrix_from_json_rejects_garbage() {
        assert!(matrix_from_json(&Json::parse("42").unwrap()).is_err());
        assert!(matrix_from_json(&Json::parse("[[1,2],[3]]").unwrap()).is_err());
        assert!(matrix_from_json(&Json::parse("[[1,\"x\"]]").unwrap()).is_err());
        assert!(matrix_from_json(&Json::parse("[]").unwrap()).unwrap().nrows() == 0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&s, 0.50), 50.0);
        assert_eq!(percentile(&s, 0.95), 95.0);
        assert_eq!(percentile(&s, 0.99), 99.0);
        assert_eq!(percentile(&s, 1.0), 100.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn latency_histogram_percentiles() {
        let m = ServerMetrics::new();
        for _ in 0..90 {
            m.record_latency_us(80); // ≤100 bucket
        }
        for _ in 0..10 {
            m.record_latency_us(9_000); // ≤10_000 bucket
        }
        assert_eq!(m.percentile_us(0.50), 100.0);
        assert_eq!(m.percentile_us(0.95), 10_000.0);
        assert_eq!(m.lat_max_us.load(Ordering::Relaxed), 9_000);
        // Overflow bucket reports the observed max.
        m.record_latency_us(400_000);
        assert_eq!(m.percentile_us(1.0), 400_000.0);
    }
}
