//! `isospark serve` — an embedding server over saved [`FittedModel`]s.
//!
//! The ROADMAP's north star is a fitted manifold that *outlives* the O(n³)
//! batch job and serves projections to clients. This module is that layer:
//! a dependency-free HTTP/1.1 server on `std::net::TcpListener` (request
//! framing hand-rolled in [`http`], as `util::json` hand-rolls JSON)
//! exposing
//!
//! * `POST /v1/models/<name>/embed` — `{"points": [[…],…]}` →
//!   `{"embedding": [[…],…]}`, bit-identical to calling
//!   [`FittedModel::map_points`] in-process on the named model;
//! * `POST /v1/models/<name>/reload` / `GET /v1/models/<name>/metrics` —
//!   per-model hot swap and counters ([`registry`]);
//! * `POST /v1/embed`, `POST /v1/reload` — legacy single-model paths,
//!   aliasing the *default* (first-registered) model;
//! * `GET /v1/models` — the registered names;
//! * `GET /healthz` — liveness + model summaries;
//! * `GET /metrics` — request counters, embed latency histogram with
//!   approximate p50/p95/p99, QPS, micro-batching stats, the admission /
//!   adaptive-batching / autoscaling controller states, per-model
//!   sections, and (when started with a PJRT backend) the per-op
//!   offload-coverage counters.
//!
//! ## Architecture
//!
//! Connections are accepted by one acceptor thread and claimed by a pool
//! of worker threads from a shared queue — the same dynamic-claiming
//! shape as [`crate::engine::executor`], but long-lived because
//! connections (unlike stage tasks) are open-ended. Workers parse
//! requests and answer everything except embeds directly. A **control
//! thread** samples the latency histogram and queue depths every
//! [`CONTROL_INTERVAL`] and drives two feedback controllers
//! ([`autoscale`]): the adaptive micro-batch cap, and the worker pool
//! size between `threads_min..=threads_max` (scale-up spawns a worker;
//! scale-down issues a *retire ticket* an idle worker consumes at its
//! next wakeup, so a busy worker is never interrupted).
//!
//! ## Micro-batching and admission
//!
//! Embed requests do not call the model from the worker: they pass the
//! [`admission::AdmissionController`] (full queue ⇒ immediate `429`/`503`
//! + `Retry-After` instead of unbounded queueing), then park the parsed
//! points in a bounded queue and block on a response channel. A single
//! batch-executor thread drains everything currently queued — up to the
//! controller's *adaptive* cap — groups it by model, concatenates each
//! group into one matrix, runs one [`FittedModel::map_points_with`] call
//! on the projection pool, and scatters the rows back. While a batch
//! executes, new arrivals pile up and form the next batch: zero added
//! latency when idle, block-sized backend calls under load.
//!
//! ## Determinism under load
//!
//! None of the production machinery can change output bits. Each row is
//! projected by the same serial code regardless of batch composition, so
//! the adaptive cap only re-partitions work; `map_points_with` is
//! bit-identical for every worker count, so pool size is invisible; and
//! admission control only decides *whether* a request runs, never *how*.
//! An accepted embed under overload returns exactly the bytes it would
//! have returned on an idle server — `tests/serve_load.rs` pins this.

pub mod admission;
pub mod autoscale;
pub mod client;
pub mod http;
pub mod registry;

pub use crate::config::ServeConfig;

use crate::backend::Backend;
use crate::engine::metrics::{LatencyHistogram, LATENCY_BUCKETS_US};
use crate::model::FittedModel;
use crate::util::json::Json;
use anyhow::{Context, Result};
use registry::{ModelEntry, Registry};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Wait slice for idle condvar loops; shutdown latency is bounded by it.
const POLL: Duration = Duration::from_millis(250);

/// Socket read slice: how long a worker blocks on one connection before
/// re-checking for queued peers (bounds the scheduling latency a parked
/// idle connection can inflict on a waiting one).
const READ_SLICE: Duration = Duration::from_millis(50);

/// Read slices a connection may stall *mid-request* before it is answered
/// with 408 and dropped (100 × 50 ms = 5 s).
const MAX_STALL_SLICES: u32 = 100;

/// Per-syscall write timeout: the longest a worker can be pinned by a
/// client that stopped reading its response.
const WRITE_LIMIT: Duration = Duration::from_secs(10);

/// Sampling interval of the control thread driving the adaptive-batching
/// and pool-autoscaling controllers.
pub const CONTROL_INTERVAL: Duration = Duration::from_millis(100);

/// Stop-check granularity inside the control thread's sleep.
const CONTROL_SLICE: Duration = Duration::from_millis(20);

/// Thread-safe server counters (all relaxed atomics — monitoring data).
struct ServerMetrics {
    started: Instant,
    embed: AtomicU64,
    healthz: AtomicU64,
    metrics: AtomicU64,
    reload: AtomicU64,
    errors: AtomicU64,
    shed: AtomicU64,
    batches: AtomicU64,
    batched_points: AtomicU64,
    max_batch_points: AtomicU64,
    latency: LatencyHistogram,
}

impl ServerMetrics {
    fn new() -> Self {
        Self {
            started: Instant::now(),
            embed: AtomicU64::new(0),
            healthz: AtomicU64::new(0),
            metrics: AtomicU64::new(0),
            reload: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_points: AtomicU64::new(0),
            max_batch_points: AtomicU64::new(0),
            latency: LatencyHistogram::new(),
        }
    }
}

/// The full `GET /metrics` document: the legacy server-wide fields, the
/// three controller states, and a per-model section.
fn metrics_json(sh: &Shared) -> Json {
    let m = &sh.metrics;
    let uptime = m.started.elapsed().as_secs_f64();
    let embeds = m.embed.load(Ordering::Relaxed);
    let lat = m.latency.snapshot();
    let mut hist: Vec<Json> = LATENCY_BUCKETS_US
        .iter()
        .enumerate()
        .map(|(i, &le)| {
            Json::obj(vec![
                ("le_us", Json::num(le as f64)),
                ("count", Json::num(lat.buckets[i] as f64)),
            ])
        })
        .collect();
    hist.push(Json::obj(vec![
        ("le_us", Json::Null), // overflow bucket
        ("count", Json::num(lat.buckets[LATENCY_BUCKETS_US.len()] as f64)),
    ]));
    let batches = m.batches.load(Ordering::Relaxed);
    let batched = m.batched_points.load(Ordering::Relaxed);
    let offload = match sh.backend.as_ref().and_then(Backend::offload_snapshot) {
        None => Json::Null,
        Some(snap) => Json::arr(
            snap.iter()
                .map(|s| {
                    Json::obj(vec![
                        ("op", Json::str(s.op.name())),
                        ("exact", Json::num(s.exact as f64)),
                        ("padded", Json::num(s.padded as f64)),
                        ("fallback", Json::num(s.missed as f64)),
                        ("coverage", Json::num(s.coverage())),
                    ])
                })
                .collect(),
        ),
    };
    let models = Json::obj(
        sh.registry
            .entries()
            .iter()
            .map(|e| {
                (
                    e.name(),
                    Json::obj(vec![
                        ("model", model_json(&e.current())),
                        ("metrics", e.metrics.to_json()),
                        ("reloads_ok", Json::num(e.reloads_ok() as f64)),
                        ("reloads_failed", Json::num(e.reloads_failed() as f64)),
                    ]),
                )
            })
            .collect(),
    );
    Json::obj(vec![
        ("uptime_secs", Json::num(uptime)),
        (
            "requests",
            Json::obj(vec![
                ("embed", Json::num(embeds as f64)),
                ("healthz", Json::num(m.healthz.load(Ordering::Relaxed) as f64)),
                ("metrics", Json::num(m.metrics.load(Ordering::Relaxed) as f64)),
                ("reload", Json::num(m.reload.load(Ordering::Relaxed) as f64)),
                ("errors", Json::num(m.errors.load(Ordering::Relaxed) as f64)),
                ("shed", Json::num(m.shed.load(Ordering::Relaxed) as f64)),
            ]),
        ),
        ("qps", Json::num(if uptime > 0.0 { embeds as f64 / uptime } else { 0.0 })),
        (
            "embed_latency_us",
            Json::obj(vec![
                ("count", Json::num(lat.count as f64)),
                ("mean", Json::num(lat.mean_us())),
                ("p50", Json::num(lat.percentile_us(0.50))),
                ("p95", Json::num(lat.percentile_us(0.95))),
                ("p99", Json::num(lat.percentile_us(0.99))),
                ("max", Json::num(lat.max_us as f64)),
                ("histogram", Json::arr(hist)),
            ]),
        ),
        (
            "batching",
            Json::obj(vec![
                ("batches", Json::num(batches as f64)),
                ("points", Json::num(batched as f64)),
                (
                    "max_points_in_batch",
                    Json::num(m.max_batch_points.load(Ordering::Relaxed) as f64),
                ),
                (
                    "mean_points_per_batch",
                    Json::num(if batches == 0 { 0.0 } else { batched as f64 / batches as f64 }),
                ),
            ]),
        ),
        ("admission", sh.admission.to_json()),
        ("adaptive_batch", sh.batcher.to_json()),
        (
            "autoscale",
            sh.scaler.to_json(
                sh.active_workers.load(Ordering::SeqCst),
                sh.pending_retires.load(Ordering::SeqCst),
            ),
        ),
        ("model", model_json(&sh.registry.default_entry().current())),
        ("models", models),
        ("offload", offload),
    ])
}

/// One embed request parked in the micro-batch queue.
struct Pending {
    entry: Arc<ModelEntry>,
    pts: crate::linalg::Matrix,
    tx: mpsc::Sender<Result<crate::linalg::Matrix, String>>,
}

/// One client connection with its read state; travels through the
/// connection queue between worker visits so keep-alive state (buffered
/// bytes, stall count) survives re-scheduling.
struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
    stalls: u32,
}

struct Shared {
    registry: Registry,
    backend: Option<Backend>,
    conns: Mutex<VecDeque<Conn>>,
    conns_cv: Condvar,
    queue: Mutex<VecDeque<Pending>>,
    queue_cv: Condvar,
    stop: AtomicBool,
    metrics: ServerMetrics,
    admission: admission::AdmissionController,
    batcher: autoscale::BatchController,
    scaler: autoscale::PoolAutoscaler,
    /// Live HTTP workers (initial + autoscaled).
    active_workers: AtomicUsize,
    /// Retire tickets issued by the autoscaler, consumed by idle workers.
    pending_retires: AtomicUsize,
    /// Join handles of workers spawned after startup by the autoscaler.
    extra_threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Projection pool size for each pooled `map_points_with` call.
    map_workers: usize,
}

/// A running server; dropping the handle leaves the threads running —
/// call [`ServerHandle::shutdown`] for an orderly stop or
/// [`ServerHandle::wait`] to block until the process dies.
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// `host:port` the server is listening on.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// Bound port (useful with `port: 0`).
    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    /// Currently served default model (the first registered).
    pub fn model(&self) -> Arc<FittedModel> {
        self.shared.registry.default_entry().current()
    }

    /// The model registry (names, per-model metrics, reload).
    pub fn registry(&self) -> &Registry {
        &self.shared.registry
    }

    /// Live HTTP worker count (floats between the configured bounds when
    /// autoscaling is on).
    pub fn active_workers(&self) -> usize {
        self.shared.active_workers.load(Ordering::SeqCst)
    }

    /// Block this thread for the server's lifetime (i.e. forever — the
    /// CLI's foreground mode; the process is stopped by signal).
    pub fn wait(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        let extras: Vec<_> =
            std::mem::take(&mut *self.shared.extra_threads.lock().unwrap());
        for t in extras {
            let _ = t.join();
        }
    }

    /// Orderly shutdown: stop accepting, drain workers, join threads.
    /// In-flight connections are abandoned after at most one poll slice.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.conns_cv.notify_all();
        self.shared.queue_cv.notify_all();
        // Unblock the acceptor's blocking accept().
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // The control thread is joined above, so no new workers can
        // appear while we collect the autoscaled ones.
        let extras: Vec<_> =
            std::mem::take(&mut *self.shared.extra_threads.lock().unwrap());
        for t in extras {
            let _ = t.join();
        }
    }
}

/// Start serving a single `model` under the default name (the legacy
/// entry point). `model_path` seeds the default for `POST /v1/reload`;
/// `backend` is only consulted for the `/metrics` offload-coverage
/// section (projection itself is pure native code).
pub fn start(
    model: FittedModel,
    model_path: Option<PathBuf>,
    backend: Option<Backend>,
    cfg: &ServeConfig,
) -> Result<ServerHandle> {
    start_registry(Registry::single(model, model_path), backend, cfg)
}

/// Start serving every model in `registry` (the first entry is the
/// default the legacy single-model paths alias).
pub fn start_registry(
    registry: Registry,
    backend: Option<Backend>,
    cfg: &ServeConfig,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
        .with_context(|| format!("bind {}:{}", cfg.host, cfg.port))?;
    let addr = listener.local_addr().context("query bound address")?;
    let (min_workers, max_workers) = cfg.pool_bounds();
    let shared = Arc::new(Shared {
        registry,
        backend,
        conns: Mutex::new(VecDeque::new()),
        conns_cv: Condvar::new(),
        queue: Mutex::new(VecDeque::new()),
        queue_cv: Condvar::new(),
        stop: AtomicBool::new(false),
        metrics: ServerMetrics::new(),
        admission: admission::AdmissionController::new(cfg.max_queue),
        batcher: autoscale::BatchController::new(
            cfg.batch_min,
            cfg.max_batch.max(1),
            cfg.target_p95_ms,
        ),
        scaler: autoscale::PoolAutoscaler::new(min_workers, max_workers),
        active_workers: AtomicUsize::new(0),
        pending_retires: AtomicUsize::new(0),
        extra_threads: Mutex::new(Vec::new()),
        map_workers: max_workers,
    });
    let mut threads = Vec::with_capacity(min_workers + 3);
    {
        let sh = Arc::clone(&shared);
        threads.push(
            std::thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || accept_loop(listener, &sh))
                .context("spawn acceptor")?,
        );
    }
    for i in 0..min_workers {
        threads.push(spawn_worker(&shared, format!("serve-worker-{i}"))?);
    }
    {
        let sh = Arc::clone(&shared);
        threads.push(
            std::thread::Builder::new()
                .name("serve-batch".into())
                .spawn(move || batch_loop(&sh))
                .context("spawn batch executor")?,
        );
    }
    {
        let sh = Arc::clone(&shared);
        threads.push(
            std::thread::Builder::new()
                .name("serve-control".into())
                .spawn(move || control_loop(&sh))
                .context("spawn control loop")?,
        );
    }
    Ok(ServerHandle { addr, shared, threads })
}

/// Spawn one HTTP worker, accounting it in `active_workers` *before* the
/// thread starts so the autoscaler never under-counts.
fn spawn_worker(sh: &Arc<Shared>, name: String) -> Result<std::thread::JoinHandle<()>> {
    sh.active_workers.fetch_add(1, Ordering::SeqCst);
    let sh2 = Arc::clone(sh);
    match std::thread::Builder::new().name(name).spawn(move || {
        worker_loop(&sh2);
        sh2.active_workers.fetch_sub(1, Ordering::SeqCst);
    }) {
        Ok(h) => Ok(h),
        Err(e) => {
            sh.active_workers.fetch_sub(1, Ordering::SeqCst);
            Err(anyhow::anyhow!("spawn serve worker: {e}"))
        }
    }
}

/// The feedback-control thread: every [`CONTROL_INTERVAL`] feed the
/// latency window to the batch controller and the queue depths to the
/// pool autoscaler, then act on the scaling decision.
fn control_loop(sh: &Arc<Shared>) {
    let mut prev_lat = sh.metrics.latency.snapshot();
    let mut prev_embeds = sh.metrics.embed.load(Ordering::Relaxed);
    let mut last = Instant::now();
    let mut extra_idx = 0u64;
    loop {
        let deadline = Instant::now() + CONTROL_INTERVAL;
        while Instant::now() < deadline {
            if sh.stop.load(Ordering::Relaxed) {
                return;
            }
            std::thread::sleep(CONTROL_SLICE);
        }
        let now = Instant::now();
        let dt = now.duration_since(last).as_secs_f64().max(1e-9);
        last = now;

        let cur = sh.metrics.latency.snapshot();
        let _ = sh.batcher.observe_window(&cur.since(&prev_lat));
        prev_lat = cur;

        let embeds = sh.metrics.embed.load(Ordering::Relaxed);
        let arrival_qps = embeds.saturating_sub(prev_embeds) as f64 / dt;
        prev_embeds = embeds;
        let backlog = sh.conns.lock().unwrap().len() + sh.queue.lock().unwrap().len();
        let active = sh.active_workers.load(Ordering::SeqCst);
        let pending = sh.pending_retires.load(Ordering::SeqCst);
        match sh.scaler.observe(active, pending, backlog, arrival_qps) {
            autoscale::ScaleDecision::Up => {
                // Cancel an unconsumed retire ticket first — capacity is
                // restored without paying for a thread spawn.
                let cancelled = sh
                    .pending_retires
                    .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |t| t.checked_sub(1))
                    .is_ok();
                if !cancelled {
                    extra_idx += 1;
                    if let Ok(h) = spawn_worker(sh, format!("serve-worker-x{extra_idx}")) {
                        sh.extra_threads.lock().unwrap().push(h);
                    }
                }
            }
            autoscale::ScaleDecision::Down => {
                sh.pending_retires.fetch_add(1, Ordering::SeqCst);
                // Wake an idle worker so the ticket is consumed promptly.
                sh.conns_cv.notify_all();
            }
            autoscale::ScaleDecision::Hold => {}
        }
    }
}

fn take_retire_ticket(sh: &Shared) -> bool {
    sh.pending_retires
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |t| t.checked_sub(1))
        .is_ok()
}

fn accept_loop(listener: TcpListener, sh: &Shared) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if sh.stop.load(Ordering::Relaxed) {
                    return;
                }
                let conn = Conn { stream, buf: Vec::new(), stalls: 0 };
                sh.conns.lock().unwrap().push_back(conn);
                sh.conns_cv.notify_one();
            }
            Err(_) => {
                if sh.stop.load(Ordering::Relaxed) {
                    return;
                }
                // Transient accept failure (fd pressure, aborted handshake).
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let conn = {
            let mut q = sh.conns.lock().unwrap();
            loop {
                if sh.stop.load(Ordering::Relaxed) {
                    return;
                }
                if let Some(c) = q.pop_front() {
                    break c;
                }
                // Only an idle worker (no connection waiting) retires, so
                // a scale-down never abandons queued work.
                if take_retire_ticket(sh) {
                    return;
                }
                q = sh.conns_cv.wait_timeout(q, POLL).unwrap().0;
            }
        };
        // Serve the connection for one scheduling slice. A keep-alive
        // connection that is still open afterwards goes back to the queue
        // with its read state, so the workers multiplex any number
        // of connections instead of each worker being pinned to one
        // (which would starve connection `workers + 1` indefinitely).
        if let Some(conn) = serve_slice(sh, conn) {
            sh.conns.lock().unwrap().push_back(conn);
            sh.conns_cv.notify_one();
        }
    }
}

/// Serve one connection until it is closed or until it should yield the
/// worker. Yield happens when the connection has nothing ready *and*
/// other connections are waiting; while the queue is empty the worker
/// stays parked here so a lone client never pays re-queue latency.
/// Returns the connection if it should be re-queued.
fn serve_slice(sh: &Shared, mut conn: Conn) -> Option<Conn> {
    if conn.stream.set_read_timeout(Some(READ_SLICE)).is_err() {
        return None;
    }
    // Bound writes too: a client that stops *reading* must not pin this
    // worker in write_all forever once the socket send buffer fills. The
    // timeout is per syscall, so a slow-but-draining client keeps making
    // progress; a stopped one costs at most one timeout, then is dropped.
    if conn.stream.set_write_timeout(Some(WRITE_LIMIT)).is_err() {
        return None;
    }
    let _ = conn.stream.set_nodelay(true);
    let mut scratch = [0u8; 8192];
    let mut served = false;
    loop {
        match http::try_parse(&conn.buf) {
            Err(e) => {
                sh.metrics.errors.fetch_add(1, Ordering::Relaxed);
                let body = Json::obj(vec![("error", Json::str(e))]).to_string();
                let resp = http::response(400, "application/json", body.as_bytes(), false);
                let _ = conn.stream.write_all(&resp);
                return None;
            }
            Ok(Some((req, used))) => {
                conn.buf.drain(..used);
                conn.stalls = 0;
                served = true;
                let keep = !req.wants_close();
                let resp = route(sh, &req, keep);
                if conn.stream.write_all(&resp).is_err() || !keep {
                    return None;
                }
                continue; // drain pipelined requests already buffered
            }
            Ok(None) => {}
        }
        // Fairness point: this connection has no complete request ready.
        // If we have served it at least once this slice and peers are
        // queued, hand the worker over instead of blocking on the socket.
        if served && !sh.conns.lock().unwrap().is_empty() {
            return Some(conn);
        }
        match conn.stream.read(&mut scratch) {
            Ok(0) => return None, // clean EOF
            Ok(n) => {
                conn.buf.extend_from_slice(&scratch[..n]);
                conn.stalls = 0;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if sh.stop.load(Ordering::Relaxed) {
                    return None;
                }
                if conn.buf.is_empty() {
                    // Idle keep-alive: yield to queued peers, else keep
                    // waiting here (no peers ⇒ nothing to be fair to).
                    if !sh.conns.lock().unwrap().is_empty() {
                        return Some(conn);
                    }
                } else {
                    conn.stalls += 1;
                    if conn.stalls > MAX_STALL_SLICES {
                        // Seconds mid-request: dead or glacial client.
                        let resp = http::response(408, "application/json", b"{}", false);
                        let _ = conn.stream.write_all(&resp);
                        return None;
                    }
                    // Mid-request stall with peers waiting: requeue and let
                    // the stall budget keep ticking on later visits.
                    if !sh.conns.lock().unwrap().is_empty() {
                        return Some(conn);
                    }
                }
            }
            Err(_) => return None,
        }
    }
}

fn route(sh: &Shared, req: &http::Request, keep: bool) -> Vec<u8> {
    // Model-scoped paths first: /v1/models/<name>/{embed,reload,metrics}.
    if let Some((name, action)) = registry::route_model_path(&req.path) {
        let entry = match sh.registry.get(name) {
            Some(e) => Arc::clone(e),
            None => return err_json(sh, 404, sh.registry.unknown(name), keep),
        };
        return match (req.method.as_str(), action) {
            ("POST", "embed") => handle_embed(sh, &entry, req, keep),
            ("POST", "reload") => handle_reload(sh, &entry, req, keep),
            ("GET", "metrics") => {
                sh.metrics.metrics.fetch_add(1, Ordering::Relaxed);
                ok_json(
                    &Json::obj(vec![
                        ("name", Json::str(entry.name())),
                        ("model", model_json(&entry.current())),
                        ("metrics", entry.metrics.to_json()),
                        ("reloads_ok", Json::num(entry.reloads_ok() as f64)),
                        ("reloads_failed", Json::num(entry.reloads_failed() as f64)),
                    ]),
                    keep,
                )
            }
            (_, "embed" | "reload" | "metrics") => {
                err_json(sh, 405, format!("method {} not allowed here", req.method), keep)
            }
            _ => err_json(sh, 404, format!("no such model action {action:?}"), keep),
        };
    }
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            sh.metrics.healthz.fetch_add(1, Ordering::Relaxed);
            let body = Json::obj(vec![
                ("status", Json::str("ok")),
                ("uptime_secs", Json::num(sh.metrics.started.elapsed().as_secs_f64())),
                ("model", model_json(&sh.registry.default_entry().current())),
                (
                    "models",
                    Json::arr(sh.registry.names().iter().map(|n| Json::str(*n)).collect()),
                ),
            ]);
            ok_json(&body, keep)
        }
        ("GET", "/metrics") => {
            sh.metrics.metrics.fetch_add(1, Ordering::Relaxed);
            ok_json(&metrics_json(sh), keep)
        }
        ("GET", "/v1/models") => {
            let names = sh.registry.names().iter().map(|n| Json::str(*n)).collect();
            ok_json(&Json::obj(vec![("models", Json::arr(names))]), keep)
        }
        ("POST", "/v1/embed") => {
            let entry = Arc::clone(sh.registry.default_entry());
            handle_embed(sh, &entry, req, keep)
        }
        ("POST", "/v1/reload") => {
            let entry = Arc::clone(sh.registry.default_entry());
            handle_reload(sh, &entry, req, keep)
        }
        (_, "/healthz" | "/metrics" | "/v1/embed" | "/v1/reload" | "/v1/models") => {
            err_json(sh, 405, format!("method {} not allowed here", req.method), keep)
        }
        _ => err_json(sh, 404, format!("no such endpoint {:?}", req.path), keep),
    }
}

/// A rejected embed: status, message, and the `Retry-After` hint carried
/// by every transient (429/503) rejection.
struct Reject {
    status: u16,
    msg: String,
    retry_after_secs: Option<u64>,
}

impl Reject {
    fn client_error(status: u16, msg: String) -> Self {
        Reject { status, msg, retry_after_secs: None }
    }

    fn transient(status: u16, msg: String, retry_after_secs: u64) -> Self {
        Reject { status, msg, retry_after_secs: Some(retry_after_secs) }
    }
}

fn handle_embed(sh: &Shared, entry: &Arc<ModelEntry>, req: &http::Request, keep: bool) -> Vec<u8> {
    let sw = Instant::now();
    sh.metrics.embed.fetch_add(1, Ordering::Relaxed);
    entry.metrics.embeds.fetch_add(1, Ordering::Relaxed);
    let resp = match embed_inner(sh, entry, &req.body) {
        Ok(body) => ok_json(&body, keep),
        Err(rej) => {
            sh.metrics.errors.fetch_add(1, Ordering::Relaxed);
            entry.metrics.errors.fetch_add(1, Ordering::Relaxed);
            let body = Json::obj(vec![("error", Json::str(rej.msg))]).to_string();
            match rej.retry_after_secs {
                Some(secs) => {
                    let ra = secs.to_string();
                    http::response_with_headers(
                        rej.status,
                        "application/json",
                        body.as_bytes(),
                        keep,
                        &[("Retry-After", ra.as_str())],
                    )
                }
                None => http::response(rej.status, "application/json", body.as_bytes(), keep),
            }
        }
    };
    let us = sw.elapsed().as_micros() as u64;
    sh.metrics.latency.record_us(us);
    entry.metrics.latency.record_us(us);
    resp
}

fn embed_inner(sh: &Shared, entry: &Arc<ModelEntry>, body: &[u8]) -> Result<Json, Reject> {
    let text = std::str::from_utf8(body)
        .map_err(|_| Reject::client_error(400, "body is not UTF-8".to_string()))?;
    let j = Json::parse(text)
        .map_err(|e| Reject::client_error(400, format!("bad JSON body: {e}")))?;
    let pts = j
        .get("points")
        .ok_or_else(|| Reject::client_error(400, "missing \"points\" array".to_string()))?;
    let pts = matrix_from_json(pts)
        .map_err(|e| Reject::client_error(400, format!("bad points: {e}")))?;
    if pts.nrows() == 0 {
        return Err(Reject::client_error(400, "empty points array".to_string()));
    }
    let model = entry.current();
    if pts.ncols() != model.dim() {
        return Err(Reject::client_error(
            400,
            format!("point dimensionality {} != model D {}", pts.ncols(), model.dim()),
        ));
    }
    let rows = pts.nrows();
    let (tx, rx) = mpsc::channel();
    {
        // The stop check must happen under the queue lock: batch_loop only
        // exits while holding this lock with the queue empty and stop set,
        // so a push that observes !stop here is guaranteed a drainer —
        // otherwise a request enqueued right as the server stops would
        // wait out the full recv timeout with nobody left to serve it.
        let mut q = sh.queue.lock().unwrap();
        if sh.stop.load(Ordering::Relaxed) {
            return Err(Reject::transient(503, "server is shutting down".to_string(), 1));
        }
        // Admission control: a filling queue browns out (429), a full one
        // sheds hard (503) — the client backs off (Retry-After tracks the
        // drain rate) and queue memory stays bounded.
        match sh.admission.decide(q.len()) {
            admission::Admission::Accept => {
                q.push_back(Pending { entry: Arc::clone(entry), pts, tx });
            }
            admission::Admission::Shed { status, retry_after_secs } => {
                sh.metrics.shed.fetch_add(1, Ordering::Relaxed);
                return Err(Reject::transient(
                    status,
                    format!(
                        "embed queue at {} of {} pending requests; retry shortly",
                        q.len(),
                        sh.admission.capacity()
                    ),
                    retry_after_secs,
                ));
            }
        }
    }
    sh.queue_cv.notify_one();
    match rx.recv_timeout(Duration::from_secs(60)) {
        Ok(Ok(emb)) => Ok(Json::obj(vec![
            ("embedding", matrix_to_json(&emb)),
            ("points", Json::num(rows as f64)),
            ("d", Json::num(emb.ncols() as f64)),
        ])),
        // Model was hot-swapped between validation and execution and the
        // new model disagrees about D — the client should retry.
        Ok(Err(msg)) => Err(Reject::client_error(400, msg)),
        Err(_) => Err(Reject::transient(
            503,
            "embed queue timed out (server overloaded or stopping)".to_string(),
            1,
        )),
    }
}

fn handle_reload(sh: &Shared, entry: &Arc<ModelEntry>, req: &http::Request, keep: bool) -> Vec<u8> {
    sh.metrics.reload.fetch_add(1, Ordering::Relaxed);
    let requested: Option<PathBuf> = if req.body.is_empty() {
        None
    } else {
        match std::str::from_utf8(&req.body).ok().and_then(|t| Json::parse(t).ok()) {
            Some(j) => j.get("path").and_then(Json::as_str).map(PathBuf::from),
            None => return err_json(sh, 400, "bad JSON body".to_string(), keep),
        }
    };
    // The registry loads (and checksum-verifies) before swapping: a
    // broken artifact on disk can never displace the serving model.
    match sh.registry.reload(entry.name(), requested.as_deref()) {
        Ok((fresh, path)) => ok_json(
            &Json::obj(vec![
                ("status", Json::str("reloaded")),
                ("name", Json::str(entry.name())),
                ("path", Json::str(path.display().to_string())),
                ("model", model_json(&fresh)),
            ]),
            keep,
        ),
        Err(msg) => err_json(sh, 400, format!("reload failed, keeping current model: {msg}"), keep),
    }
}

/// Batch-executor loop: drain the queue up to the adaptive cap, run one
/// pooled `map_points` per model, scatter results. Exits once stopped
/// *and* drained.
fn batch_loop(sh: &Shared) {
    loop {
        let drained: Vec<Pending> = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if !q.is_empty() {
                    break;
                }
                if sh.stop.load(Ordering::Relaxed) {
                    return;
                }
                q = sh.queue_cv.wait_timeout(q, POLL).unwrap().0;
            }
            let cap = sh.batcher.cap();
            let mut out = Vec::new();
            let mut rows = 0usize;
            while let Some(p) = q.front() {
                let r = p.pts.nrows();
                if !out.is_empty() && rows + r > cap {
                    break;
                }
                rows += r;
                out.push(q.pop_front().unwrap());
            }
            out
        };
        let sw = Instant::now();
        let reqs = drained.len() as u64;
        execute_batch(sh, drained);
        // Feed the drain rate back so Retry-After tracks reality.
        sh.admission.note_drained(reqs, sw.elapsed().as_secs_f64().max(1e-6));
    }
}

/// Group a drained batch by model (arrival order preserved within each
/// group) and execute one pooled projection per group.
fn execute_batch(sh: &Shared, drained: Vec<Pending>) {
    let mut groups: Vec<(Arc<ModelEntry>, Vec<Pending>)> = Vec::new();
    for p in drained {
        match groups.iter_mut().find(|(e, _)| Arc::ptr_eq(e, &p.entry)) {
            Some((_, v)) => v.push(p),
            None => {
                let e = Arc::clone(&p.entry);
                groups.push((e, vec![p]));
            }
        }
    }
    for (entry, batch) in groups {
        execute_group(sh, &entry, batch);
    }
}

fn execute_group(sh: &Shared, entry: &ModelEntry, drained: Vec<Pending>) {
    let model = entry.current();
    let d_in = model.dim();
    // Requests validated against a model that has since been hot-swapped
    // to a different input dimensionality get individual errors; the rest
    // batch together.
    let mut batch: Vec<Pending> = Vec::with_capacity(drained.len());
    for p in drained {
        if p.pts.ncols() == d_in {
            batch.push(p);
        } else {
            let _ = p.tx.send(Err(format!(
                "model was reloaded: point dimensionality {} != model D {d_in}",
                p.pts.ncols()
            )));
        }
    }
    if batch.is_empty() {
        return;
    }
    let total: usize = batch.iter().map(|p| p.pts.nrows()).sum();
    let mut data = Vec::with_capacity(total * d_in);
    for p in &batch {
        data.extend_from_slice(p.pts.as_slice());
    }
    let big = crate::linalg::Matrix::from_vec(total, d_in, data);
    sh.metrics.batches.fetch_add(1, Ordering::Relaxed);
    sh.metrics.batched_points.fetch_add(total as u64, Ordering::Relaxed);
    sh.metrics.max_batch_points.fetch_max(total as u64, Ordering::Relaxed);
    entry.metrics.batches.fetch_add(1, Ordering::Relaxed);
    entry.metrics.batched_points.fetch_add(total as u64, Ordering::Relaxed);
    entry.metrics.max_batch_points.fetch_max(total as u64, Ordering::Relaxed);
    match model.map_points_with(&big, sh.map_workers) {
        Ok(emb) => {
            let d_out = emb.ncols();
            let mut row = 0usize;
            for p in &batch {
                let r = p.pts.nrows();
                let slice = emb.slice(row, row + r, 0, d_out);
                row += r;
                let _ = p.tx.send(Ok(slice));
            }
        }
        Err(e) => {
            let msg = format!("projection failed: {e:#}");
            for p in &batch {
                let _ = p.tx.send(Err(msg.clone()));
            }
        }
    }
}

fn ok_json(body: &Json, keep: bool) -> Vec<u8> {
    http::response(200, "application/json", body.to_string().as_bytes(), keep)
}

fn err_json(sh: &Shared, status: u16, msg: String, keep: bool) -> Vec<u8> {
    sh.metrics.errors.fetch_add(1, Ordering::Relaxed);
    let body = Json::obj(vec![("error", Json::str(msg))]);
    http::response(status, "application/json", body.to_string().as_bytes(), keep)
}

/// Model summary used by `/healthz`, `/metrics`, and `/v1/reload`.
pub fn model_json(m: &FittedModel) -> Json {
    Json::obj(vec![
        ("n", Json::num(m.n() as f64)),
        ("dim", Json::num(m.dim() as f64)),
        ("landmarks", Json::num(m.num_landmarks() as f64)),
        ("d", Json::num(m.out_dim() as f64)),
        ("k", Json::num(m.k() as f64)),
    ])
}

/// Matrix → JSON array-of-row-arrays. Rust's float `Display` is
/// shortest-roundtrip, so serialize → parse restores every f64 bit-exactly
/// (the embed endpoint's bit-identity guarantee rides on this).
pub fn matrix_to_json(m: &crate::linalg::Matrix) -> Json {
    Json::arr(
        (0..m.nrows())
            .map(|i| Json::arr(m.row(i).iter().map(|&x| Json::num(x)).collect()))
            .collect(),
    )
}

/// JSON array-of-row-arrays → matrix; rejects ragged/non-numeric input.
pub fn matrix_from_json(j: &Json) -> Result<crate::linalg::Matrix, String> {
    let rows = j.as_arr().ok_or("expected an array of rows")?;
    let mut out: Vec<Vec<f64>> = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let cells = row.as_arr().ok_or_else(|| format!("row {i} is not an array"))?;
        let mut r = Vec::with_capacity(cells.len());
        for (jj, c) in cells.iter().enumerate() {
            r.push(c.as_f64().ok_or_else(|| format!("row {i} col {jj} is not a number"))?);
        }
        if let Some(first) = out.first() {
            if first.len() != r.len() {
                return Err(format!(
                    "ragged rows: row {i} has {} cols, row 0 has {}",
                    r.len(),
                    first.len()
                ));
            }
        }
        out.push(r);
    }
    Ok(crate::linalg::Matrix::from_rows(&out))
}

/// Exact percentile of a **sorted** latency sample (nearest-rank); used by
/// the loopback load generator and `bench-serve`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_json_roundtrip_bits() {
        let m = crate::linalg::Matrix::from_rows(&[
            vec![std::f64::consts::PI, -0.0, 1e-308],
            vec![1.0 / 3.0, 2.5e17, -7.125],
        ]);
        let j = matrix_to_json(&m);
        let text = j.to_string();
        let back = matrix_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.nrows(), 2);
        for (a, b) in m.as_slice().iter().zip(back.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn matrix_from_json_rejects_garbage() {
        assert!(matrix_from_json(&Json::parse("42").unwrap()).is_err());
        assert!(matrix_from_json(&Json::parse("[[1,2],[3]]").unwrap()).is_err());
        assert!(matrix_from_json(&Json::parse("[[1,\"x\"]]").unwrap()).is_err());
        assert!(matrix_from_json(&Json::parse("[]").unwrap()).unwrap().nrows() == 0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&s, 0.50), 50.0);
        assert_eq!(percentile(&s, 0.95), 95.0);
        assert_eq!(percentile(&s, 0.99), 99.0);
        assert_eq!(percentile(&s, 1.0), 100.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn server_latency_histogram_reports_percentiles() {
        let m = ServerMetrics::new();
        for _ in 0..90 {
            m.latency.record_us(80); // ≤100 bucket
        }
        for _ in 0..10 {
            m.latency.record_us(9_000); // ≤10_000 bucket
        }
        let s = m.latency.snapshot();
        assert_eq!(s.percentile_us(0.50), 100.0);
        assert_eq!(s.percentile_us(0.95), 10_000.0);
        assert_eq!(s.max_us, 9_000);
        // Overflow bucket reports the observed max.
        m.latency.record_us(400_000);
        assert_eq!(m.latency.snapshot().percentile_us(1.0), 400_000.0);
    }
}
