//! Hand-rolled HTTP/1.1 message framing (the crate is anyhow-only, so no
//! hyper/tiny_http — this mirrors how `util::json` hand-rolls JSON).
//!
//! The parser is a pure function over a byte buffer: callers accumulate
//! bytes from the socket and ask [`try_parse`] whether a complete request
//! sits at the front. `Ok(None)` means "need more bytes", `Err` means the
//! peer sent something malformed (answer 400 and close). This shape keeps
//! the parser independent of socket timeouts and trivially unit-testable,
//! and gives request pipelining for free: leftover bytes after `consumed`
//! are simply the next request.
//!
//! Scope: request line + headers + `Content-Length` bodies. Chunked
//! transfer encoding is rejected (nothing in the serving protocol needs
//! it), header names are lower-cased at parse time, and head/body sizes
//! are capped so a confused client cannot balloon server memory.

/// Maximum bytes of request line + headers.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Maximum request body bytes (a 1M-point f64 batch serializes well under
/// this; anything larger should be split into multiple requests anyway to
/// keep micro-batches block-sized).
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// `(name, value)` pairs; names lower-cased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// True for `HTTP/1.0` requests (default close instead of keep-alive).
    pub http10: bool,
}

impl Request {
    /// First header with the given (lower-case) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the connection must close after this request
    /// (`Connection: close`, or HTTP/1.0 without `keep-alive`).
    pub fn wants_close(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => true,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => false,
            _ => self.http10,
        }
    }
}

/// Try to parse one complete request from the front of `buf`. Returns the
/// request and the number of bytes consumed; `Ok(None)` when the buffer
/// does not yet hold a full request.
pub fn try_parse(buf: &[u8]) -> Result<Option<(Request, usize)>, String> {
    let head_end = match find_head_end(buf) {
        Some(e) => e,
        None if buf.len() > MAX_HEAD_BYTES => {
            return Err(format!("request head exceeds {MAX_HEAD_BYTES} bytes"));
        }
        None => return Ok(None),
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| "request head is not valid UTF-8".to_string())?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(format!("bad method in request line {request_line:?}"));
    }
    if !path.starts_with('/') {
        return Err(format!("bad path in request line {request_line:?}"));
    }
    let http10 = match version {
        "HTTP/1.1" => false,
        "HTTP/1.0" => true,
        other => return Err(format!("unsupported HTTP version {other:?}")),
    };
    let mut headers = Vec::new();
    for line in lines {
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| format!("malformed header line {line:?}"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let req = Request { method, path, headers, body: Vec::new(), http10 };
    if req
        .header("transfer-encoding")
        .is_some_and(|v| !v.eq_ignore_ascii_case("identity"))
    {
        return Err("chunked transfer encoding is not supported".to_string());
    }
    let body_len = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| format!("bad Content-Length {v:?}"))?,
    };
    if body_len > MAX_BODY_BYTES {
        return Err(format!("body of {body_len} bytes exceeds {MAX_BODY_BYTES}"));
    }
    let total = head_end + 4 + body_len;
    if buf.len() < total {
        return Ok(None);
    }
    let mut req = req;
    req.body = buf[head_end + 4..total].to_vec();
    Ok(Some((req, total)))
}

/// Byte offset of the `\r\n\r\n` head terminator, if present.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let scan = buf.len().min(MAX_HEAD_BYTES + 4);
    buf[..scan].windows(4).position(|w| w == b"\r\n\r\n")
}

/// Render a complete response with a body.
pub fn response(status: u16, content_type: &str, body: &[u8], keep_alive: bool) -> Vec<u8> {
    response_with_headers(status, content_type, body, keep_alive, &[])
}

/// [`response`] with extra response headers (e.g. `Retry-After` on a
/// load-shedding 503). `extra` entries are emitted verbatim after the
/// standard framing headers.
pub fn response_with_headers(
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
    extra: &[(&str, &str)],
) -> Vec<u8> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        status_reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in extra {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    let mut out = head.into_bytes();
    out.extend_from_slice(body);
    out
}

/// Reason phrase for the status codes the server emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_get_without_body() {
        let raw = b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n";
        let (req, used) = try_parse(raw).unwrap().unwrap();
        assert_eq!(used, raw.len());
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
        assert!(!req.wants_close()); // HTTP/1.1 defaults to keep-alive
    }

    #[test]
    fn parses_post_with_body_incrementally() {
        let raw = b"POST /v1/embed HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        // Every strict prefix is incomplete…
        for cut in 0..raw.len() {
            assert!(try_parse(&raw[..cut]).unwrap().is_none(), "cut={cut}");
        }
        // …and the full buffer parses.
        let (req, used) = try_parse(raw).unwrap().unwrap();
        assert_eq!(used, raw.len());
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn pipelined_requests_report_consumed() {
        let raw = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let (req, used) = try_parse(raw).unwrap().unwrap();
        assert_eq!(req.path, "/a");
        let (req2, used2) = try_parse(&raw[used..]).unwrap().unwrap();
        assert_eq!(req2.path, "/b");
        assert_eq!(used + used2, raw.len());
    }

    #[test]
    fn connection_semantics() {
        let close = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        assert!(try_parse(close).unwrap().unwrap().0.wants_close());
        let old = b"GET / HTTP/1.0\r\n\r\n";
        assert!(try_parse(old).unwrap().unwrap().0.wants_close());
        let old_ka = b"GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n";
        assert!(!try_parse(old_ka).unwrap().unwrap().0.wants_close());
    }

    #[test]
    fn rejects_malformed() {
        assert!(try_parse(b"NOT A REQUEST\r\n\r\n").is_err());
        assert!(try_parse(b"GET nopath HTTP/1.1\r\n\r\n").is_err());
        assert!(try_parse(b"GET / HTTP/2.0\r\n\r\n").is_err());
        assert!(try_parse(b"GET / HTTP/1.1\r\nbroken header\r\n\r\n").is_err());
        assert!(try_parse(b"POST / HTTP/1.1\r\nContent-Length: pony\r\n\r\n").is_err());
        assert!(try_parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").is_err());
    }

    #[test]
    fn enforces_size_limits() {
        let huge_head = vec![b'A'; MAX_HEAD_BYTES + 8];
        assert!(try_parse(&huge_head).is_err());
        let huge_body =
            format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(try_parse(huge_body.as_bytes()).is_err());
    }

    #[test]
    fn response_with_extra_headers() {
        let r =
            response_with_headers(503, "application/json", b"{}", false, &[("Retry-After", "1")]);
        let text = String::from_utf8(r).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        assert!(text.contains("\r\n\r\n{}"), "{text}");
    }

    #[test]
    fn response_roundtrips_framing() {
        let r = response(200, "application/json", b"{\"ok\":true}", true);
        let text = String::from_utf8(r).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 11\r\n"), "{text}");
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        assert!(text.ends_with("{\"ok\":true}"), "{text}");
    }
}
