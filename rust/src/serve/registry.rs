//! Multi-model registry: one serve process, N fitted manifolds.
//!
//! The set of model *names* is fixed at startup (`--models a=dir,b=dir`);
//! what each name *points at* is hot-swappable via
//! `POST /v1/models/<name>/reload`, with the same contract as the legacy
//! single-model reload: the replacement artifact is loaded and verified
//! **before** the swap, so a failed reload leaves the old model serving
//! and in-flight batches — which hold their own `Arc` — are never torn.
//!
//! Routing: `POST /v1/models/<name>/embed` (and `reload` / `GET
//! metrics`) namespaces every per-model operation under
//! [`route_model_path`]. The legacy paths `/v1/embed` and `/v1/reload`
//! keep working and alias the *default* entry — the first model
//! registered, named [`DEFAULT_MODEL`] for single-model starts.
//!
//! Each entry carries its own [`ModelMetrics`] (request counts, embed
//! latency histogram, batching shape) so `/metrics` can report per-model
//! load next to the server-wide aggregates.

use crate::engine::metrics::LatencyHistogram;
use crate::model::FittedModel;
use crate::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Name under which a single-model start registers its model.
pub const DEFAULT_MODEL: &str = "default";

/// Model names: path-segment safe, bounded.
pub fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name.bytes().all(|b| b.is_ascii_alphanumeric() || matches!(b, b'-' | b'_' | b'.'))
}

/// Split `/v1/models/<name>/<action>` into `(name, action)`.
pub fn route_model_path(path: &str) -> Option<(&str, &str)> {
    let rest = path.strip_prefix("/v1/models/")?;
    let (name, action) = rest.split_once('/')?;
    if name.is_empty() || action.is_empty() || action.contains('/') {
        return None;
    }
    Some((name, action))
}

/// Per-model serving counters (relaxed atomics — monitoring data).
#[derive(Debug, Default)]
pub struct ModelMetrics {
    pub embeds: AtomicU64,
    pub errors: AtomicU64,
    pub latency: LatencyHistogram,
    pub batches: AtomicU64,
    pub batched_points: AtomicU64,
    pub max_batch_points: AtomicU64,
}

impl ModelMetrics {
    pub fn to_json(&self) -> Json {
        let lat = self.latency.snapshot();
        Json::obj(vec![
            ("embeds", Json::num(self.embeds.load(Ordering::Relaxed) as f64)),
            ("errors", Json::num(self.errors.load(Ordering::Relaxed) as f64)),
            (
                "embed_latency_us",
                Json::obj(vec![
                    ("count", Json::num(lat.count as f64)),
                    ("mean", Json::num(lat.mean_us())),
                    ("p50", Json::num(lat.percentile_us(0.50))),
                    ("p95", Json::num(lat.percentile_us(0.95))),
                    ("p99", Json::num(lat.percentile_us(0.99))),
                    ("max", Json::num(lat.max_us as f64)),
                ]),
            ),
            (
                "batching",
                Json::obj(vec![
                    ("batches", Json::num(self.batches.load(Ordering::Relaxed) as f64)),
                    ("points", Json::num(self.batched_points.load(Ordering::Relaxed) as f64)),
                    (
                        "max_batch_points",
                        Json::num(self.max_batch_points.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
        ])
    }
}

/// One named, hot-swappable model slot.
pub struct ModelEntry {
    name: String,
    model: RwLock<Arc<FittedModel>>,
    /// Artifact directory the model was loaded from; reload without an
    /// explicit path re-reads this one.
    path: Mutex<Option<PathBuf>>,
    pub metrics: ModelMetrics,
    reloads_ok: AtomicU64,
    reloads_failed: AtomicU64,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The model currently serving this name. Batches clone the `Arc`
    /// once per drain, so a concurrent reload never tears a batch.
    pub fn current(&self) -> Arc<FittedModel> {
        Arc::clone(&self.model.read().expect("model lock poisoned"))
    }

    pub fn reloads_ok(&self) -> u64 {
        self.reloads_ok.load(Ordering::Relaxed)
    }

    pub fn reloads_failed(&self) -> u64 {
        self.reloads_failed.load(Ordering::Relaxed)
    }

    pub fn source_path(&self) -> Option<PathBuf> {
        self.path.lock().expect("path lock poisoned").clone()
    }
}

/// The fixed name → entry map. Lookup is a linear scan: the registry is
/// a handful of models, and a `Vec` keeps registration order — entry 0
/// is the default the legacy paths alias.
pub struct Registry {
    entries: Vec<Arc<ModelEntry>>,
}

impl Registry {
    /// Registry for a single-model start (legacy `serve --model`).
    pub fn single(model: FittedModel, path: Option<PathBuf>) -> Registry {
        Registry::from_entries(vec![(DEFAULT_MODEL.to_string(), model, path)])
            .expect("single-entry registry is always valid")
    }

    /// Build from `(name, model, source_path)` triples. Names must be
    /// non-empty, unique, and path-segment safe; the first entry becomes
    /// the default for the legacy single-model routes.
    pub fn from_entries(
        entries: Vec<(String, FittedModel, Option<PathBuf>)>,
    ) -> Result<Registry, String> {
        if entries.is_empty() {
            return Err("registry needs at least one model".to_string());
        }
        let mut out: Vec<Arc<ModelEntry>> = Vec::with_capacity(entries.len());
        for (name, model, path) in entries {
            if !valid_name(&name) {
                return Err(format!(
                    "invalid model name {name:?}: use 1-64 chars of [A-Za-z0-9._-]"
                ));
            }
            if out.iter().any(|e| e.name == name) {
                return Err(format!("duplicate model name {name:?}"));
            }
            out.push(Arc::new(ModelEntry {
                name,
                model: RwLock::new(Arc::new(model)),
                path: Mutex::new(path),
                metrics: ModelMetrics::default(),
                reloads_ok: AtomicU64::new(0),
                reloads_failed: AtomicU64::new(0),
            }));
        }
        Ok(Registry { entries: out })
    }

    pub fn get(&self, name: &str) -> Option<&Arc<ModelEntry>> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// The entry the legacy single-model routes alias (first registered).
    pub fn default_entry(&self) -> &Arc<ModelEntry> {
        &self.entries[0]
    }

    pub fn entries(&self) -> &[Arc<ModelEntry>] {
        &self.entries
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// Hot-reload one entry: load (and thereby checksum-verify) the
    /// artifact **before** swapping, so failure keeps the old model
    /// serving. Returns the freshly serving model and the path it came
    /// from.
    pub fn reload(
        &self,
        name: &str,
        requested: Option<&Path>,
    ) -> Result<(Arc<FittedModel>, PathBuf), String> {
        let entry = self.get(name).ok_or_else(|| self.unknown(name))?;
        let dir = match requested {
            Some(p) => p.to_path_buf(),
            None => entry
                .source_path()
                .ok_or_else(|| format!("model {name:?} was not loaded from disk; pass a path"))?,
        };
        match FittedModel::load(&dir) {
            Ok(m) => {
                let fresh = Arc::new(m);
                *entry.model.write().expect("model lock poisoned") = Arc::clone(&fresh);
                *entry.path.lock().expect("path lock poisoned") = Some(dir.clone());
                entry.reloads_ok.fetch_add(1, Ordering::Relaxed);
                Ok((fresh, dir))
            }
            Err(e) => {
                entry.reloads_failed.fetch_add(1, Ordering::Relaxed);
                Err(format!("reload of model {name:?} from {} failed: {e:#}", dir.display()))
            }
        }
    }

    /// 404 body text naming what *does* exist.
    pub fn unknown(&self, name: &str) -> String {
        format!("no model {name:?}; available: [{}]", self.names().join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_routing_splits_name_and_action() {
        assert_eq!(route_model_path("/v1/models/a/embed"), Some(("a", "embed")));
        assert_eq!(route_model_path("/v1/models/m-1.v2/metrics"), Some(("m-1.v2", "metrics")));
        assert_eq!(route_model_path("/v1/models/a"), None);
        assert_eq!(route_model_path("/v1/models//embed"), None);
        assert_eq!(route_model_path("/v1/models/a/"), None);
        assert_eq!(route_model_path("/v1/models/a/b/c"), None);
        assert_eq!(route_model_path("/v1/embed"), None);
    }

    #[test]
    fn name_validation() {
        assert!(valid_name("default"));
        assert!(valid_name("swiss_roll-v2.1"));
        assert!(!valid_name(""));
        assert!(!valid_name("has space"));
        assert!(!valid_name("slash/y"));
        assert!(!valid_name(&"x".repeat(65)));
    }

    #[test]
    fn model_metrics_json_shape() {
        let m = ModelMetrics::default();
        m.embeds.fetch_add(3, Ordering::Relaxed);
        m.latency.record_us(40);
        let j = m.to_json();
        assert_eq!(j.get("embeds").and_then(|v| v.as_f64()), Some(3.0));
        let lat = j.get("embed_latency_us").unwrap();
        assert_eq!(lat.get("count").and_then(|v| v.as_f64()), Some(1.0));
        assert!(j.get("batching").is_some());
    }
}
