//! Admission control for the serve tier: the bounded accept queue's
//! shedding policy.
//!
//! Every embed request passes through [`AdmissionController::decide`]
//! while the caller holds the pending-queue lock (so the observed queue
//! length cannot race the enqueue). Two watermarks:
//!
//! * **Hard** (`queue_len >= capacity`) — the queue is full; shed with
//!   `503 Service Unavailable`. `capacity == 0` sheds every embed, which
//!   is how a replica is drained out of rotation.
//! * **Soft** (`queue_len >= soft_limit`, at ¾ capacity) — the queue is
//!   approaching full; *brown out* by shedding every fourth request with
//!   `429 Too Many Requests` so well-behaved clients back off before the
//!   hard wall. The soft zone only exists for capacities ≥ 8 — tiny
//!   queues (tests, drain mode) stay exactly binary.
//!
//! Both answers carry `Retry-After`, estimated from the batch executor's
//! recently observed drain rate (requests/second, reported via
//! [`AdmissionController::note_drained`]) — "the backlog ahead of you at
//! the current drain rate", clamped to `1..=30` seconds.
//!
//! Shedding never touches accepted work: an admitted request is queued
//! and embedded by the same batch path as under no load, so admission
//! control cannot change output bits.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shed every Nth request inside the soft zone.
const SOFT_SHED_PERIOD: u64 = 4;
/// Soft zone exists only at or above this capacity.
const SOFT_MIN_CAPACITY: usize = 8;
/// `Retry-After` clamp (seconds).
const RETRY_AFTER_MIN: u64 = 1;
const RETRY_AFTER_MAX: u64 = 30;

/// Outcome of an admission decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Enqueue the request.
    Accept,
    /// Reject with `status` (429 soft / 503 hard) and a `Retry-After`.
    Shed { status: u16, retry_after_secs: u64 },
}

/// Bounded-accept-queue policy with relaxed-atomic counters (decisions
/// are made under the queue lock; the counters are monitoring data).
#[derive(Debug)]
pub struct AdmissionController {
    capacity: usize,
    soft_limit: usize,
    accepted: AtomicU64,
    shed_soft: AtomicU64,
    shed_hard: AtomicU64,
    soft_clock: AtomicU64,
    /// Recently observed drain rate, requests/second (gauge).
    drain_rps: AtomicU64,
}

impl AdmissionController {
    pub fn new(capacity: usize) -> Self {
        let soft_limit = if capacity >= SOFT_MIN_CAPACITY {
            (capacity * 3).div_ceil(4)
        } else {
            capacity
        };
        AdmissionController {
            capacity,
            soft_limit,
            accepted: AtomicU64::new(0),
            shed_soft: AtomicU64::new(0),
            shed_hard: AtomicU64::new(0),
            soft_clock: AtomicU64::new(0),
            drain_rps: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Decide for one embed request given the current pending-queue
    /// length. Call with the queue lock held.
    pub fn decide(&self, queue_len: usize) -> Admission {
        if queue_len >= self.capacity {
            self.shed_hard.fetch_add(1, Ordering::Relaxed);
            return Admission::Shed {
                status: 503,
                retry_after_secs: self.estimate_retry_after(queue_len),
            };
        }
        if queue_len >= self.soft_limit {
            let tick = self.soft_clock.fetch_add(1, Ordering::Relaxed);
            if tick % SOFT_SHED_PERIOD == SOFT_SHED_PERIOD - 1 {
                self.shed_soft.fetch_add(1, Ordering::Relaxed);
                return Admission::Shed {
                    status: 429,
                    retry_after_secs: self.estimate_retry_after(queue_len),
                };
            }
        }
        self.accepted.fetch_add(1, Ordering::Relaxed);
        Admission::Accept
    }

    /// Report a drained batch so `Retry-After` tracks the real drain
    /// rate. Called by the batch executor after each pooled embed.
    pub fn note_drained(&self, requests: u64, wall_secs: f64) {
        if wall_secs > 0.0 && requests > 0 {
            let rps = (requests as f64 / wall_secs).round() as u64;
            self.drain_rps.store(rps.max(1), Ordering::Relaxed);
        }
    }

    /// Seconds until the current backlog clears at the observed drain
    /// rate; 1 when no drain has been observed yet.
    fn estimate_retry_after(&self, queue_len: usize) -> u64 {
        let rps = self.drain_rps.load(Ordering::Relaxed);
        if rps == 0 {
            return RETRY_AFTER_MIN;
        }
        (queue_len as u64).div_ceil(rps).clamp(RETRY_AFTER_MIN, RETRY_AFTER_MAX)
    }

    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    pub fn shed_soft(&self) -> u64 {
        self.shed_soft.load(Ordering::Relaxed)
    }

    pub fn shed_hard(&self) -> u64 {
        self.shed_hard.load(Ordering::Relaxed)
    }

    pub fn drain_rps(&self) -> u64 {
        self.drain_rps.load(Ordering::Relaxed)
    }

    /// `/metrics` fragment.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("capacity", Json::num(self.capacity as f64)),
            ("soft_limit", Json::num(self.soft_limit as f64)),
            ("accepted", Json::num(self.accepted() as f64)),
            ("shed_429", Json::num(self.shed_soft() as f64)),
            ("shed_503", Json::num(self.shed_hard() as f64)),
            ("drain_rps", Json::num(self.drain_rps() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_capacity_sheds_everything_hard() {
        let a = AdmissionController::new(0);
        for len in 0..5 {
            match a.decide(len) {
                Admission::Shed { status: 503, retry_after_secs } => {
                    assert!(retry_after_secs >= 1);
                }
                other => panic!("expected hard shed, got {other:?}"),
            }
        }
        assert_eq!(a.shed_hard(), 5);
        assert_eq!(a.accepted(), 0);
    }

    #[test]
    fn small_capacity_is_binary() {
        // capacity < 8: no soft zone — accept below, 503 at/above.
        let a = AdmissionController::new(2);
        assert_eq!(a.decide(0), Admission::Accept);
        assert_eq!(a.decide(1), Admission::Accept);
        match a.decide(2) {
            Admission::Shed { status, .. } => assert_eq!(status, 503),
            Admission::Accept => panic!("full queue must shed"),
        }
        assert_eq!(a.accepted(), 2);
        assert_eq!(a.shed_soft(), 0);
    }

    #[test]
    fn soft_zone_browns_out_every_fourth() {
        let a = AdmissionController::new(16); // soft limit = 12
        for _ in 0..8 {
            assert_eq!(a.decide(4), Admission::Accept); // below soft zone
        }
        let mut soft = 0;
        for _ in 0..8 {
            if let Admission::Shed { status, .. } = a.decide(13) {
                assert_eq!(status, 429);
                soft += 1;
            }
        }
        assert_eq!(soft, 2, "every 4th request in the soft zone sheds");
        assert_eq!(a.shed_soft(), 2);
        assert_eq!(a.shed_hard(), 0);
    }

    #[test]
    fn retry_after_tracks_drain_rate() {
        let a = AdmissionController::new(8);
        // No drain observed yet: conservative 1s.
        match a.decide(8) {
            Admission::Shed { retry_after_secs, .. } => assert_eq!(retry_after_secs, 1),
            Admission::Accept => panic!(),
        }
        // 2 requests/second observed: backlog of 8 → 4 seconds.
        a.note_drained(4, 2.0);
        assert_eq!(a.drain_rps(), 2);
        match a.decide(8) {
            Admission::Shed { retry_after_secs, .. } => assert_eq!(retry_after_secs, 4),
            Admission::Accept => panic!(),
        }
        // Huge backlog still clamps at 30s.
        match a.decide(1_000_000) {
            Admission::Shed { retry_after_secs, .. } => assert_eq!(retry_after_secs, 30),
            Admission::Accept => panic!(),
        }
    }

    #[test]
    fn metrics_json_has_counters() {
        let a = AdmissionController::new(4);
        let _ = a.decide(0);
        let _ = a.decide(4);
        let j = a.to_json();
        assert_eq!(j.get("accepted").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("shed_503").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("capacity").and_then(|v| v.as_f64()), Some(4.0));
    }
}
