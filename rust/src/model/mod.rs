//! Persistent fitted-model artifacts — the serving side of the paper's
//! streaming companion method (§V, Schoeneman et al.): the expensive exact
//! batch fit is saved once and then amortized over any number of O(k·m)
//! out-of-sample projections, possibly in a different process, on a
//! different day ([`crate::serve`] puts an HTTP front on exactly this).
//!
//! [`FittedModel`] is the fit-state of
//! [`crate::coordinator::streaming::StreamingModel`] split into a
//! serializable struct: the batch points, landmark indices, landmark
//! geodesic table δ, per-landmark means δ̄, the landmark-MDS eigenpairs,
//! and the triangulated batch embedding. On disk a model is a *directory*:
//!
//! ```text
//! model/
//!   model.json      # manifest: format version, dims, per-file checksums
//!   batch.bin       # n×D  batch points                  (data::io format)
//!   delta.bin       # m×n  squared geodesics landmark → batch point
//!   eigvecs.bin     # m×d  landmark-MDS eigenvectors
//!   embedding.bin   # n×d  triangulated batch embedding
//! ```
//!
//! Small vectors (landmark indices, δ̄, eigenvalues) live in the manifest
//! itself. [`FittedModel::load`] cross-checks the manifest against the
//! binary files — format version, matrix shapes, FNV-1a-64 checksums, and
//! cross-file consistency — and rejects any mismatch with context instead
//! of panicking later, mirroring the AOT artifact manifest cross-check in
//! [`crate::runtime`]. `save → load → map_points` is bit-identical to the
//! in-memory model: matrices round-trip through the exact little-endian
//! f64 binary format and manifest floats through Rust's shortest-roundtrip
//! float formatting.
//!
//! [`ModelInfo::inspect`] reads the manifest *only* (no matrix loads, no
//! checksum passes), so `isospark info --model <dir>` can describe a
//! broken artifact without tripping over the breakage.

use crate::data::io::{file_fnv1a64, read_bin, write_bin};
use crate::engine::executor::{resolve_workers, run_tasks_with_policy};
use crate::kernels::kselect::row_topk;
use crate::linalg::Matrix;
use crate::util::fmt::render_table;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// On-disk format version this build writes and reads.
pub const FORMAT_VERSION: usize = 1;
/// Manifest file name inside a model directory.
pub const MANIFEST_FILE: &str = "model.json";
/// Manifest `kind` tag (a cheap defence against pointing the loader at an
/// unrelated JSON file, e.g. the AOT artifact manifest).
const KIND: &str = "isospark-fitted-model";

/// The four matrix files of an artifact, with their manifest names.
const FILE_BATCH: &str = "batch.bin";
const FILE_DELTA: &str = "delta.bin";
const FILE_EIGVECS: &str = "eigvecs.bin";
const FILE_EMBEDDING: &str = "embedding.bin";

/// Below this many flops-worth of projection work, `map_points` stays on
/// the serial path: a pool spawn costs more than the mapping itself (same
/// reasoning as the driver-side assembly thresholds in `coordinator`).
const PAR_MIN_WORK: usize = 1 << 17;

/// A fitted streaming-Isomap model: everything needed to project new
/// points into the batch embedding frame, detached from the engine that
/// produced it.
#[derive(Clone)]
pub struct FittedModel {
    /// Batch points (n × D), kept for kNN of incoming points.
    pub(crate) batch: Matrix,
    /// Landmark indices into the batch.
    pub(crate) landmarks: Vec<usize>,
    /// Squared geodesic distances landmark → every batch point (m × n).
    pub(crate) delta: Matrix,
    /// Mean squared landmark-landmark distance per landmark (δ̄).
    pub(crate) mean_delta: Vec<f64>,
    /// Landmark MDS eigenpairs used for triangulation.
    pub(crate) eigvals: Vec<f64>,
    pub(crate) eigvecs: Matrix,
    /// Output dimensionality.
    pub(crate) d: usize,
    /// Neighborhood size used for incoming points.
    pub(crate) k: usize,
    /// Batch embedding (n × d) — triangulated, same frame as new points.
    pub batch_embedding: Matrix,
}

impl FittedModel {
    /// Number of batch points.
    pub fn n(&self) -> usize {
        self.batch.nrows()
    }

    /// Input dimensionality D.
    pub fn dim(&self) -> usize {
        self.batch.ncols()
    }

    /// Number of landmarks.
    pub fn num_landmarks(&self) -> usize {
        self.landmarks.len()
    }

    /// Output (embedding) dimensionality d.
    pub fn out_dim(&self) -> usize {
        self.d
    }

    /// Neighborhood size k used for incoming points.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Map one new point from the stream: kNN against the batch, geodesics
    /// to landmarks through those neighbors, distance-based triangulation.
    pub fn map_point(&self, p: &[f64]) -> Result<Vec<f64>> {
        if p.len() != self.batch.ncols() {
            bail!("point dimensionality {} != batch D {}", p.len(), self.batch.ncols());
        }
        let n = self.batch.nrows();
        // Distances to every batch point (O(n·D) — the stream fast path).
        let dists: Vec<f64> = (0..n)
            .map(|i| {
                self.batch
                    .row(i)
                    .iter()
                    .zip(p)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        let nbrs = row_topk(&dists, self.k, 0, None);
        // Geodesic to each landmark ≈ min over neighbors of (edge + geo).
        let m = self.landmarks.len();
        let mut dsq = vec![0.0; m];
        for (a, ds) in dsq.iter_mut().enumerate() {
            let mut best = f64::INFINITY;
            for &(edge, j) in &nbrs {
                let geo = self.delta[(a, j)].sqrt();
                best = best.min(edge + geo);
            }
            *ds = best * best;
        }
        Ok(self.triangulate(&dsq))
    }

    /// Map a batch of streaming points, using all available cores for
    /// large batches (see [`FittedModel::map_points_with`]).
    pub fn map_points(&self, pts: &Matrix) -> Result<Matrix> {
        self.map_points_with(pts, 0)
    }

    /// Map a batch of streaming points on a worker pool of `workers`
    /// threads (0 = all cores). Per-point kNN + triangulation is
    /// embarrassingly parallel and each row is computed by the exact same
    /// serial code, so the result is bit-identical for any pool size;
    /// small batches stay on the serial path because a pool spawn costs
    /// more than the mapping.
    pub fn map_points_with(&self, pts: &Matrix, workers: usize) -> Result<Matrix> {
        if pts.nrows() > 0 && pts.ncols() != self.batch.ncols() {
            bail!("point dimensionality {} != batch D {}", pts.ncols(), self.batch.ncols());
        }
        let rows = pts.nrows();
        let d = self.d;
        let mut out = Matrix::zeros(rows, d);
        let workers = resolve_workers(workers).min(rows.max(1));
        let per_point = self.batch.nrows() * self.batch.ncols().max(1)
            + self.k * self.landmarks.len();
        if workers == 1 || rows * per_point < PAR_MIN_WORK {
            for i in 0..rows {
                let y = self.map_point(pts.row(i))?;
                out.row_mut(i).copy_from_slice(&y);
            }
            return Ok(out);
        }
        // Carve the output buffer into disjoint row-range spans (the eigen
        // V-paste idiom) so workers write without locks; chunking only
        // affects scheduling, never bits.
        let chunk = rows.div_ceil(workers * 4).max(1);
        let mut tasks: Vec<(usize, &mut [f64])> = Vec::new();
        let mut rest: &mut [f64] = out.as_mut_slice();
        let mut start = 0usize;
        while start < rows {
            let end = (start + chunk).min(rows);
            let (span, tail) = std::mem::take(&mut rest).split_at_mut((end - start) * d);
            tasks.push((start, span));
            rest = tail;
            start = end;
        }
        // Serving has no SparkContext and therefore no fault plan: the
        // policy slot is always `None` here, i.e. the plain fast path.
        let results =
            run_tasks_with_policy(None, "model:map_points", workers, tasks, |(start, span)| {
                let rows_here = span.len() / d;
                for r in 0..rows_here {
                    let y = self.map_point(pts.row(*start + r))?;
                    span[r * d..(r + 1) * d].copy_from_slice(&y);
                }
                Ok::<(), anyhow::Error>(())
            });
        for r in results {
            r?;
        }
        Ok(out)
    }

    /// L-Isomap triangulation: y = ½·Λ^{-½}·Qᵀ·(δ̄ − δ).
    pub(crate) fn triangulate(&self, dsq: &[f64]) -> Vec<f64> {
        let m = self.landmarks.len();
        (0..self.d)
            .map(|j| {
                let mut acc = 0.0;
                for a in 0..m {
                    acc += self.eigvecs[(a, j)] * (self.mean_delta[a] - dsq[a]);
                }
                0.5 * acc / self.eigvals[j].sqrt()
            })
            .collect()
    }

    /// Internal consistency check shared by `fit` products and `load`.
    fn validate(&self) -> Result<()> {
        let (n, dd) = (self.batch.nrows(), self.batch.ncols());
        let m = self.landmarks.len();
        if n == 0 || dd == 0 {
            bail!("empty batch ({n}×{dd})");
        }
        if m == 0 {
            bail!("no landmarks");
        }
        if self.d == 0 {
            bail!("output dimensionality d = 0");
        }
        if self.k == 0 || self.k > n {
            bail!("neighborhood size k={} out of range 1..={n}", self.k);
        }
        if (self.delta.nrows(), self.delta.ncols()) != (m, n) {
            bail!(
                "delta shape {}×{} != landmarks×batch {m}×{n}",
                self.delta.nrows(),
                self.delta.ncols()
            );
        }
        if (self.eigvecs.nrows(), self.eigvecs.ncols()) != (m, self.d) {
            bail!(
                "eigvecs shape {}×{} != m×d {m}×{}",
                self.eigvecs.nrows(),
                self.eigvecs.ncols(),
                self.d
            );
        }
        if (self.batch_embedding.nrows(), self.batch_embedding.ncols()) != (n, self.d) {
            bail!(
                "batch embedding shape {}×{} != n×d {n}×{}",
                self.batch_embedding.nrows(),
                self.batch_embedding.ncols(),
                self.d
            );
        }
        if self.mean_delta.len() != m {
            bail!("mean_delta length {} != m {m}", self.mean_delta.len());
        }
        if self.eigvals.len() != self.d {
            bail!("eigvals length {} != d {}", self.eigvals.len(), self.d);
        }
        // The manifest itself carries no checksum (only the .bin files
        // do), so its floats are the untrusted surface: require them
        // finite and sane or a bit-rotted model.json would serve inf/NaN
        // embeddings — which Json::write can't even legally serialize.
        if let Some(bad) = self.eigvals.iter().find(|v| !v.is_finite() || **v <= 0.0) {
            bail!("non-positive/non-finite MDS eigenvalue {bad} (triangulation divides by √λ)");
        }
        if let Some(bad) = self.mean_delta.iter().find(|v| !v.is_finite()) {
            bail!("non-finite mean_delta entry {bad}");
        }
        if let Some(&bad) = self.landmarks.iter().find(|&&l| l >= n) {
            bail!("landmark index {bad} out of range for batch n={n}");
        }
        Ok(())
    }

    /// Write the artifact directory (created if missing): four binary
    /// matrices plus the `model.json` manifest with per-file checksums.
    ///
    /// ```no_run
    /// use isospark::backend::Backend;
    /// use isospark::config::{ClusterConfig, IsomapConfig};
    /// use isospark::coordinator::streaming::StreamingModel;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let batch = isospark::data::swiss_roll::euler_isometric(400, 42).points;
    /// let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
    /// let fit = StreamingModel::fit(&batch, &cfg, 64, &ClusterConfig::local(), &Backend::Native)?;
    /// fit.model().save(std::path::Path::new("/tmp/isospark-model"))?;
    /// # Ok(()) }
    /// ```
    pub fn save(&self, dir: &Path) -> Result<()> {
        self.validate().context("refusing to save an inconsistent model")?;
        std::fs::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
        let mut files: Vec<(&str, Json)> = Vec::new();
        for (name, m) in [
            (FILE_BATCH, &self.batch),
            (FILE_DELTA, &self.delta),
            (FILE_EIGVECS, &self.eigvecs),
            (FILE_EMBEDDING, &self.batch_embedding),
        ] {
            let path = dir.join(name);
            write_bin(&path, m).with_context(|| format!("write {name}"))?;
            let sum = file_fnv1a64(&path).with_context(|| format!("checksum {name}"))?;
            files.push((
                name,
                Json::obj(vec![
                    ("rows", Json::num(m.nrows() as f64)),
                    ("cols", Json::num(m.ncols() as f64)),
                    ("fnv1a64", Json::str(format!("{sum:016x}"))),
                ]),
            ));
        }
        let manifest = Json::obj(vec![
            ("kind", Json::str(KIND)),
            ("format_version", Json::num(FORMAT_VERSION as f64)),
            ("n", Json::num(self.n() as f64)),
            ("dim", Json::num(self.dim() as f64)),
            ("m", Json::num(self.num_landmarks() as f64)),
            ("d", Json::num(self.d as f64)),
            ("k", Json::num(self.k as f64)),
            (
                "landmarks",
                Json::arr(self.landmarks.iter().map(|&l| Json::num(l as f64)).collect()),
            ),
            ("mean_delta", Json::arr(self.mean_delta.iter().map(|&x| Json::num(x)).collect())),
            ("eigvals", Json::arr(self.eigvals.iter().map(|&x| Json::num(x)).collect())),
            ("files", Json::obj(files)),
        ]);
        let mpath = dir.join(MANIFEST_FILE);
        std::fs::write(&mpath, manifest.to_string()).with_context(|| format!("write {mpath:?}"))?;
        Ok(())
    }

    /// Load an artifact directory, cross-checking format version, shapes,
    /// and checksums. Every failure carries context naming the offending
    /// file or field; nothing in here panics.
    ///
    /// ```no_run
    /// use isospark::linalg::Matrix;
    /// use isospark::model::FittedModel;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let model = FittedModel::load(std::path::Path::new("/tmp/isospark-model"))?;
    /// let point = Matrix::zeros(1, model.dim()); // one D-dimensional query point
    /// let embedded = model.map_points(&point)?;
    /// assert_eq!(embedded.ncols(), model.out_dim());
    /// # Ok(()) }
    /// ```
    pub fn load(dir: &Path) -> Result<FittedModel> {
        let man = Manifest::read(dir)?;
        if man.format_version != FORMAT_VERSION {
            bail!(
                "{}: format version {} (this build reads {FORMAT_VERSION})",
                dir.join(MANIFEST_FILE).display(),
                man.format_version
            );
        }
        let batch = man.load_matrix(dir, FILE_BATCH, man.n, man.dim)?;
        let delta = man.load_matrix(dir, FILE_DELTA, man.m, man.n)?;
        let eigvecs = man.load_matrix(dir, FILE_EIGVECS, man.m, man.d)?;
        let batch_embedding = man.load_matrix(dir, FILE_EMBEDDING, man.n, man.d)?;
        if man.landmarks.len() != man.m {
            bail!("manifest landmarks length {} != m {}", man.landmarks.len(), man.m);
        }
        let model = FittedModel {
            batch,
            landmarks: man.landmarks,
            delta,
            mean_delta: man.mean_delta,
            eigvals: man.eigvals,
            eigvecs,
            d: man.d,
            k: man.k,
            batch_embedding,
        };
        model
            .validate()
            .with_context(|| format!("model artifact {} is inconsistent", dir.display()))?;
        Ok(model)
    }
}

/// Strict non-negative integer from a JSON number: unlike
/// `Json::as_usize` (a plain cast), this rejects fractional, negative,
/// non-finite, and >2⁵³ values — a hand-edited or bit-rotted manifest
/// must fail loudly, not load with silently truncated parameters.
fn json_index(j: &Json) -> Option<usize> {
    let x = j.as_f64()?;
    if x.is_finite() && x.fract() == 0.0 && (0.0..=9e15).contains(&x) {
        Some(x as usize)
    } else {
        None
    }
}

/// Parsed `model.json`, shared between the full loader and the
/// manifest-only inspector.
struct Manifest {
    format_version: usize,
    n: usize,
    dim: usize,
    m: usize,
    d: usize,
    k: usize,
    landmarks: Vec<usize>,
    mean_delta: Vec<f64>,
    eigvals: Vec<f64>,
    /// name → (rows, cols, fnv1a64)
    files: BTreeMap<String, (usize, usize, u64)>,
}

impl Manifest {
    fn read(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read model manifest {mpath:?}"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parse model manifest {}: {e}", mpath.display()))?;
        let kind = j.get("kind").and_then(Json::as_str).unwrap_or("<missing>");
        if kind != KIND {
            bail!("{}: kind {kind:?} is not a fitted-model manifest ({KIND:?})", mpath.display());
        }
        let field = |key: &str| -> Result<usize> {
            j.get(key).and_then(json_index).ok_or_else(|| {
                anyhow!("{}: missing/non-integer numeric field {key:?}", mpath.display())
            })
        };
        let floats = |key: &str| -> Result<Vec<f64>> {
            let arr = j
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{}: missing array {key:?}", mpath.display()))?;
            arr.iter()
                .map(|x| {
                    x.as_f64().ok_or_else(|| {
                        anyhow!("{}: non-numeric entry in {key:?}", mpath.display())
                    })
                })
                .collect()
        };
        let mut files = BTreeMap::new();
        if let Some(Json::Obj(fm)) = j.get("files") {
            for (name, entry) in fm {
                let rows = entry
                    .get("rows")
                    .and_then(json_index)
                    .ok_or_else(|| anyhow!("{}: file {name}: bad rows", mpath.display()))?;
                let cols = entry
                    .get("cols")
                    .and_then(json_index)
                    .ok_or_else(|| anyhow!("{}: file {name}: bad cols", mpath.display()))?;
                let sum = entry
                    .get("fnv1a64")
                    .and_then(Json::as_str)
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
                    .ok_or_else(|| {
                        anyhow!("{}: file {name}: missing/garbled fnv1a64", mpath.display())
                    })?;
                files.insert(name.clone(), (rows, cols, sum));
            }
        } else {
            bail!("{}: missing \"files\" object", mpath.display());
        }
        let landmarks: Vec<usize> = j
            .get("landmarks")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{}: missing array \"landmarks\"", mpath.display()))?
            .iter()
            .map(|x| {
                json_index(x).ok_or_else(|| {
                    anyhow!("{}: non-integer landmark index in manifest", mpath.display())
                })
            })
            .collect::<Result<_>>()?;
        Ok(Manifest {
            format_version: field("format_version")?,
            n: field("n")?,
            dim: field("dim")?,
            m: field("m")?,
            d: field("d")?,
            k: field("k")?,
            landmarks,
            mean_delta: floats("mean_delta")?,
            eigvals: floats("eigvals")?,
            files,
        })
    }

    /// Load one binary matrix, verifying checksum and shape against both
    /// the per-file manifest entry and the caller's expectation.
    fn load_matrix(&self, dir: &Path, name: &str, rows: usize, cols: usize) -> Result<Matrix> {
        let (mrows, mcols, want_sum) = *self
            .files
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no entry for {name}"))?;
        if (mrows, mcols) != (rows, cols) {
            bail!("{name}: manifest shape {mrows}×{mcols} != declared dims {rows}×{cols}");
        }
        let path = dir.join(name);
        let got_sum = file_fnv1a64(&path)?;
        if got_sum != want_sum {
            bail!(
                "{name}: checksum mismatch (manifest {want_sum:016x}, file {got_sum:016x}) — \
                 artifact corrupt?"
            );
        }
        let m = read_bin(&path).with_context(|| format!("load {name}"))?;
        if (m.nrows(), m.ncols()) != (rows, cols) {
            bail!("{name}: stored shape {}×{} != manifest {rows}×{cols}", m.nrows(), m.ncols());
        }
        Ok(m)
    }
}

/// One binary file as described by the manifest, plus its on-disk reality.
pub struct FileInfo {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Bytes the binary format implies (header + rows·cols·8).
    pub expected_bytes: u64,
    /// Actual size, `None` when the file is missing.
    pub on_disk_bytes: Option<u64>,
    pub checksum: String,
}

/// Manifest-only view of a model artifact for `isospark info --model`:
/// reads `model.json` and stats the binary files, but never loads a matrix
/// or walks its bytes — a truncated or corrupt artifact stays inspectable.
pub struct ModelInfo {
    pub dir: PathBuf,
    pub format_version: usize,
    pub n: usize,
    pub dim: usize,
    pub m: usize,
    pub d: usize,
    pub k: usize,
    pub files: Vec<FileInfo>,
}

impl ModelInfo {
    /// Read the manifest of `dir`. Unlike [`FittedModel::load`], a format
    /// version this build cannot serve is *reported*, not rejected.
    pub fn inspect(dir: &Path) -> Result<ModelInfo> {
        let man = Manifest::read(dir)?;
        let files = man
            .files
            .iter()
            .map(|(name, &(rows, cols, sum))| FileInfo {
                name: name.clone(),
                rows,
                cols,
                expected_bytes: crate::data::io::bin_file_size(rows, cols).unwrap_or(u64::MAX),
                on_disk_bytes: std::fs::metadata(dir.join(name)).ok().map(|m| m.len()),
                checksum: format!("{sum:016x}"),
            })
            .collect();
        Ok(ModelInfo {
            dir: dir.to_path_buf(),
            format_version: man.format_version,
            n: man.n,
            dim: man.dim,
            m: man.m,
            d: man.d,
            k: man.k,
            files,
        })
    }

    /// Human-readable report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "model artifact {} (format v{}{})\n",
            self.dir.display(),
            self.format_version,
            if self.format_version == FORMAT_VERSION {
                "".to_string()
            } else {
                format!(", this build reads v{FORMAT_VERSION}")
            }
        ));
        out.push_str(&format!(
            "  batch n={} D={} | landmarks m={} | output d={} | kNN k={}\n",
            self.n, self.dim, self.m, self.d, self.k
        ));
        let mut rows = vec![vec![
            "file".to_string(),
            "shape".to_string(),
            "expect".to_string(),
            "on disk".to_string(),
            "fnv1a64".to_string(),
        ]];
        for f in &self.files {
            let status = match f.on_disk_bytes {
                None => "MISSING".to_string(),
                Some(b) if b != f.expected_bytes => format!("{b} (TRUNCATED?)"),
                Some(b) => b.to_string(),
            };
            rows.push(vec![
                f.name.clone(),
                format!("{}×{}", f.rows, f.cols),
                f.expected_bytes.to_string(),
                status,
                f.checksum.clone(),
            ]);
        }
        out.push_str(&render_table(&rows));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io::fnv1a64;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("isospark_model_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// A tiny hand-built (not fitted) model for unit tests; the integration
    /// suite covers real fitted models.
    fn toy_model() -> FittedModel {
        let n = 6;
        let dd = 3;
        let m = 3;
        let d = 2;
        let batch = Matrix::from_vec(n, dd, (0..n * dd).map(|i| i as f64 * 0.5).collect());
        let mut delta = Matrix::zeros(m, n);
        for a in 0..m {
            for j in 0..n {
                delta[(a, j)] = ((a + 1) * (j + 2)) as f64 * 0.25;
            }
        }
        let mut eigvecs = Matrix::zeros(m, d);
        for a in 0..m {
            for j in 0..d {
                eigvecs[(a, j)] = 0.1 + (a * d + j) as f64 * 0.3;
            }
        }
        let mut model = FittedModel {
            batch,
            landmarks: vec![0, 2, 5],
            delta,
            mean_delta: vec![1.0, 2.0, 3.0],
            eigvals: vec![2.5, 1.25],
            eigvecs,
            d,
            k: 2,
            batch_embedding: Matrix::zeros(n, d),
        };
        for i in 0..n {
            let di: Vec<f64> = (0..m).map(|a| model.delta[(a, i)]).collect();
            let y = model.triangulate(&di);
            model.batch_embedding.row_mut(i).copy_from_slice(&y);
        }
        model
    }

    #[test]
    fn fnv_matches_known_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn save_load_roundtrip_bits() {
        let model = toy_model();
        let dir = tmp_dir("roundtrip");
        model.save(&dir).unwrap();
        let loaded = FittedModel::load(&dir).unwrap();
        assert_eq!(loaded.batch.as_slice(), model.batch.as_slice());
        assert_eq!(loaded.delta.as_slice(), model.delta.as_slice());
        assert_eq!(loaded.eigvecs.as_slice(), model.eigvecs.as_slice());
        assert_eq!(loaded.batch_embedding.as_slice(), model.batch_embedding.as_slice());
        assert_eq!(loaded.landmarks, model.landmarks);
        assert_eq!(loaded.mean_delta, model.mean_delta);
        assert_eq!(loaded.eigvals, model.eigvals);
        assert_eq!((loaded.d, loaded.k), (model.d, model.k));
        let p = vec![0.1, 0.2, 0.3];
        let a = model.map_point(&p).unwrap();
        let b = loaded.map_point(&p).unwrap();
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn inspect_reads_manifest_only() {
        let model = toy_model();
        let dir = tmp_dir("inspect");
        model.save(&dir).unwrap();
        // Corrupt a binary file: inspect must still work (manifest-only)…
        std::fs::write(dir.join(FILE_DELTA), b"garbage").unwrap();
        let info = ModelInfo::inspect(&dir).unwrap();
        assert_eq!((info.n, info.dim, info.m, info.d, info.k), (6, 3, 3, 2, 2));
        assert_eq!(info.format_version, FORMAT_VERSION);
        let rendered = info.render();
        assert!(rendered.contains("delta.bin"), "{rendered}");
        assert!(rendered.contains("TRUNCATED"), "{rendered}");
        // …while load fails loudly on the same artifact.
        let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
        assert!(err.contains("delta.bin"), "{err}");
    }

    #[test]
    fn missing_file_is_reported() {
        let model = toy_model();
        let dir = tmp_dir("missing");
        model.save(&dir).unwrap();
        std::fs::remove_file(dir.join(FILE_EMBEDDING)).unwrap();
        let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
        assert!(err.contains("embedding.bin"), "{err}");
        let info = ModelInfo::inspect(&dir).unwrap();
        assert!(info.render().contains("MISSING"));
    }

    #[test]
    fn map_points_parallel_matches_serial_bitwise() {
        let model = toy_model();
        // Enough rows that the pool path engages even on a toy model.
        let rows = PAR_MIN_WORK; // per_point ≥ 1 ⇒ rows·per_point ≥ threshold
        let rows = rows / (model.batch.nrows() * model.batch.ncols()) + 16;
        let pts = Matrix::from_vec(
            rows,
            3,
            (0..rows * 3).map(|i| (i as f64 * 0.713).sin()).collect(),
        );
        let seq = model.map_points_with(&pts, 1).unwrap();
        let par = model.map_points_with(&pts, 8).unwrap();
        for (a, b) in seq.as_slice().iter().zip(par.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rejects_non_integer_manifest_numbers() {
        // A hand-edited manifest with fractional/negative "integers" must
        // fail loudly, not load with silently truncated parameters.
        let model = toy_model();
        let dir = tmp_dir("strict");
        model.save(&dir).unwrap();
        let mpath = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace("\"landmarks\":[0,2,5]", "\"landmarks\":[0,2.5,5]"))
            .unwrap();
        let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
        assert!(err.contains("non-integer landmark"), "{err}");

        model.save(&dir).unwrap();
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace("\"k\":2", "\"k\":2.9")).unwrap();
        let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
        assert!(err.contains("\"k\""), "{err}");

        model.save(&dir).unwrap();
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace("\"n\":6", "\"n\":-6")).unwrap();
        let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
        assert!(err.contains("\"n\""), "{err}");

        // Overflow-to-infinity floats (1e400 parses as +inf) must not
        // produce a model that serves inf embeddings.
        model.save(&dir).unwrap();
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace("\"mean_delta\":[1,2,3]", "\"mean_delta\":[1,1e400,3]"))
            .unwrap();
        let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
        assert!(err.contains("non-finite"), "{err}");

        model.save(&dir).unwrap();
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace("\"eigvals\":[2.5,1.25]", "\"eigvals\":[2.5,1e400]"))
            .unwrap();
        let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
        assert!(err.contains("non-finite"), "{err}");
    }

    #[test]
    fn rejects_unsupported_version_and_wrong_kind() {
        let model = toy_model();
        let dir = tmp_dir("version");
        model.save(&dir).unwrap();
        let mpath = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace("\"format_version\":1", "\"format_version\":99"))
            .unwrap();
        let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
        assert!(err.contains("format version 99"), "{err}");
        // Inspection still describes the future-version artifact.
        let info = ModelInfo::inspect(&dir).unwrap();
        assert_eq!(info.format_version, 99);
        // A non-model manifest is refused by kind.
        std::fs::write(&mpath, "{\"kind\":\"something-else\",\"files\":{}}").unwrap();
        let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
        assert!(err.contains("kind"), "{err}");
    }
}
