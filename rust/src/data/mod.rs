//! Dataset generators and IO.
//!
//! The paper evaluates on (a) the *Euler Isometric Swiss Roll* [Schoeneman
//! et al., SDM 2017] sampled at n = 50k/75k/100k and (b) random samples of
//! EMNIST (28×28 handwritten digits, D = 784). EMNIST images are not
//! available in this offline environment, so [`emnist_synth`] renders
//! synthetic stroke-based digits with controlled slant/curvature factors —
//! the same dimensionality and the same qualitative structure Fig. 5 of the
//! paper reads off (see DESIGN.md §5 substitutions).

pub mod clusters;
pub mod emnist_synth;
pub mod io;
pub mod swiss_roll;

use crate::linalg::Matrix;

/// A dataset: `n × D` points, optional integer labels, and (for synthetic
/// manifolds) the ground-truth low-dimensional coordinates used to compute
/// Procrustes error.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// High-dimensional points, one per row.
    pub points: Matrix,
    /// Class labels (e.g. digit identity), when meaningful.
    pub labels: Option<Vec<usize>>,
    /// Ground-truth latent coordinates, when known.
    pub ground_truth: Option<Matrix>,
    /// Human-readable name used in reports.
    pub name: String,
}

impl Dataset {
    /// Number of points.
    pub fn n(&self) -> usize {
        self.points.nrows()
    }

    /// Ambient dimensionality `D`.
    pub fn dim(&self) -> usize {
        self.points.ncols()
    }
}

/// Named dataset presets mirroring the paper's benchmarks (at laptop scale
/// `n` is a parameter; the paper's n=50k+ sizes are reached through the
/// calibrated simulator, see `sim`).
pub fn by_name(name: &str, n: usize, seed: u64) -> Option<Dataset> {
    match name {
        "swiss" | "swiss_roll" => Some(swiss_roll::euler_isometric(n, seed)),
        "emnist" | "emnist_synth" => Some(emnist_synth::generate(n, seed)),
        "clusters" => Some(clusters::gaussian_clusters(n, 16, 8, 0.3, seed)),
        "s_curve" => Some(swiss_roll::s_curve(n, seed)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist() {
        for name in ["swiss", "emnist", "clusters", "s_curve"] {
            let d = by_name(name, 64, 1).unwrap();
            assert_eq!(d.n(), 64, "{name}");
            assert!(d.dim() >= 3, "{name}");
        }
        assert!(by_name("nope", 10, 1).is_none());
    }
}
