//! Synthetic EMNIST-like digit images.
//!
//! The paper's high-dimensional benchmark is EMNIST (28×28 handwritten
//! digits, D = 784). Real EMNIST is not available offline, so this module
//! renders digits from vector stroke templates with three controlled latent
//! factors — *slant* (shear), *stroke thickness*, and per-point jitter —
//! mirroring the factors the paper's Fig. 5 reads off its embedding (D2 =
//! slant angle, D1 = curved vs. straight strokes). The substitution keeps
//! D = 784 and the same kNN-dominated code path.

use super::Dataset;
use crate::linalg::Matrix;
use crate::util::Rng;

const SIDE: usize = 28;
/// Ambient dimensionality, 28×28 pixels.
pub const DIM: usize = SIDE * SIDE;

/// A stroke is a polyline in the unit square (y grows downward).
type Stroke = Vec<(f64, f64)>;

/// Approximate an arc by a polyline.
fn arc(cx: f64, cy: f64, r: f64, a0: f64, a1: f64, segs: usize) -> Stroke {
    (0..=segs)
        .map(|i| {
            let a = a0 + (a1 - a0) * i as f64 / segs as f64;
            (cx + r * a.cos(), cy + r * a.sin())
        })
        .collect()
}

/// Vector templates for digits 0–9 (hand-authored, loosely following
/// seven-segment-plus-curves shapes).
fn template(digit: usize) -> Vec<Stroke> {
    use std::f64::consts::PI;
    match digit {
        0 => vec![arc(0.5, 0.5, 0.32, 0.0, 2.0 * PI, 32)],
        1 => vec![vec![(0.35, 0.25), (0.55, 0.12), (0.55, 0.88)]],
        2 => vec![
            arc(0.5, 0.32, 0.22, -PI, 0.1, 16),
            vec![(0.70, 0.38), (0.30, 0.85)],
            vec![(0.30, 0.85), (0.75, 0.85)],
        ],
        3 => vec![
            arc(0.48, 0.32, 0.20, -PI * 0.9, PI * 0.45, 16),
            arc(0.48, 0.68, 0.22, -PI * 0.45, PI * 0.9, 16),
        ],
        4 => vec![
            vec![(0.60, 0.12), (0.25, 0.60), (0.78, 0.60)],
            vec![(0.60, 0.12), (0.60, 0.88)],
        ],
        5 => vec![
            vec![(0.72, 0.14), (0.34, 0.14), (0.32, 0.45)],
            arc(0.50, 0.64, 0.22, -PI * 0.55, PI * 0.75, 18),
        ],
        6 => vec![
            vec![(0.62, 0.12), (0.38, 0.45)],
            arc(0.50, 0.65, 0.21, 0.0, 2.0 * PI, 28),
        ],
        7 => vec![vec![(0.26, 0.14), (0.76, 0.14), (0.42, 0.88)]],
        8 => vec![
            arc(0.50, 0.32, 0.17, 0.0, 2.0 * PI, 24),
            arc(0.50, 0.68, 0.21, 0.0, 2.0 * PI, 24),
        ],
        9 => vec![
            arc(0.50, 0.35, 0.21, 0.0, 2.0 * PI, 28),
            vec![(0.68, 0.42), (0.55, 0.88)],
        ],
        _ => unreachable!("digit out of range"),
    }
}

/// Curvature score of a template: fraction of ink on arc strokes. Drives
/// the "curved vs. straight" factor the paper observes along D1.
pub fn curvature_score(digit: usize) -> f64 {
    // 1 and 4 and 7 are all straight lines; 0, 8 all curves.
    match digit {
        0 => 1.0,
        1 => 0.0,
        2 => 0.55,
        3 => 0.95,
        4 => 0.0,
        5 => 0.6,
        6 => 0.85,
        7 => 0.0,
        8 => 1.0,
        9 => 0.8,
        _ => 0.5,
    }
}

/// Squared distance from point `p` to segment `(a, b)`.
fn seg_dist2(p: (f64, f64), a: (f64, f64), b: (f64, f64)) -> f64 {
    let (px, py) = p;
    let (ax, ay) = a;
    let (bx, by) = b;
    let (dx, dy) = (bx - ax, by - ay);
    let len2 = dx * dx + dy * dy;
    let t = if len2 == 0.0 {
        0.0
    } else {
        (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0)
    };
    let (qx, qy) = (ax + t * dx, ay + t * dy);
    (px - qx) * (px - qx) + (py - qy) * (py - qy)
}

/// Render one digit with the given latent factors into a 784-vector.
///
/// * `slant` — shear factor in [-0.35, 0.35]; positive leans right.
/// * `thickness` — stroke radius in unit-square coordinates.
/// * `jitter` — per-vertex Gaussian noise.
pub fn render(digit: usize, slant: f64, thickness: f64, jitter: f64, rng: &mut Rng) -> Vec<f64> {
    let mut strokes = template(digit);
    for s in &mut strokes {
        for p in s.iter_mut() {
            // Shear around the vertical center: x += slant * (0.5 - y).
            p.0 += slant * (0.5 - p.1);
            p.0 += rng.normal(0.0, jitter);
            p.1 += rng.normal(0.0, jitter);
        }
    }
    let mut img = vec![0.0f64; DIM];
    let inv = 1.0 / (SIDE as f64);
    for py in 0..SIDE {
        for px in 0..SIDE {
            let p = ((px as f64 + 0.5) * inv, (py as f64 + 0.5) * inv);
            let mut d2 = f64::INFINITY;
            for s in &strokes {
                for w in s.windows(2) {
                    d2 = d2.min(seg_dist2(p, w[0], w[1]));
                }
            }
            // Soft pen: intensity falls off as a Gaussian of distance.
            let sigma = thickness;
            let v = (-d2 / (2.0 * sigma * sigma)).exp();
            img[py * SIDE + px] = if v > 0.02 { v } else { 0.0 };
        }
    }
    img
}

/// Maximum of the per-sample legibility morph factor (see [`generate`]).
const MAX_MORPH: f64 = 0.9;

/// Generate `n` synthetic EMNIST-like points with labels and the latent
/// `(curvature, slant)` factors as ground truth.
///
/// Real handwriting contains ambiguous, barely legible samples that
/// connect the digit classes into one manifold (the paper's EMNIST kNN
/// graph is a single component at k = 10). Clean stroke renderings lack
/// those bridges, so each sample is additionally blended toward a common
/// heavy-stroke blob by a squared-uniform *legibility* factor
/// (`morph = u²·0.9`, mostly near 0): low-legibility samples of all
/// classes approach one another, restoring single-component connectivity
/// at the paper's k — the same role messy handwriting plays in EMNIST.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed(seed);
    // The common "illegible" blob: mean of all digits at maximum pen width.
    let mut blob = vec![0.0f64; DIM];
    for d in 0..10 {
        let img = render(d, 0.0, 0.12, 0.0, &mut rng);
        for (b, v) in blob.iter_mut().zip(&img) {
            *b += v / 10.0;
        }
    }
    let mut points = Matrix::zeros(n, DIM);
    let mut labels = Vec::with_capacity(n);
    let mut truth = Matrix::zeros(n, 2);
    for i in 0..n {
        let digit = rng.below(10);
        let slant = rng.range(-0.30, 0.30);
        let thickness = rng.range(0.035, 0.055);
        let mut img = render(digit, slant, thickness, 0.008, &mut rng);
        let morph = rng.f64().powi(2) * MAX_MORPH;
        for (v, b) in img.iter_mut().zip(&blob) {
            *v = (1.0 - morph) * *v + morph * b;
        }
        points.row_mut(i).copy_from_slice(&img);
        labels.push(digit);
        truth[(i, 0)] = curvature_score(digit);
        truth[(i, 1)] = slant;
    }
    Dataset {
        points,
        labels: Some(labels),
        ground_truth: Some(truth),
        name: format!("emnist{n}"),
    }
}

/// ASCII-art rendering of one row (used by the example binaries to show
/// sample digits like the paper's Fig. 5 insets).
pub fn ascii(img: &[f64]) -> String {
    let shades = [' ', '.', ':', 'o', 'O', '#'];
    let mut out = String::new();
    for y in 0..SIDE {
        for x in 0..SIDE {
            let v = img[y * SIDE + x].clamp(0.0, 1.0);
            let idx = ((v * (shades.len() - 1) as f64).round()) as usize;
            out.push(shades[idx]);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let d = generate(40, 1);
        assert_eq!(d.dim(), 784);
        assert_eq!(d.labels.as_ref().unwrap().len(), 40);
        assert!(d.labels.unwrap().iter().all(|&l| l < 10));
    }

    #[test]
    fn images_have_ink_and_background() {
        let mut rng = Rng::seed(2);
        for digit in 0..10 {
            let img = render(digit, 0.0, 0.045, 0.0, &mut rng);
            let ink: f64 = img.iter().sum();
            let zeros = img.iter().filter(|&&v| v == 0.0).count();
            assert!(ink > 5.0, "digit {digit} has no ink");
            assert!(zeros > 300, "digit {digit} has no background");
        }
    }

    #[test]
    fn same_digit_same_factors_close_different_digits_far() {
        let mut rng = Rng::seed(3);
        let a = render(0, 0.1, 0.045, 0.0, &mut rng);
        let b = render(0, 0.12, 0.045, 0.0, &mut rng);
        let c = render(1, 0.1, 0.045, 0.0, &mut rng);
        let d2 = |x: &[f64], y: &[f64]| -> f64 {
            x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        assert!(d2(&a, &b) < d2(&a, &c), "intra-class should beat inter-class");
    }

    #[test]
    fn slant_moves_pixels() {
        let mut rng = Rng::seed(4);
        let left = render(1, -0.3, 0.045, 0.0, &mut rng);
        let right = render(1, 0.3, 0.045, 0.0, &mut rng);
        // Center of ink mass along x should shift between strong slants
        // (top leans opposite ways).
        let com_top = |img: &[f64]| -> f64 {
            let mut m = 0.0;
            let mut s = 0.0;
            for y in 0..10 {
                for x in 0..SIDE {
                    m += img[y * SIDE + x] * x as f64;
                    s += img[y * SIDE + x];
                }
            }
            m / s
        };
        // Positive slant leans the glyph right: the top of the stroke
        // shifts toward larger x (x += slant·(0.5 − y), positive at top).
        assert!(com_top(&right) > com_top(&left));
    }

    #[test]
    fn deterministic() {
        let a = generate(10, 7);
        let b = generate(10, 7);
        assert_eq!(a.points.as_slice(), b.points.as_slice());
    }

    #[test]
    fn ascii_renders() {
        let mut rng = Rng::seed(5);
        let img = render(8, 0.0, 0.05, 0.0, &mut rng);
        let art = ascii(&img);
        assert_eq!(art.lines().count(), 28);
        assert!(art.contains('#'));
    }
}
