//! Lightweight matrix IO: CSV (for embeddings/reports consumed by plotting
//! tools) and a raw little-endian f64 binary format for fast round-trips.

use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Read, Write};
use std::path::Path;

/// Write a matrix as CSV with an optional header row.
pub fn write_csv(path: &Path, m: &Matrix, header: Option<&[&str]>) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    if let Some(h) = header {
        writeln!(w, "{}", h.join(","))?;
    }
    for i in 0..m.nrows() {
        let row: Vec<String> = m.row(i).iter().map(|x| format!("{x}")).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a CSV of floats; `skip_header` drops the first line.
pub fn read_csv(path: &Path, skip_header: bool) -> Result<Matrix> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let reader = std::io::BufReader::new(f);
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if lineno == 0 && skip_header {
            continue;
        }
        if line.trim().is_empty() {
            continue;
        }
        let row: Result<Vec<f64>, _> = line.split(',').map(|c| c.trim().parse::<f64>()).collect();
        let row = row.with_context(|| format!("{path:?}:{} bad float", lineno + 1))?;
        if let Some(first) = rows.first() {
            if first.len() != row.len() {
                bail!("{path:?}:{} ragged row", lineno + 1);
            }
        }
        rows.push(row);
    }
    if rows.is_empty() {
        bail!("{path:?}: empty CSV");
    }
    Ok(Matrix::from_rows(&rows))
}

/// Binary format: magic, u64 rows, u64 cols, then rows*cols little-endian f64.
const MAGIC: &[u8; 8] = b"ISOSPK01";

/// Exact on-disk size of the binary format for a `rows × cols` matrix
/// (magic + two u64 dims + payload). Kept next to the format so other
/// modules (e.g. the model-artifact inspector) never hardcode the layout.
/// `None` when the dims are so large the size overflows u64 — dims read
/// from untrusted headers must not panic the checked-arithmetic debug
/// build (or silently wrap in release).
pub fn bin_file_size(rows: usize, cols: usize) -> Option<u64> {
    (rows as u64)
        .checked_mul(cols as u64)?
        .checked_mul(8)?
        .checked_add(MAGIC.len() as u64 + 16)
}

/// Encode a matrix in the crate's binary layout (magic + u64 dims + f64
/// little-endian payload) — byte-for-byte what [`write_bin`] puts on disk,
/// appended to `out`. The distribution layer reuses this encoding as the
/// block-shuffle frame payload, so a matrix that crossed the wire and one
/// that round-tripped through disk are the same bytes.
pub fn matrix_to_bytes(m: &Matrix, out: &mut Vec<u8>) {
    out.reserve(MAGIC.len() + 16 + m.as_slice().len() * 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(m.nrows() as u64).to_le_bytes());
    out.extend_from_slice(&(m.ncols() as u64).to_le_bytes());
    for x in m.as_slice() {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Decode a matrix from the [`matrix_to_bytes`] layout at the start of
/// `buf`; returns the matrix and the number of bytes consumed (trailing
/// bytes are the caller's business — payloads may concatenate fields).
/// Bit-exact: `f64::to_le_bytes`/`from_le_bytes` round-trip every value
/// including `-0.0`, `±∞`, and NaN payloads.
pub fn matrix_from_bytes(buf: &[u8]) -> Result<(Matrix, usize)> {
    if buf.len() < MAGIC.len() + 16 || &buf[..MAGIC.len()] != MAGIC {
        bail!("matrix bytes: bad magic");
    }
    let rows = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    let cols = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
    let need = bin_file_size(rows, cols)
        .ok_or_else(|| anyhow::anyhow!("matrix bytes: insane dims {rows}×{cols} in header"))?;
    if (buf.len() as u64) < need {
        bail!("matrix bytes: truncated ({} < {need})", buf.len());
    }
    let need = need as usize;
    let data: Vec<f64> = buf[24..need]
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((Matrix::from_vec(rows, cols, data), need))
}

/// Write the raw binary matrix format.
pub fn write_bin(path: &Path, m: &Matrix) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(m.nrows() as u64).to_le_bytes())?;
    w.write_all(&(m.ncols() as u64).to_le_bytes())?;
    for x in m.as_slice() {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// FNV-1a 64-bit over a byte slice — the crate's cheap, dependency-free
/// corruption check (integrity against truncation/bit-rot, not
/// cryptography). Shared by the model artifact manifest and the durable
/// checkpoint store so both speak the same checksum.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a64::new();
    h.update(bytes);
    h.finish()
}

/// Incremental FNV-1a 64-bit, for hashing data that is not contiguous in
/// memory (e.g. the durable checkpoint job fingerprints over block maps).
/// `update`-ing in pieces is bit-identical to [`fnv1a64`] over the
/// concatenation.
pub(crate) struct Fnv1a64(u64);

impl Fnv1a64 {
    pub(crate) fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    pub(crate) fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub(crate) fn finish(&self) -> u64 {
        self.0
    }
}

/// FNV-1a 64-bit over a whole file.
pub(crate) fn file_fnv1a64(path: &Path) -> Result<u64> {
    let bytes = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
    Ok(fnv1a64(&bytes))
}

/// Read the raw binary matrix format.
pub fn read_bin(path: &Path) -> Result<Matrix> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    if buf.len() < 24 || &buf[..8] != MAGIC {
        bail!("{path:?}: bad magic");
    }
    let rows = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    let cols = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
    let need = bin_file_size(rows, cols)
        .ok_or_else(|| anyhow::anyhow!("{path:?}: insane dims {rows}×{cols} in header"))?;
    if buf.len() as u64 != need {
        bail!("{path:?}: truncated ({} != {need})", buf.len());
    }
    let data: Vec<f64> = buf[24..]
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Matrix::from_vec(rows, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("isospark_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn csv_roundtrip() {
        let m = Matrix::from_rows(&[vec![1.5, -2.0], vec![0.25, 1e-3]]);
        let p = tmp("a.csv");
        write_csv(&p, &m, Some(&["x", "y"])).unwrap();
        let r = read_csv(&p, true).unwrap();
        assert!(r.max_abs_diff(&m) < 1e-15);
    }

    #[test]
    fn csv_no_header() {
        let m = Matrix::from_rows(&[vec![1.0], vec![2.0]]);
        let p = tmp("b.csv");
        write_csv(&p, &m, None).unwrap();
        let r = read_csv(&p, false).unwrap();
        assert_eq!(r.nrows(), 2);
    }

    #[test]
    fn bin_roundtrip_exact() {
        let m = Matrix::from_rows(&[vec![std::f64::consts::PI, f64::MIN_POSITIVE], vec![-0.0, 1e308]]);
        let p = tmp("c.bin");
        write_bin(&p, &m).unwrap();
        let r = read_bin(&p).unwrap();
        assert_eq!(r.as_slice(), m.as_slice());
    }

    #[test]
    fn bytes_codec_matches_disk_format_bit_for_bit() {
        let m = Matrix::from_rows(&[vec![std::f64::consts::E, -0.0], vec![f64::INFINITY, 1e-308]]);
        let p = tmp("bytes.bin");
        write_bin(&p, &m).unwrap();
        let mut wire = Vec::new();
        matrix_to_bytes(&m, &mut wire);
        assert_eq!(wire, std::fs::read(&p).unwrap());
        // Trailing bytes after the matrix are left for the caller.
        wire.extend_from_slice(&[0xAB; 5]);
        let (r, used) = matrix_from_bytes(&wire).unwrap();
        assert_eq!(used, wire.len() - 5);
        let (rb, mb): (Vec<u64>, Vec<u64>) = (
            r.as_slice().iter().map(|v| v.to_bits()).collect(),
            m.as_slice().iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(rb, mb);
    }

    #[test]
    fn bytes_codec_rejects_garbage() {
        assert!(matrix_from_bytes(b"short").is_err());
        let mut bad = Vec::new();
        matrix_to_bytes(&Matrix::zeros(2, 2), &mut bad);
        let err = format!("{:#}", matrix_from_bytes(&bad[..30]).unwrap_err());
        assert!(err.contains("truncated"), "{err}");
        bad[0] = b'X';
        assert!(matrix_from_bytes(&bad).is_err());
    }

    #[test]
    fn bin_file_size_matches_writer() {
        let m = Matrix::zeros(3, 5);
        let p = tmp("size.bin");
        write_bin(&p, &m).unwrap();
        assert_eq!(std::fs::metadata(&p).unwrap().len(), bin_file_size(3, 5).unwrap());
        assert_eq!(bin_file_size(usize::MAX, usize::MAX), None);
    }

    #[test]
    fn bin_rejects_overflowing_header_dims() {
        let p = tmp("overflow.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
        bytes.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", read_bin(&p).unwrap_err());
        assert!(err.contains("insane dims"), "{err}");
    }

    #[test]
    fn bin_rejects_corrupt() {
        let p = tmp("d.bin");
        std::fs::write(&p, b"NOTMAGIC123").unwrap();
        assert!(read_bin(&p).is_err());
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmp("e.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_csv(&p, false).is_err());
    }
}
