//! Gaussian cluster generator — a generic high-dimensional workload used by
//! engine/scalability benchmarks where manifold structure is irrelevant.

use super::Dataset;
use crate::linalg::Matrix;
use crate::util::Rng;

/// `n` points split evenly across `c` spherical Gaussian clusters in `R^dim`
/// with the given per-axis standard deviation. Cluster centers are drawn
/// uniformly from the unit hypercube scaled by 4.
pub fn gaussian_clusters(n: usize, dim: usize, c: usize, std: f64, seed: u64) -> Dataset {
    assert!(c >= 1 && dim >= 1);
    let mut rng = Rng::seed(seed);
    let centers: Vec<Vec<f64>> = (0..c)
        .map(|_| (0..dim).map(|_| rng.range(0.0, 4.0)).collect())
        .collect();
    let mut points = Matrix::zeros(n, dim);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let k = i % c;
        for j in 0..dim {
            points[(i, j)] = centers[k][j] + rng.normal(0.0, std);
        }
        labels.push(k);
    }
    Dataset { points, labels: Some(labels), ground_truth: None, name: format!("clusters{n}") }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let d = gaussian_clusters(30, 5, 3, 0.1, 1);
        assert_eq!(d.n(), 30);
        assert_eq!(d.dim(), 5);
        assert_eq!(d.labels.as_ref().unwrap().len(), 30);
    }

    #[test]
    fn clusters_are_tight() {
        let d = gaussian_clusters(300, 8, 3, 0.05, 2);
        let labels = d.labels.unwrap();
        // Mean intra-cluster distance should be far below inter-cluster.
        let dist = |a: usize, b: usize| -> f64 {
            d.points
                .row(a)
                .iter()
                .zip(d.points.row(b))
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f64>()
                .sqrt()
        };
        let (mut intra, mut ni) = (0.0, 0);
        let (mut inter, mut nx) = (0.0, 0);
        for a in 0..100 {
            for b in (a + 1)..100 {
                if labels[a] == labels[b] {
                    intra += dist(a, b);
                    ni += 1;
                } else {
                    inter += dist(a, b);
                    nx += 1;
                }
            }
        }
        assert!(intra / ni as f64 * 3.0 < inter / nx as f64);
    }
}
