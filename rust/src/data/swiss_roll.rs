//! Isometric swiss-roll and S-curve generators.
//!
//! The paper uses the *Euler Isometric Swiss Roll* (Schoeneman et al., SDM
//! 2017) — a clothoid-based roll whose unit-speed parametrization makes the
//! 3-D embedding isometric to the latent rectangle, so Isomap's output can
//! be scored with Procrustes error against ground truth.
//!
//! A pure clothoid, however, winds into its asymptotic point with
//! vanishing coil separation: at laptop-scale n (10²–10³ points vs the
//! paper's 5·10⁴) the kNN graph inevitably short-circuits adjacent coils
//! and *no* exact Isomap can recover the latent rectangle. We therefore
//! generate the default benchmark as an **arc-length-parameterized
//! Archimedean roll** — also exactly isometric (unit-speed by
//! construction) but with *constant* coil separation `2πa`, which keeps
//! the benchmark solvable at any density (DESIGN.md §5 documents this
//! substitution). The clothoid variant remains available as
//! [`clothoid_roll`] for stress-testing shortcut behavior.

use super::Dataset;
use crate::linalg::Matrix;
use crate::util::Rng;

/// Archimedean spiral coefficient: `r = SPIRAL_A · θ`; coil gap `2π·a`.
///
/// Sized so the coil gap (≈3.77) clears the *corner-point* kNN radius: at
/// a domain corner only a quarter-disk of neighbors exists, so the k-NN
/// radius doubles vs the interior (≈2.6 at n=600, k=10) — with a smaller
/// `a` (0.35) unlucky seeds produced a single corner shortcut edge that
/// corrupted every geodesic through it (observed before fixing: Procrustes
/// 0.54 instead of 2e-3, in the *dense reference* pipeline too).
const SPIRAL_A: f64 = 0.6;
/// Angular range of the roll. Starting at 2π keeps the innermost coil's
/// radius (aθ ≈ 2.2) no smaller than the coil gap (2πa ≈ 2.2), so sparse
/// sampling cannot produce shortcut edges across the tight inner turns
/// (observed at n=600, k=10 with the classic 1.5π start).
const THETA_MIN: f64 = 2.0 * std::f64::consts::PI;
const THETA_MAX: f64 = 5.0 * std::f64::consts::PI;
/// Roll height.
const HEIGHT: f64 = 6.0;

/// Arc length of `r = aθ` from 0 to θ: `(a/2)(θ√(1+θ²) + asinh θ)`.
fn arc_len(theta: f64) -> f64 {
    (SPIRAL_A / 2.0) * (theta * (1.0 + theta * theta).sqrt() + theta.asinh())
}

/// Invert [`arc_len`] by bisection (monotone).
fn theta_of_arc(s: f64) -> f64 {
    let (mut lo, mut hi) = (0.0f64, THETA_MAX * 1.5);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if arc_len(mid) < s {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Latent arc-length range corresponding to `θ ∈ [THETA_MIN, THETA_MAX]`.
pub fn latent_range() -> (f64, f64) {
    (arc_len(THETA_MIN), arc_len(THETA_MAX))
}

/// Sample `n` points from the isometric swiss roll.
///
/// Latent coordinates are `(s, h)` with `s` uniform over the spiral's
/// arc-length window and `h` uniform over the height; the embedding is
/// `(r cos θ, h, r sin θ)` with `θ = θ(s)`. Unit-speed parametrization
/// makes geodesic distance on the roll equal Euclidean distance in the
/// `(s, h)` rectangle.
pub fn euler_isometric(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed(seed);
    let (s0, s1) = latent_range();
    let mut points = Matrix::zeros(n, 3);
    let mut truth = Matrix::zeros(n, 2);
    for i in 0..n {
        let s = rng.range(s0, s1);
        let h = rng.range(0.0, HEIGHT);
        let theta = theta_of_arc(s);
        let r = SPIRAL_A * theta;
        points[(i, 0)] = r * theta.cos();
        points[(i, 1)] = h;
        points[(i, 2)] = r * theta.sin();
        truth[(i, 0)] = s;
        truth[(i, 1)] = h;
    }
    Dataset {
        points,
        labels: None,
        ground_truth: Some(truth),
        name: format!("swiss{n}"),
    }
}

/// Fresnel-style integrals by Simpson accumulation:
/// `(∫₀ᵗ cos(s²) ds, ∫₀ᵗ sin(s²) ds)`.
fn euler_spiral(t: f64) -> (f64, f64) {
    let steps_per_unit = 2048.0;
    let n = ((t * steps_per_unit).ceil() as usize).max(2);
    let n = n + n % 2;
    let h = t / n as f64;
    let f_cos = |s: f64| (s * s).cos();
    let f_sin = |s: f64| (s * s).sin();
    let mut c = f_cos(0.0) + f_cos(t);
    let mut s = f_sin(0.0) + f_sin(t);
    for i in 1..n {
        let x = i as f64 * h;
        let w = if i % 2 == 1 { 4.0 } else { 2.0 };
        c += w * f_cos(x);
        s += w * f_sin(x);
    }
    (c * h / 3.0, s * h / 3.0)
}

/// The literal Euler-spiral (clothoid) roll of Schoeneman et al.:
/// `ρ·(C(u/ρ), S(u/ρ))` with latent `u ∈ [0, t_max]` — exactly isometric
/// but with curvature growing linearly along the roll, so its tail coils
/// into the asymptotic point. Useful for studying shortcut-edge failure
/// modes; requires very dense sampling for faithful recovery.
pub fn clothoid_roll(n: usize, t_max: f64, rho: f64, seed: u64) -> Dataset {
    let mut rng = Rng::seed(seed);
    let mut points = Matrix::zeros(n, 3);
    let mut truth = Matrix::zeros(n, 2);
    for i in 0..n {
        let t = rng.range(0.0, t_max);
        let h = rng.range(0.0, HEIGHT);
        let (x, y) = euler_spiral(t / rho);
        points[(i, 0)] = rho * x;
        points[(i, 1)] = rho * y;
        points[(i, 2)] = h;
        truth[(i, 0)] = t;
        truth[(i, 1)] = h;
    }
    Dataset { points, labels: None, ground_truth: Some(truth), name: format!("clothoid{n}") }
}

/// Classic S-curve manifold (second synthetic benchmark).
pub fn s_curve(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed(seed);
    let mut points = Matrix::zeros(n, 3);
    let mut truth = Matrix::zeros(n, 2);
    for i in 0..n {
        let t = rng.range(-1.5 * std::f64::consts::PI, 1.5 * std::f64::consts::PI);
        let h = rng.range(0.0, 2.0);
        points[(i, 0)] = t.sin();
        points[(i, 1)] = h;
        points[(i, 2)] = t.signum() * (t.cos() - 1.0);
        truth[(i, 0)] = t;
        truth[(i, 1)] = h;
    }
    Dataset { points, labels: None, ground_truth: Some(truth), name: format!("scurve{n}") }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arc_length_inversion() {
        for theta in [2.0, 5.0, 10.0, 14.0] {
            let s = arc_len(theta);
            let got = theta_of_arc(s);
            assert!((got - theta).abs() < 1e-9, "theta={theta} got={got}");
        }
    }

    #[test]
    fn roll_is_unit_speed() {
        // Nearby latent points differ in 3-D by their latent distance.
        let (s0, s1) = latent_range();
        let ds = 1e-5;
        for f in [0.1, 0.5, 0.9] {
            let s = s0 + f * (s1 - s0);
            let p = |s: f64| {
                let th = theta_of_arc(s);
                let r = SPIRAL_A * th;
                (r * th.cos(), r * th.sin())
            };
            let (x0, y0) = p(s);
            let (x1, y1) = p(s + ds);
            let d = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt();
            assert!((d - ds).abs() < 1e-7 * ds.max(1.0), "at s={s}: d={d}");
        }
    }

    #[test]
    fn coil_gap_constant() {
        // Adjacent windings are separated by ~2πa everywhere.
        let gap = 2.0 * std::f64::consts::PI * SPIRAL_A;
        for theta in [2.0 * std::f64::consts::PI, 3.0 * std::f64::consts::PI] {
            let r1 = SPIRAL_A * theta;
            let r2 = SPIRAL_A * (theta + 2.0 * std::f64::consts::PI);
            assert!((r2 - r1 - gap).abs() < 1e-12);
        }
        // Gap comfortably exceeds typical kNN distances at n≈500.
        assert!(gap > 1.5);
    }

    #[test]
    fn spiral_matches_series_small_t() {
        // For small t: C(t) ≈ t − t⁵/10, S(t) ≈ t³/3 − t⁷/42.
        let t = 0.3;
        let (c, s) = euler_spiral(t);
        let c_ref = t - t.powi(5) / 10.0 + t.powi(9) / 216.0;
        let s_ref = t.powi(3) / 3.0 - t.powi(7) / 42.0;
        assert!((c - c_ref).abs() < 1e-8, "C={c} ref={c_ref}");
        assert!((s - s_ref).abs() < 1e-8, "S={s} ref={s_ref}");
    }

    #[test]
    fn clothoid_is_unit_speed() {
        let (t0, dt, rho) = (7.0, 1e-4, 4.0);
        let (x0, y0) = euler_spiral(t0 / rho);
        let (x1, y1) = euler_spiral((t0 + dt) / rho);
        let ds = (rho * rho * ((x1 - x0).powi(2) + (y1 - y0).powi(2))).sqrt();
        assert!((ds - dt).abs() < 1e-8, "ds={ds} dt={dt}");
    }

    #[test]
    fn dataset_shapes_and_determinism() {
        let a = euler_isometric(100, 9);
        let b = euler_isometric(100, 9);
        assert_eq!(a.points.as_slice(), b.points.as_slice());
        assert_eq!(a.points.ncols(), 3);
        assert_eq!(a.ground_truth.as_ref().unwrap().ncols(), 2);
        let c = euler_isometric(100, 10);
        assert_ne!(a.points.as_slice(), c.points.as_slice());
        let cl = clothoid_roll(50, 12.0, 4.0, 3);
        assert_eq!(cl.points.nrows(), 50);
    }

    #[test]
    fn latent_in_range() {
        let d = euler_isometric(500, 3);
        let (s0, s1) = latent_range();
        let t = d.ground_truth.unwrap();
        for i in 0..500 {
            assert!(t[(i, 0)] >= s0 && t[(i, 0)] <= s1);
            assert!((0.0..=HEIGHT).contains(&t[(i, 1)]));
        }
    }

    #[test]
    fn s_curve_shapes() {
        let d = s_curve(64, 4);
        assert_eq!(d.dim(), 3);
        assert_eq!(d.n(), 64);
    }
}
