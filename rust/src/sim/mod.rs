//! Paper-scale projection: calibrated analytic cost model + the same
//! virtual-cluster scheduling/network semantics as the engine.
//!
//! The real engine executes every block (bit-exact results) and is
//! practical here up to n ≈ 3k on one core; the paper's Tables I–III run
//! n = 50k–125k on 2–24 nodes. This module regenerates those tables by
//! (1) calibrating per-kernel cost coefficients from measured runs of the
//! *actual* kernels, then (2) replaying the pipeline's exact task/shuffle
//! structure (same `q`-length critical path, same three APSP phases, same
//! replication factors) onto the engine's [`VirtualClock`] and
//! [`NetworkModel`]. `validate_against_engine` (integration tests) checks
//! the projection against real engine runs at small n.

use crate::config::ClusterConfig;
use crate::engine::clock::{Task, VirtualClock};
use crate::engine::network::{NetworkModel, Traffic};
use crate::engine::partitioner::{ut_count, Partitioner, UpperTriangularPartitioner};
use crate::engine::BlockId;
use crate::kernels;
use crate::linalg::Matrix;
use crate::util::{Rng, Stopwatch};

/// Seconds-per-unit coefficients for each kernel, fitted from real runs.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// dist block: seconds per `b·b·D` multiply-add.
    pub dist: f64,
    /// min-plus product: seconds per `b³` compare-add.
    pub minplus: f64,
    /// in-block Floyd–Warshall: seconds per `b³`.
    pub fw: f64,
    /// heap top-k: seconds per scanned element.
    pub topk: f64,
    /// centering apply: seconds per element.
    pub center: f64,
    /// gemm: seconds per `b·b·d` multiply-add.
    pub gemm: f64,
}

impl CostModel {
    /// A stylized model of the paper's MKL-backed testbed, used when
    /// calibration is too slow (docs/tests): ~2 GFLOP/s effective for
    /// BLAS-like ops, slower for the semiring ops Numba compiles.
    pub fn paper_like() -> Self {
        Self {
            dist: 0.5e-9,
            minplus: 1.2e-9,
            fw: 1.5e-9,
            topk: 2.0e-9,
            center: 1.0e-9,
            gemm: 0.5e-9,
        }
    }

    /// Fit coefficients by timing the native kernels at block size `b`.
    pub fn calibrate(b: usize) -> Self {
        let mut rng = Rng::seed(7);
        let mut mk = |r: usize, c: usize| {
            let mut m = Matrix::zeros(r, c);
            for i in 0..r {
                for j in 0..c {
                    m[(i, j)] = rng.range(0.1, 10.0);
                }
            }
            m
        };
        let reps = 3;

        let xd = mk(b, 16);
        let sw = Stopwatch::start();
        for _ in 0..reps {
            std::hint::black_box(kernels::sqdist::dist_block(&xd, &xd));
        }
        let dist = sw.secs() / (reps * b * b * 16) as f64;

        let a = mk(b, b);
        let bb = mk(b, b);
        let mut dst = Matrix::full(b, b, f64::INFINITY);
        let sw = Stopwatch::start();
        for _ in 0..reps {
            kernels::minplus::minplus_into(&a, &bb, &mut dst);
        }
        let minplus = sw.secs() / (reps * b * b * b) as f64;

        let mut g = mk(b, b);
        let sw = Stopwatch::start();
        for _ in 0..reps {
            kernels::floyd_warshall::floyd_warshall_inplace(&mut g);
        }
        let fw = sw.secs() / (reps * b * b * b) as f64;

        let row: Vec<f64> = (0..b * b).map(|i| (i % 977) as f64).collect();
        let sw = Stopwatch::start();
        for _ in 0..reps {
            std::hint::black_box(kernels::kselect::row_topk(&row, 10, 0, None));
        }
        let topk = sw.secs() / (reps * b * b) as f64;

        let mu = vec![1.0; b];
        let mut cblk = mk(b, b);
        let sw = Stopwatch::start();
        for _ in 0..reps {
            kernels::centering::center_block(&mut cblk, &mu, &mu, 0.5);
        }
        let center = sw.secs() / (reps * b * b) as f64;

        let q = mk(b, 8);
        let mut out = Matrix::zeros(b, 8);
        let sw = Stopwatch::start();
        for _ in 0..reps {
            kernels::matvec::gemm_acc(&a, &q, &mut out);
        }
        let gemm = sw.secs() / (reps * b * b * 8) as f64;

        Self { dist, minplus, fw, topk, center, gemm }
    }
}

/// Workload description for a projection.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub n: usize,
    /// Ambient dimensionality D (only kNN depends on it — paper §IV-B).
    pub dim: usize,
    pub d: usize,
    pub k: usize,
    pub b: usize,
    /// Power iterations to charge (paper: usually 20–50; default 30).
    pub eigen_iters: usize,
    /// APSP checkpoint cadence (paper: 10; 0 = never).
    pub checkpoint_every: usize,
}

impl Workload {
    pub fn new(name: &str, n: usize, dim: usize, b: usize) -> Self {
        Self { name: name.into(), n, dim, d: 2, k: 10, b, eigen_iters: 30, checkpoint_every: 10 }
    }

    /// The paper's five benchmark datasets (§IV-A) at a given block size.
    pub fn paper_suite(b: usize) -> Vec<Workload> {
        vec![
            Workload::new("EMNIST50", 50_000, 784, b),
            Workload::new("Swiss50", 50_000, 3, b),
            Workload::new("Swiss75", 75_000, 3, b),
            Workload::new("Swiss100", 100_000, 3, b),
            Workload::new("EMNIST125", 125_000, 784, b),
        ]
    }
}

/// Result of a projected run.
#[derive(Clone, Debug)]
pub struct Projection {
    /// `None` when the dataset does not fit in cluster memory — the "-"
    /// entries of Table I.
    pub total_secs: Option<f64>,
    pub knn_secs: f64,
    pub apsp_secs: f64,
    pub center_secs: f64,
    pub eigen_secs: f64,
    pub shuffle_bytes: u64,
    pub resident_bytes_per_node: u64,
}

/// Expected fraction of shuffle records that cross executor boundaries.
fn cross(nodes: usize) -> f64 {
    1.0 - 1.0 / nodes as f64
}

/// Project the full pipeline on a simulated cluster. Mirrors the stage
/// structure of `coordinator::{knn,apsp,centering,eigen}` one-to-one.
pub fn project(w: &Workload, cluster: &ClusterConfig, m: &CostModel) -> Projection {
    let n = w.n;
    let b = w.b;
    let q = n.div_ceil(b);
    let total_blocks = ut_count(q);
    let parts = total_blocks.min(cluster.total_cores().max(1));
    let part = UpperTriangularPartitioner::new(q, parts);
    let nodes = cluster.nodes;
    let net = NetworkModel::new(cluster);
    let mut clock = VirtualClock::new(nodes, cluster.cores_per_node);
    let xf = cross(nodes);
    let blk_bytes = (b * b * 8 + 16) as u64;

    // Memory model. The distance matrix M and graph G are co-resident
    // during the graph fill, each APSP iteration transiently holds the
    // phase-2/3 replicas (up to ~2 extra copies of G's blocks in shuffle
    // buffers), and the JVM + pickle representation carries ~1.5×
    // overhead: a 7× working-set factor over the raw upper-triangular
    // payload. This reproduces the paper's exact feasibility frontier
    // (Table I's `-` cells: Swiss75 needs ≥4 nodes, Swiss100 ≥8,
    // EMNIST125 ≥12 at 56 GB executors).
    const WORKING_SET_FACTOR: f64 = 7.5;
    let g_bytes = total_blocks as u64 * blk_bytes;
    let resident_bytes_per_node =
        (g_bytes as f64 * WORKING_SET_FACTOR / nodes as f64) as u64;
    let feasible = resident_bytes_per_node <= cluster.mem_per_node;

    // Spill/GC pressure: when the working set approaches executor memory,
    // Spark spills shuffle blocks and GC churns; compute slows down
    // super-linearly. This is what makes the paper's *relative* speedups
    // super-linear (their §IV-B caveat). Quadratic onset above 30%
    // utilization.
    let util = resident_bytes_per_node as f64 / cluster.mem_per_node as f64;
    let spill_mult = if util > 0.3 { 1.0 + 5.0 * ((util - 0.3) / 0.7).powi(2) } else { 1.0 };

    let node_of = |id: BlockId| -> usize { (part.partition(id) * nodes / parts.max(1)).min(nodes - 1) };
    let ut_blocks = || (0..q).flat_map(move |i| (i..q).map(move |j| BlockId::new(i, j)));

    // Helper to run a stage whose tasks are (block id, duration).
    let run = |clock: &mut VirtualClock, tasks: &[(BlockId, f64)]| -> f64 {
        let t: Vec<Task> =
            tasks.iter().map(|&(id, d)| Task { node: node_of(id), duration: d }).collect();
        clock.run_stage(&t)
    };

    let mut shuffle_bytes = 0u64;
    let mut charge_uniform_shuffle = |clock: &mut VirtualClock, total: f64, msgs: u64| {
        // Volume spread uniformly across node NICs (the UT partitioner's
        // balanced packing), scaled by the cross-node fraction.
        let mut t = Traffic::new(nodes);
        let per = (total * xf / nodes as f64) as u64;
        for v in 0..nodes {
            t.in_bytes[v] = per;
            t.out_bytes[v] = per;
        }
        t.messages = (msgs as f64 * xf) as u64;
        shuffle_bytes += t.total();
        let dt = net.shuffle_time(&t);
        clock.advance(dt);
    };

    // Driver lineage model shared by all stages: scheduling cost per task
    // grows with lineage depth (engine::context::LINEAGE_OVERHEAD_FACTOR).
    let mut lineage_depth = 0usize;
    let sched = |depth: usize, tasks: usize| -> f64 {
        cluster.sched_overhead * (1.0 + 0.05 * depth as f64) * tasks as f64
    };

    // ---------------- kNN stage ----------------
    let t0 = clock.now();
    // pairs replication: q point blocks (b×D) each sent to ~q pair blocks.
    let point_bytes = (b * w.dim * 8) as u64;
    charge_uniform_shuffle(&mut clock, (q as u64 * q as u64 * point_bytes) as f64, (q * q) as u64);
    // dist + local topk per UT block.
    let dist_t = m.dist * (b * b * w.dim) as f64;
    let topk_t = m.topk * (b * b) as f64 * 2.0; // rows + cols scan
    let tasks: Vec<(BlockId, f64)> = ut_blocks().map(|id| (id, dist_t + topk_t)).collect();
    run(&mut clock, &tasks);
    // topk merge: n·k candidate entries from q sources each.
    charge_uniform_shuffle(&mut clock, (n * w.k * 16 * q) as f64 / 2.0, (n / b.max(1)) as u64 * q as u64);
    // graph fill: n·k edges shuffled to blocks.
    charge_uniform_shuffle(&mut clock, (n * w.k * 24) as f64, (n * w.k) as u64 / 100);
    let fill_tasks: Vec<(BlockId, f64)> =
        ut_blocks().map(|id| (id, m.center * (b * b) as f64)).collect();
    run(&mut clock, &fill_tasks);
    // kNN adds ~6 lineage nodes; charge its stages' tasks.
    lineage_depth += 6;
    clock.advance(sched(lineage_depth, q + 3 * total_blocks + q * q / 2));
    let knn_secs = clock.now() - t0;

    // ---------------- APSP stage ----------------
    let t0 = clock.now();
    let fw_t = m.fw * (b * b * b) as f64 * spill_mult;
    let mp_t = m.minplus * (b * b * b) as f64 * spill_mult;
    for piv in 0..q {
        // Phase 1: one FW task on the pivot's node; replicate to row+col.
        run(&mut clock, &[(BlockId::new(piv, piv), fw_t)]);
        let p2_count = q - 1;
        charge_uniform_shuffle(&mut clock, (p2_count as u64 * blk_bytes) as f64, p2_count as u64);
        // Phase 2: q-1 min-plus tasks.
        let p2_tasks: Vec<(BlockId, f64)> = (0..q)
            .filter(|&r| r != piv)
            .map(|r| {
                let id = if r < piv { BlockId::new(r, piv) } else { BlockId::new(piv, r) };
                (id, mp_t)
            })
            .collect();
        run(&mut clock, &p2_tasks);
        // Phase-2 replication: each of the 2(q-1) oriented segments goes to
        // ~q-1 phase-3 blocks (the paper's communication-avoiding O(q)
        // replication).
        let repl = 2 * p2_count * p2_count;
        charge_uniform_shuffle(&mut clock, (repl as u64 * blk_bytes) as f64, repl as u64);
        // Phase 3: all UT blocks outside row/col piv.
        let p3_tasks: Vec<(BlockId, f64)> = ut_blocks()
            .filter(|id| id.i != piv && id.j != piv)
            .map(|id| (id, mp_t))
            .collect();
        run(&mut clock, &p3_tasks);
        // Driver scheduling overhead: per task, amplified by lineage depth
        // (each APSP iteration adds ~6 lineage nodes; reset on checkpoint).
        lineage_depth += 6;
        let iter_tasks = 1 + p2_count + 2 * p2_count + p3_tasks.len() + total_blocks;
        clock.advance(sched(lineage_depth, iter_tasks));
        // Checkpoint: disk write of the per-node share of G, lineage reset.
        if w.checkpoint_every > 0 && (piv + 1) % w.checkpoint_every == 0 {
            lineage_depth = 0;
            if cluster.disk_bandwidth.is_finite() {
                clock.advance(g_bytes as f64 / nodes as f64 / cluster.disk_bandwidth);
            }
        }
    }
    let apsp_secs = clock.now() - t0;

    // ---------------- centering ----------------
    let t0 = clock.now();
    let sums_tasks: Vec<(BlockId, f64)> =
        ut_blocks().map(|id| (id, m.center * (b * b) as f64)).collect();
    run(&mut clock, &sums_tasks);
    charge_uniform_shuffle(&mut clock, (q * q * b * 8) as f64 / 2.0, (q * q) as u64 / 2);
    clock.advance(net.collect_time((n * 8) as u64, q as u64));
    clock.advance(net.broadcast_time((n * 8) as u64));
    let apply_tasks: Vec<(BlockId, f64)> =
        ut_blocks().map(|id| (id, m.center * (b * b) as f64)).collect();
    run(&mut clock, &apply_tasks);
    lineage_depth += 4;
    clock.advance(sched(lineage_depth, 2 * total_blocks + q));
    let center_secs = clock.now() - t0;

    // ---------------- eigendecomposition ----------------
    let t0 = clock.now();
    let q_bytes = (n * w.d * 8) as u64;
    let gemm_t = m.gemm * (b * b * w.d) as f64;
    for _ in 0..w.eigen_iters {
        clock.advance(net.broadcast_time(q_bytes));
        let tasks: Vec<(BlockId, f64)> = ut_blocks()
            .map(|id| (id, if id.i == id.j { gemm_t } else { 2.0 * gemm_t }))
            .collect();
        run(&mut clock, &tasks);
        // reduce V blocks + collect to driver.
        charge_uniform_shuffle(&mut clock, (q * q * b * w.d * 8) as f64 / 2.0, (q * q) as u64 / 2);
        clock.advance(net.collect_time(q_bytes, q as u64));
        // Each iteration adds flat_map + reduce (+collect) lineage nodes.
        lineage_depth += 3;
        clock.advance(sched(lineage_depth, total_blocks + q));
    }
    let eigen_secs = clock.now() - t0;

    Projection {
        total_secs: feasible.then_some(clock.now()),
        knn_secs,
        apsp_secs,
        center_secs,
        eigen_secs,
        shuffle_bytes,
        resident_bytes_per_node,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::paper_like()
    }

    #[test]
    fn more_nodes_is_faster() {
        let w = Workload::new("Swiss50", 50_000, 3, 1500);
        let m = model();
        let t2 = project(&w, &ClusterConfig::paper_testbed(2), &m).total_secs.unwrap();
        let t8 = project(&w, &ClusterConfig::paper_testbed(8), &m).total_secs.unwrap();
        let t24 = project(&w, &ClusterConfig::paper_testbed(24), &m).total_secs.unwrap();
        assert!(t2 > t8 && t8 > t24, "t2={t2} t8={t8} t24={t24}");
        // Strong scaling in the paper's observed range: S(8 v 2) in [2, 8].
        let s = t2 / t8;
        assert!(s > 2.0 && s < 8.5, "speedup 2->8 nodes = {s}");
    }

    #[test]
    fn apsp_dominates_at_scale() {
        let w = Workload::new("Swiss75", 75_000, 3, 1500);
        let p = project(&w, &ClusterConfig::paper_testbed(12), &model());
        assert!(p.apsp_secs > p.knn_secs);
        assert!(p.apsp_secs > p.center_secs + p.eigen_secs);
    }

    #[test]
    fn knn_scales_with_dimension() {
        let s = Workload::new("Swiss50", 50_000, 3, 1500);
        let e = Workload::new("EMNIST50", 50_000, 784, 1500);
        let m = model();
        let ps = project(&s, &ClusterConfig::paper_testbed(8), &m);
        let pe = project(&e, &ClusterConfig::paper_testbed(8), &m);
        // D=784 vs D=3 must cost visibly more in kNN (dist compute + the
        // point-block replication shuffle both scale with D); the common
        // driver/scheduling charges dilute the ratio below the pure-flops
        // 261x, matching the paper's "kNN is a small fraction" observation.
        assert!(pe.knn_secs > 1.5 * ps.knn_secs, "{} vs {}", pe.knn_secs, ps.knn_secs);
        // ...but the total is not dominated by kNN (paper: same scaling for
        // Swiss50 and EMNIST50).
        let ratio = pe.total_secs.unwrap() / ps.total_secs.unwrap();
        assert!(ratio < 2.5, "EMNIST50/Swiss50 total ratio = {ratio}");
    }

    #[test]
    fn small_clusters_cannot_fit_large_datasets() {
        // Table I: Swiss100 impossible below 8 nodes, EMNIST125 below 12.
        let m = model();
        let w100 = Workload::new("Swiss100", 100_000, 3, 1500);
        let mut small = ClusterConfig::paper_testbed(4);
        // 100k²·8·2.5/4 nodes = 50 GB > 56 GB? tune: the paper's `-` comes
        // from real memory pressure; assert the monotone relation instead.
        small.mem_per_node = 8 * (1 << 30);
        assert!(project(&w100, &small, &m).total_secs.is_none());
        let big = ClusterConfig::paper_testbed(24);
        assert!(project(&w100, &big, &m).total_secs.is_some());
    }

    #[test]
    fn weak_scaling_cubic_in_n() {
        // Fixed nodes: T(n) should grow roughly like n³ (APSP-dominated).
        let m = model();
        let cl = ClusterConfig::paper_testbed(16);
        let t50 = project(&Workload::new("s", 50_000, 3, 1500), &cl, &m).total_secs.unwrap();
        let t100 = project(&Workload::new("s", 100_000, 3, 1500), &cl, &m).total_secs.unwrap();
        let ratio = t100 / t50;
        assert!(ratio > 5.0 && ratio < 12.0, "T(100k)/T(50k) = {ratio}");
    }

    #[test]
    fn calibration_produces_sane_coefficients() {
        let m = CostModel::calibrate(96);
        for (name, v) in [
            ("dist", m.dist),
            ("minplus", m.minplus),
            ("fw", m.fw),
            ("topk", m.topk),
            ("center", m.center),
            ("gemm", m.gemm),
        ] {
            assert!(v > 1e-12 && v < 1e-5, "{name} coefficient insane: {v}");
        }
    }
}
