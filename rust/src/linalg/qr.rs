//! Householder QR decomposition.
//!
//! The paper's driver runs NumPy's (LAPACK) QR on the tall-skinny `V` matrix
//! (`n × d`, d small) each power-iteration step. This is the Rust
//! equivalent: thin QR via Householder reflections, returning `Q (n×d)` with
//! orthonormal columns and upper-triangular `R (d×d)` with a sign convention
//! (non-negative diagonal) so successive iterates are comparable under the
//! Frobenius-norm convergence test.

use super::matrix::Matrix;

/// Thin QR: `a = Q·R`, `Q` is `m×n` with orthonormal columns, `R` is `n×n`
/// upper triangular with non-negative diagonal. Requires `m >= n`.
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let m = a.nrows();
    let n = a.ncols();
    assert!(m >= n, "qr_thin requires rows >= cols ({m} < {n})");

    // Work on a copy; accumulate Householder vectors in-place below the
    // diagonal, R above it (standard LAPACK-style compact form).
    let mut r = a.clone();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);

    for k in 0..n {
        // Build the Householder vector for column k.
        let mut v: Vec<f64> = (k..m).map(|i| r[(i, k)]).collect();
        let alpha = -v[0].signum() * v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if alpha == 0.0 {
            // Zero column below the diagonal: identity reflector.
            vs.push(vec![0.0; m - k]);
            continue;
        }
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        // Apply H = I - 2 v vᵀ / (vᵀv) to the trailing submatrix.
        for j in k..n {
            let mut dot = 0.0;
            for (ii, vi) in v.iter().enumerate() {
                dot += vi * r[(k + ii, j)];
            }
            let c = 2.0 * dot / vnorm2;
            for (ii, vi) in v.iter().enumerate() {
                r[(k + ii, j)] -= c * vi;
            }
        }
        vs.push(v);
    }

    // Extract the n×n R (upper triangle).
    let mut rr = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rr[(i, j)] = r[(i, j)];
        }
    }

    // Form thin Q by applying the reflectors to the first n columns of I.
    let mut q = Matrix::eye(m, n);
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for (ii, vi) in v.iter().enumerate() {
                dot += vi * q[(k + ii, j)];
            }
            let c = 2.0 * dot / vnorm2;
            for (ii, vi) in v.iter().enumerate() {
                q[(k + ii, j)] -= c * vi;
            }
        }
    }

    // Sign convention: make R's diagonal non-negative (flip matching Q cols).
    for j in 0..n {
        if rr[(j, j)] < 0.0 {
            for jj in j..n {
                rr[(j, jj)] = -rr[(j, jj)];
            }
            for i in 0..m {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }

    (q, rr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_matrix(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut a = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                a[(i, j)] = rng.gaussian();
            }
        }
        a
    }

    fn assert_orthonormal(q: &Matrix, tol: f64) {
        let qtq = q.transpose().matmul(q);
        let eye = Matrix::eye(q.ncols(), q.ncols());
        assert!(qtq.max_abs_diff(&eye) < tol, "QᵀQ != I: {:?}", qtq);
    }

    #[test]
    fn reconstructs_a() {
        for seed in 0..5 {
            let a = random_matrix(20, 4, seed);
            let (q, r) = qr_thin(&a);
            let qr = q.matmul(&r);
            assert!(qr.max_abs_diff(&a) < 1e-10, "seed {seed}");
        }
    }

    #[test]
    fn q_orthonormal() {
        let a = random_matrix(50, 6, 11);
        let (q, _) = qr_thin(&a);
        assert_orthonormal(&q, 1e-10);
    }

    #[test]
    fn r_upper_triangular_nonneg_diag() {
        let a = random_matrix(30, 5, 13);
        let (_, r) = qr_thin(&a);
        for i in 0..5 {
            assert!(r[(i, i)] >= 0.0);
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn square_case() {
        let a = random_matrix(6, 6, 17);
        let (q, r) = qr_thin(&a);
        assert!(q.matmul(&r).max_abs_diff(&a) < 1e-10);
        assert_orthonormal(&q, 1e-10);
    }

    #[test]
    fn rank_deficient_column() {
        // Second column is a multiple of the first: R should have a ~0
        // diagonal entry, and QR must still reconstruct A.
        let mut a = Matrix::zeros(8, 3);
        let mut rng = Rng::seed(3);
        for i in 0..8 {
            let x = rng.gaussian();
            a[(i, 0)] = x;
            a[(i, 1)] = 2.0 * x;
            a[(i, 2)] = rng.gaussian();
        }
        let (q, r) = qr_thin(&a);
        assert!(q.matmul(&r).max_abs_diff(&a) < 1e-10);
        assert!(r[(1, 1)].abs() < 1e-10);
    }

    #[test]
    fn identity_input() {
        let a = Matrix::eye(5, 3);
        let (q, r) = qr_thin(&a);
        assert!(q.matmul(&r).max_abs_diff(&a) < 1e-12);
        assert!(r.max_abs_diff(&Matrix::eye(3, 3)) < 1e-12);
    }
}
