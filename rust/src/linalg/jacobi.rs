//! Cyclic Jacobi eigensolver for symmetric matrices.
//!
//! Exactness baseline: the paper's spectral stage is a *simultaneous power
//! iteration* that extracts only the top-d eigenpairs; for tests and
//! ablations we need ground-truth eigenpairs of the (dense, small) feature
//! matrix. Jacobi is slow but robust and has no convergence-order caveats.

use super::matrix::Matrix;

/// Full eigendecomposition of a symmetric matrix.
/// Returns `(eigenvalues, eigenvectors)` sorted by eigenvalue descending;
/// eigenvectors are the columns of the returned matrix.
pub fn eigh(a: &Matrix, max_sweeps: usize, tol: f64) -> (Vec<f64>, Matrix) {
    let n = a.nrows();
    assert_eq!(n, a.ncols(), "eigh requires a square matrix");
    debug_assert!(a.is_symmetric(1e-8), "eigh requires symmetry");

    let mut m = a.clone();
    let mut v = Matrix::eye(n, n);

    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Rotation angle that annihilates m[p][q].
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // Apply the rotation to rows/cols p and q of M.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Collect and sort descending by eigenvalue.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let vals: Vec<f64> = pairs.iter().map(|&(x, _)| x).collect();
    let mut vecs = Matrix::zeros(n, n);
    for (newj, &(_, oldj)) in pairs.iter().enumerate() {
        for i in 0..n {
            vecs[(i, newj)] = v[(i, oldj)];
        }
    }
    (vals, vecs)
}

/// Top-d eigenpairs via [`eigh`], with the paper's sign convention
/// (largest-magnitude entry of each eigenvector made positive).
pub fn top_d(a: &Matrix, d: usize) -> (Vec<f64>, Matrix) {
    let n = a.nrows();
    let (vals, vecs) = eigh(a, 100, 1e-12);
    let mut q = Matrix::zeros(n, d);
    for j in 0..d {
        // Sign fix.
        let mut imax = 0;
        for i in 0..n {
            if vecs[(i, j)].abs() > vecs[(imax, j)].abs() {
                imax = i;
            }
        }
        let s = if vecs[(imax, j)] < 0.0 { -1.0 } else { 1.0 };
        for i in 0..n {
            q[(i, j)] = s * vecs[(i, j)];
        }
    }
    (vals[..d].to_vec(), q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_symmetric(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let x = rng.gaussian();
                a[(i, j)] = x;
                a[(j, i)] = x;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_rows(&[
            vec![3.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 2.0],
        ]);
        let (vals, _) = eigh(&a, 50, 1e-14);
        assert!((vals[0] - 3.0).abs() < 1e-12);
        assert!((vals[1] - 2.0).abs() < 1e-12);
        assert!((vals[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction() {
        let a = random_symmetric(12, 5);
        let (vals, vecs) = eigh(&a, 100, 1e-14);
        // A = V Λ Vᵀ
        let mut lam = Matrix::zeros(12, 12);
        for i in 0..12 {
            lam[(i, i)] = vals[i];
        }
        let rec = vecs.matmul(&lam).matmul(&vecs.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_symmetric(10, 7);
        let (_, vecs) = eigh(&a, 100, 1e-14);
        let vtv = vecs.transpose().matmul(&vecs);
        assert!(vtv.max_abs_diff(&Matrix::eye(10, 10)) < 1e-10);
    }

    #[test]
    fn satisfies_eigen_equation() {
        let a = random_symmetric(8, 9);
        let (vals, vecs) = eigh(&a, 100, 1e-14);
        for j in 0..8 {
            for i in 0..8 {
                let mut av = 0.0;
                for k in 0..8 {
                    av += a[(i, k)] * vecs[(k, j)];
                }
                assert!((av - vals[j] * vecs[(i, j)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn top_d_signs_fixed() {
        let a = random_symmetric(9, 11);
        let (vals, q) = top_d(&a, 3);
        assert_eq!(vals.len(), 3);
        assert_eq!(q.ncols(), 3);
        for j in 0..3 {
            let col = q.col(j);
            let imax = (0..9).max_by(|&x, &y| col[x].abs().partial_cmp(&col[y].abs()).unwrap()).unwrap();
            assert!(col[imax] > 0.0);
        }
    }
}
