//! Row-major dense `f64` matrix.
//!
//! This is the in-memory representation of every block the engine moves
//! around (the paper's NumPy 2-D arrays). The layout is row-major ("C
//! order") to match both the native kernels' loop nests and the PJRT
//! literals the runtime feeds to the AOT executables.

use std::fmt;

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, v: f64) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity-like rectangular matrix (ones on the main diagonal). For
    /// square `n×n` this is the identity; for `n×d` it is the first `d`
    /// standard basis vectors — the paper's power-iteration start `V¹`.
    pub fn eye(rows: usize, cols: usize) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows.min(cols) {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from an existing buffer (length must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Self { rows, cols, data }
    }

    /// Build from nested rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    pub fn nrows(&self) -> usize {
        self.rows
    }

    pub fn ncols(&self) -> usize {
        self.cols
    }

    /// Underlying row-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable underlying buffer.
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the underlying buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` copied out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Plain matrix product `self * rhs`, ikj loop order for cache locality.
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let rrow = rhs.row(k);
                let orow = out.row_mut(i);
                for (o, &r) in orow.iter_mut().zip(rrow) {
                    *o += a * r;
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Frobenius norm of `self - other`.
    pub fn fro_dist(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Largest absolute entry-wise difference.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// In-place scalar multiply.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Sub-matrix copy: rows `r0..r1`, cols `c0..c1`.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Paste `block` with its top-left corner at `(r0, c0)`.
    pub fn paste(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for i in 0..block.rows {
            self.row_mut(r0 + i)[c0..c0 + block.cols].copy_from_slice(block.row(i));
        }
    }

    /// Column means.
    pub fn col_means(&self) -> Vec<f64> {
        let mut mu = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (m, &x) in mu.iter_mut().zip(self.row(i)) {
                *m += x;
            }
        }
        for m in &mut mu {
            *m /= self.rows as f64;
        }
        mu
    }

    /// Mean over all entries.
    pub fn grand_mean(&self) -> f64 {
        self.data.iter().sum::<f64>() / (self.data.len() as f64)
    }

    /// True when the matrix is symmetric within `tol`.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let cells: Vec<String> =
                self.row(i).iter().take(8).map(|x| format!("{x:10.4}")).collect();
            writeln!(f, "  {}{}", cells.join(" "), if self.cols > 8 { " …" } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_layout() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m[(1, 0)], 3.0);
        assert_eq!(m.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.col(0), vec![1.0, 3.0]);
    }

    #[test]
    fn eye_rectangular() {
        let m = Matrix::eye(4, 2);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 1)], 1.0);
        assert_eq!(m[(2, 0)], 0.0);
        assert_eq!(m[(3, 1)], 0.0);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let c = a.matmul(&Matrix::eye(3, 3));
        assert_eq!(c, a);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose()[(2, 1)], 6.0);
    }

    #[test]
    fn slice_paste_roundtrip() {
        let mut m = Matrix::zeros(5, 5);
        let b = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        m.paste(1, 2, &b);
        assert_eq!(m.slice(1, 3, 2, 4), b);
        assert_eq!(m[(0, 0)], 0.0);
        assert_eq!(m[(2, 3)], 4.0);
    }

    #[test]
    fn norms() {
        let a = Matrix::from_rows(&[vec![3.0, 0.0], vec![0.0, 4.0]]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
        let b = Matrix::zeros(2, 2);
        assert!((a.fro_dist(&b) - 5.0).abs() < 1e-12);
        assert_eq!(a.max_abs_diff(&b), 4.0);
    }

    #[test]
    fn stats() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.col_means(), vec![2.0, 3.0]);
        assert!((a.grand_mean() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn symmetry_check() {
        let s = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(s.is_symmetric(0.0));
        let ns = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.1, 1.0]]);
        assert!(!ns.is_symmetric(1e-3));
        assert!(!Matrix::zeros(2, 3).is_symmetric(1.0));
    }
}
