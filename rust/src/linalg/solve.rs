//! Dense linear solver: LU with partial pivoting.
//!
//! Used by the LLE extension (per-point local Gram systems `C·w = 1`) and
//! available as a general substrate. Small systems (k×k, k ≈ 10) are the
//! target; no blocking needed.

use super::matrix::Matrix;
use anyhow::{bail, Result};

/// Reusable LU factorization with partial pivoting (factor once, solve
/// many right-hand sides — the shift-invert iteration's access pattern).
pub struct Lu {
    lu: Matrix,
    perm: Vec<usize>,
}

impl Lu {
    /// Factor a square matrix.
    pub fn factor(a: &Matrix) -> Result<Lu> {
        let n = a.nrows();
        if a.ncols() != n {
            bail!("Lu: matrix not square ({}x{})", a.nrows(), a.ncols());
        }
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        for col in 0..n {
            // Pivot: largest |entry| at or below the diagonal.
            let mut p = col;
            for r in (col + 1)..n {
                if lu[(r, col)].abs() > lu[(p, col)].abs() {
                    p = r;
                }
            }
            if lu[(p, col)].abs() < 1e-300 {
                bail!("Lu: singular matrix (pivot ~0 at column {col})");
            }
            if p != col {
                for c in 0..n {
                    let tmp = lu[(col, c)];
                    lu[(col, c)] = lu[(p, c)];
                    lu[(p, c)] = tmp;
                }
                perm.swap(col, p);
            }
            let piv = lu[(col, col)];
            for r in (col + 1)..n {
                let f = lu[(r, col)] / piv;
                lu[(r, col)] = f; // store L factor in place
                for c in (col + 1)..n {
                    let v = lu[(col, c)];
                    lu[(r, c)] -= f * v;
                }
            }
        }
        Ok(Lu { lu, perm })
    }

    /// Solve `A·x = b` using the stored factors.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.perm.len();
        if b.len() != n {
            bail!("Lu::solve: rhs length {} != {n}", b.len());
        }
        // Forward substitution with permuted rhs: L·y = P·b.
        let mut y = vec![0.0; n];
        for r in 0..n {
            let mut acc = b[self.perm[r]];
            for c in 0..r {
                acc -= self.lu[(r, c)] * y[c];
            }
            y[r] = acc;
        }
        // Back substitution: U·x = y.
        let mut x = vec![0.0; n];
        for r in (0..n).rev() {
            let mut acc = y[r];
            for c in (r + 1)..n {
                acc -= self.lu[(r, c)] * x[c];
            }
            x[r] = acc / self.lu[(r, r)];
        }
        Ok(x)
    }
}

/// One-shot solve `A·x = b` via [`Lu`].
pub fn solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    if a.nrows() != a.ncols() || b.len() != a.nrows() {
        bail!("solve: shape mismatch ({}x{} vs rhs {})", a.nrows(), a.ncols(), b.len());
    }
    Lu::factor(a)?.solve(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = rng.gaussian();
            }
            a[(i, i)] += 4.0; // diagonally dominant => well-conditioned
        }
        a
    }

    #[test]
    fn solves_known_system() {
        let a = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]);
        let x = solve(&a, &[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn residual_small_random() {
        for seed in 0..6 {
            let n = 12;
            let a = random(n, seed);
            let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
            let x = solve(&a, &b).unwrap();
            for i in 0..n {
                let mut acc = 0.0;
                for j in 0..n {
                    acc += a[(i, j)] * x[j];
                }
                assert!((acc - b[i]).abs() < 1e-9, "seed {seed} row {i}");
            }
        }
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        // Needs a row swap to solve.
        let a = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let x = solve(&a, &[2.0, 3.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_singular() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(solve(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn rejects_bad_shapes() {
        let a = Matrix::zeros(3, 2);
        assert!(solve(&a, &[1.0, 2.0, 3.0]).is_err());
        let b = Matrix::eye(2, 2);
        assert!(solve(&b, &[1.0]).is_err());
    }
}
