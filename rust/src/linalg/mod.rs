//! Dense linear algebra substrate: row-major `f64` matrices, Householder
//! QR (used by the driver in simultaneous power iteration), and a Jacobi
//! eigensolver used as an exactness baseline for small problems.

pub mod jacobi;
pub mod matrix;
pub mod qr;
pub mod solve;

pub use matrix::Matrix;
