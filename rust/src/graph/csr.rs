//! Immutable CSR (compressed sparse row) neighborhood graph.
//!
//! Built once from the kNN stage's per-point neighbor lists and never
//! mutated: `row_ptr` (length `n + 1`) delimits each vertex's adjacency
//! span inside the parallel `cols` / `weights` arrays. Edges are
//! symmetrized (kNN lists are directed; the geodesic graph is not),
//! deduplicated keeping the smallest weight, and column-sorted per row —
//! so construction is deterministic and adjacency scans are contiguous,
//! cache-friendly streams. Column indices are `u32` (half the memory of
//! `usize` at the scales this path exists for).

use crate::kernels::kselect::Neighbor;
use anyhow::{bail, Result};

/// An immutable, symmetrized kNN neighborhood graph in CSR form.
///
/// Memory: `n·4 + nnz·(4 + 8)` bytes plus the row pointers — for `n`
/// points at `k` neighbors that is `O(n·k)`, against `O(n²)` for the
/// dense blocked graph the Floyd–Warshall path operates on.
#[derive(Clone, Debug)]
pub struct CsrGraph {
    n: usize,
    /// `row_ptr[v]..row_ptr[v + 1]` spans vertex `v`'s adjacency.
    row_ptr: Vec<usize>,
    /// Neighbor vertex ids, column-sorted within each row.
    cols: Vec<u32>,
    /// Edge weights, parallel to `cols`.
    weights: Vec<f64>,
}

impl CsrGraph {
    /// Build from per-point kNN lists (`lists[i]` = the `(distance,
    /// neighbor)` pairs of point `i`, as produced by the distributed kNN
    /// stage). Lists may be ragged — points can carry fewer than `k`
    /// entries. Every directed list edge `(i, j)` contributes both arcs
    /// `i → j` and `j → i`; duplicate arcs (mutual neighbors) collapse to
    /// the minimum weight.
    pub fn from_knn_lists(lists: &[Vec<Neighbor>]) -> Result<CsrGraph> {
        let n = lists.len();
        if n > u32::MAX as usize {
            bail!("CSR graph: {n} points exceed the u32 column-index range");
        }
        // Pass 1: symmetrized degree count.
        let mut deg = vec![0usize; n];
        for (i, list) in lists.iter().enumerate() {
            for &(w, j) in list {
                if j >= n {
                    bail!("CSR graph: point {i} lists neighbor {j}, but n = {n}");
                }
                if !w.is_finite() || w < 0.0 {
                    bail!("CSR graph: edge ({i}, {j}) has invalid weight {w}");
                }
                deg[i] += 1;
                deg[j] += 1;
            }
        }
        let mut row_ptr = vec![0usize; n + 1];
        for i in 0..n {
            row_ptr[i + 1] = row_ptr[i] + deg[i];
        }
        // Pass 2: scatter both arc directions.
        let mut cursor = row_ptr.clone();
        let mut cols = vec![0u32; row_ptr[n]];
        let mut weights = vec![0.0f64; row_ptr[n]];
        for (i, list) in lists.iter().enumerate() {
            for &(w, j) in list {
                cols[cursor[i]] = j as u32;
                weights[cursor[i]] = w;
                cursor[i] += 1;
                cols[cursor[j]] = i as u32;
                weights[cursor[j]] = w;
                cursor[j] += 1;
            }
        }
        // Pass 3: per-row sort by (column, weight) and dedup keeping the
        // minimum weight, compacting the arrays in place. The write head
        // never catches the read head (rows only shrink), and rows are
        // staged through a reused scratch buffer so each row is sorted
        // independently of its final position.
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        let mut write = 0usize;
        let mut out_ptr = vec![0usize; n + 1];
        for i in 0..n {
            let (s, e) = (row_ptr[i], row_ptr[i + 1]);
            scratch.clear();
            scratch.extend(cols[s..e].iter().copied().zip(weights[s..e].iter().copied()));
            scratch.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
            let mut last: Option<u32> = None;
            for &(c, w) in &scratch {
                if last == Some(c) {
                    continue; // duplicate arc: the sort put the minimum first
                }
                last = Some(c);
                cols[write] = c;
                weights[write] = w;
                write += 1;
            }
            out_ptr[i + 1] = write;
        }
        cols.truncate(write);
        weights.truncate(write);
        cols.shrink_to_fit();
        weights.shrink_to_fit();
        Ok(CsrGraph { n, row_ptr: out_ptr, cols, weights })
    }

    /// Number of vertices.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of directed arcs (twice the undirected edge count).
    pub fn num_edges(&self) -> usize {
        self.cols.len()
    }

    /// Adjacency of vertex `u` as parallel `(columns, weights)` slices,
    /// column-sorted.
    pub fn neighbors(&self, u: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.row_ptr[u], self.row_ptr[u + 1]);
        (&self.cols[s..e], &self.weights[s..e])
    }

    /// Number of connected components (iterative DFS over the CSR arrays).
    pub fn components(&self) -> usize {
        let mut seen = vec![false; self.n];
        let mut stack: Vec<usize> = Vec::new();
        let mut count = 0usize;
        for start in 0..self.n {
            if seen[start] {
                continue;
            }
            count += 1;
            seen[start] = true;
            stack.push(start);
            while let Some(u) = stack.pop() {
                let (cols, _) = self.neighbors(u);
                for &v in cols {
                    let v = v as usize;
                    if !seen[v] {
                        seen[v] = true;
                        stack.push(v);
                    }
                }
            }
        }
        count
    }

    /// Error unless the graph is a single connected component — geodesics
    /// between components are infinite, which the dense path reports at
    /// the centering stage; the sparse path reports it up front.
    pub fn require_connected(&self) -> Result<()> {
        let c = self.components();
        if c != 1 {
            bail!("kNN graph disconnected ({c} components); increase k");
        }
        Ok(())
    }

    /// Resident bytes of the CSR arrays (diagnostics / memory model).
    pub fn nbytes(&self) -> u64 {
        (self.row_ptr.len() * 8 + self.cols.len() * 4 + self.weights.len() * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lists(edges: &[(usize, usize, f64)], n: usize) -> Vec<Vec<Neighbor>> {
        let mut out = vec![Vec::new(); n];
        for &(i, j, w) in edges {
            out[i].push((w, j));
        }
        out
    }

    #[test]
    fn symmetrizes_and_sorts() {
        // Directed list edges 0->2 and 0->1; CSR must carry both arcs of
        // each, column-sorted.
        let g = CsrGraph::from_knn_lists(&lists(&[(0, 2, 2.0), (0, 1, 1.0)], 3)).unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.num_edges(), 4);
        let (c0, w0) = g.neighbors(0);
        assert_eq!(c0, &[1, 2]);
        assert_eq!(w0, &[1.0, 2.0]);
        let (c1, w1) = g.neighbors(1);
        assert_eq!((c1, w1), (&[0u32][..], &[1.0][..]));
        let (c2, w2) = g.neighbors(2);
        assert_eq!((c2, w2), (&[0u32][..], &[2.0][..]));
    }

    #[test]
    fn dedups_mutual_edges_keeping_min() {
        // 0 lists 1 at 1.5 and 1 lists 0 at 1.0 (asymmetric top-k raggedness
        // cannot produce different distances, but the CSR must be robust to
        // it): one arc per direction survives, at the smaller weight.
        let g = CsrGraph::from_knn_lists(&lists(&[(0, 1, 1.5), (1, 0, 1.0)], 2)).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(0).1, &[1.0]);
        assert_eq!(g.neighbors(1).1, &[1.0]);
    }

    #[test]
    fn ragged_lists_and_isolated_points() {
        // Ragged: point 0 has two neighbors, 1 has none of its own, 3 is
        // fully isolated.
        let g =
            CsrGraph::from_knn_lists(&lists(&[(0, 1, 1.0), (0, 2, 2.0), (2, 1, 0.5)], 4)).unwrap();
        let (c3, w3) = g.neighbors(3);
        assert!(c3.is_empty() && w3.is_empty());
        assert_eq!(g.neighbors(1).0, &[0, 2]);
        assert_eq!(g.components(), 2); // {0,1,2} and {3}
        let err = g.require_connected().unwrap_err();
        assert!(format!("{err:#}").contains("disconnected"), "{err:#}");
    }

    #[test]
    fn connected_passes() {
        let g = CsrGraph::from_knn_lists(&lists(&[(0, 1, 1.0), (1, 2, 1.0)], 3)).unwrap();
        assert_eq!(g.components(), 1);
        assert!(g.require_connected().is_ok());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(CsrGraph::from_knn_lists(&lists(&[(0, 5, 1.0)], 2)).is_err()); // j out of range
        assert!(CsrGraph::from_knn_lists(&lists(&[(0, 1, f64::NAN)], 2)).is_err());
        assert!(CsrGraph::from_knn_lists(&lists(&[(0, 1, f64::INFINITY)], 2)).is_err());
        assert!(CsrGraph::from_knn_lists(&lists(&[(0, 1, -1.0)], 2)).is_err());
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::from_knn_lists(&[]).unwrap();
        assert_eq!(g.n(), 0);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.components(), 0);
    }
}
