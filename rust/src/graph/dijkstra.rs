//! Batched multi-source Dijkstra over a [`CsrGraph`].
//!
//! One source = one binary-heap Dijkstra writing its distance row
//! directly into the caller's buffer. [`multi_source`] fans a batch of
//! sources out over the engine's worker pool: sources are independent,
//! each runs the exact same serial code against the immutable CSR, and
//! every worker reuses a thread-local heap ([`DijkstraScratch`]) across
//! the sources it claims — so the output is **bit-identical for any
//! worker count** (the property the determinism suite enforces for every
//! pooled path in the crate).
//!
//! Cost per source is `O((n + E) log n)` with `E = O(n·k)` — against the
//! `O(n²)` per-row share of the dense blocked Floyd–Warshall, this is the
//! path that stays feasible when an `n × n` matrix no longer fits.

use super::csr::CsrGraph;
use crate::engine::executor::{resolve_workers, run_tasks_with_policy};
use crate::engine::fault::TaskPolicy;
use crate::linalg::Matrix;
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Reusable per-thread Dijkstra state: the binary heap. (The distance
/// array itself is the caller's output row, so the only allocation worth
/// keeping warm between sources is the heap's backing buffer.)
#[derive(Debug, Default)]
pub struct DijkstraScratch {
    heap: BinaryHeap<HeapEntry>,
}

impl DijkstraScratch {
    /// Fresh scratch with an empty heap.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Min-heap entry; `BinaryHeap` is a max-heap, so the ordering is
/// reversed. Distances are finite and non-negative (CSR construction
/// rejects anything else), and ties break on the node id, so the order is
/// total and deterministic.
#[derive(Debug, PartialEq)]
struct HeapEntry {
    dist: f64,
    node: u32,
}

impl Eq for HeapEntry {}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        other.dist.total_cmp(&self.dist).then(other.node.cmp(&self.node))
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Single-source shortest paths from `src`, written into `dist`
/// (`dist.len()` must equal the vertex count; unreachable vertices keep
/// `+∞`). The scratch heap is cleared on entry and reusable afterwards.
pub fn sssp_into(g: &CsrGraph, src: usize, scratch: &mut DijkstraScratch, dist: &mut [f64]) {
    assert_eq!(dist.len(), g.n(), "distance buffer length must equal the vertex count");
    assert!(src < g.n(), "source {src} out of range (n = {})", g.n());
    dist.fill(f64::INFINITY);
    dist[src] = 0.0;
    scratch.heap.clear();
    scratch.heap.push(HeapEntry { dist: 0.0, node: src as u32 });
    while let Some(HeapEntry { dist: d, node: u }) = scratch.heap.pop() {
        if d > dist[u as usize] {
            continue; // stale entry: u was settled through a shorter path
        }
        let (cols, weights) = g.neighbors(u as usize);
        for (&v, &w) in cols.iter().zip(weights) {
            let nd = d + w;
            if nd < dist[v as usize] {
                dist[v as usize] = nd;
                scratch.heap.push(HeapEntry { dist: nd, node: v });
            }
        }
    }
}

thread_local! {
    /// Per-thread scratch for [`multi_source`]: each pool worker keeps one
    /// heap warm across every source it claims.
    static SCRATCH: RefCell<DijkstraScratch> = RefCell::new(DijkstraScratch::new());
}

/// Geodesic distances from each of `sources` to every vertex, as an
/// `m × n` matrix (row `i` = distances from `sources[i]`; unreachable
/// vertices hold `+∞`). Sources run concurrently on `workers` pool
/// threads (`0` = all cores); each row is produced by the same serial
/// [`sssp_into`], so the result is bit-identical for any worker count.
///
/// ```
/// use isospark::graph::{dijkstra, CsrGraph};
///
/// // A weighted path 0 —1.0— 1 —2.0— 2, given as directed kNN lists
/// // (the constructor symmetrizes them).
/// let lists: Vec<Vec<(f64, usize)>> = vec![
///     vec![(1.0, 1)],
///     vec![(2.0, 2)],
///     vec![],
/// ];
/// let g = CsrGraph::from_knn_lists(&lists).unwrap();
/// let d = dijkstra::multi_source(&g, &[0, 2], 2);
/// assert_eq!(d[(0, 2)], 3.0); // 0 → 1 → 2
/// assert_eq!(d[(1, 0)], 3.0); // symmetric
/// assert_eq!(d[(1, 1)], 2.0);
/// ```
pub fn multi_source(g: &CsrGraph, sources: &[usize], workers: usize) -> Matrix {
    multi_source_with_policy(g, sources, workers, None)
}

/// [`multi_source`] with a fault-tolerance policy in front of every
/// source's task (stage `geo:dijkstra`). `None` is the untouched fast
/// path. Injected failures abort an attempt *before* the task body runs,
/// so a retried source never observes a half-written distance row.
pub fn multi_source_with_policy(
    g: &CsrGraph,
    sources: &[usize],
    workers: usize,
    policy: Option<&TaskPolicy>,
) -> Matrix {
    multi_source_stage(g, sources, workers, policy, "geo:dijkstra")
}

/// [`multi_source_with_policy`] charged to a caller-chosen stage name, so
/// other front ends (the implicit feature source recomputes panels under
/// `feat:panel`) keep their own fault-injection schedule and metrics rows.
/// The stage name never reaches the task bodies: distances are
/// bit-identical across stage names, worker counts, and fault plans.
pub fn multi_source_stage(
    g: &CsrGraph,
    sources: &[usize],
    workers: usize,
    policy: Option<&TaskPolicy>,
    stage: &str,
) -> Matrix {
    let n = g.n();
    let m = sources.len();
    let mut out = Matrix::full(m, n, f64::INFINITY);
    let workers = resolve_workers(workers).min(m.max(1));
    let tasks: Vec<(usize, &mut [f64])> =
        sources.iter().copied().zip(out.as_mut_slice().chunks_mut(n.max(1))).collect();
    run_tasks_with_policy(policy, stage, workers, tasks, |(src, row)| {
        SCRATCH.with(|s| sssp_into(g, *src, &mut s.borrow_mut(), row));
    });
    out
}

/// Squared geodesics from each source — the `m × n` landmark table the
/// L-Isomap / streaming fits triangulate against. Errors (with the
/// offending pair) if any vertex is unreachable from any source, which
/// mirrors how the dense path surfaces a disconnected graph.
pub fn geodesics_squared(g: &CsrGraph, sources: &[usize], workers: usize) -> Result<Matrix> {
    geodesics_squared_with_policy(g, sources, workers, None)
}

/// [`geodesics_squared`] with a fault-tolerance policy threaded through
/// the underlying [`multi_source_with_policy`] fan-out.
pub fn geodesics_squared_with_policy(
    g: &CsrGraph,
    sources: &[usize],
    workers: usize,
    policy: Option<&TaskPolicy>,
) -> Result<Matrix> {
    let mut delta = multi_source_with_policy(g, sources, workers, policy);
    for (i, &src) in sources.iter().enumerate() {
        for (j, v) in delta.row_mut(i).iter_mut().enumerate() {
            if !v.is_finite() {
                bail!(
                    "source {src} cannot reach point {j}: the kNN graph is disconnected; \
                     increase k"
                );
            }
            *v *= *v;
        }
    }
    Ok(delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: usize) -> CsrGraph {
        // 0 —1— 1 —1— 2 … a unit-weight path.
        let mut lists: Vec<Vec<(f64, usize)>> = vec![Vec::new(); n];
        for (i, list) in lists.iter_mut().enumerate().take(n - 1) {
            list.push((1.0, i + 1));
        }
        CsrGraph::from_knn_lists(&lists).unwrap()
    }

    #[test]
    fn sssp_on_a_path() {
        let g = path_graph(6);
        let mut scratch = DijkstraScratch::new();
        let mut dist = vec![0.0; 6];
        sssp_into(&g, 2, &mut scratch, &mut dist);
        assert_eq!(dist, vec![2.0, 1.0, 0.0, 1.0, 2.0, 3.0]);
        // Scratch reuse: a second run from a different source is clean.
        sssp_into(&g, 5, &mut scratch, &mut dist);
        assert_eq!(dist, vec![5.0, 4.0, 3.0, 2.0, 1.0, 0.0]);
    }

    #[test]
    fn shorter_path_wins() {
        // Triangle with a shortcut: 0-1 (1.0), 1-2 (1.0), 0-2 (1.5).
        let lists: Vec<Vec<(f64, usize)>> =
            vec![vec![(1.0, 1), (1.5, 2)], vec![(1.0, 2)], vec![]];
        let g = CsrGraph::from_knn_lists(&lists).unwrap();
        let d = multi_source(&g, &[0], 1);
        assert_eq!(d[(0, 2)], 1.5); // direct edge beats 0→1→2 = 2.0
    }

    #[test]
    fn unreachable_stays_infinite() {
        let lists: Vec<Vec<(f64, usize)>> = vec![vec![(1.0, 1)], vec![], vec![]];
        let g = CsrGraph::from_knn_lists(&lists).unwrap();
        let d = multi_source(&g, &[0], 1);
        assert!(d[(0, 2)].is_infinite());
        let err = geodesics_squared(&g, &[0], 1).unwrap_err();
        assert!(format!("{err:#}").contains("cannot reach point 2"), "{err:#}");
    }

    #[test]
    fn pooled_matches_serial_bitwise() {
        let g = path_graph(40);
        let sources: Vec<usize> = (0..40).step_by(3).collect();
        let serial = multi_source(&g, &sources, 1);
        for workers in [2, 3, 8] {
            let pooled = multi_source(&g, &sources, workers);
            for (a, b) in serial.as_slice().iter().zip(pooled.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers = {workers}");
            }
        }
    }

    #[test]
    fn squared_table_is_squared() {
        let g = path_graph(4);
        let sq = geodesics_squared(&g, &[0, 3], 2).unwrap();
        assert_eq!(sq[(0, 3)], 9.0);
        assert_eq!(sq[(1, 0)], 9.0);
        assert_eq!(sq[(0, 0)], 0.0);
    }

    #[test]
    fn empty_sources() {
        let g = path_graph(3);
        let d = multi_source(&g, &[], 4);
        assert_eq!(d.nrows(), 0);
    }

    #[test]
    fn faulty_run_is_bit_identical_to_clean() {
        use crate::config::ClusterConfig;
        use crate::engine::fault::{FaultPlan, ResilienceStats};
        use crate::engine::SparkContext;
        use std::sync::Arc;

        let g = path_graph(40);
        let sources: Vec<usize> = (0..40).step_by(2).collect();
        let clean = multi_source(&g, &sources, 2);
        let policy = TaskPolicy::new(
            FaultPlan::new(0.3, 9, 5),
            Arc::new(ResilienceStats::default()),
            SparkContext::new(ClusterConfig::local()),
        );
        let chaotic = multi_source_with_policy(&g, &sources, 4, Some(&policy));
        for (a, b) in clean.as_slice().iter().zip(chaotic.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let s = policy.stats.snapshot();
        assert!(s.any(), "rate 0.3 over 20 sources must inject something");
    }
}
