//! Sparse geodesics: the k-sparse alternative to the dense blocked APSP.
//!
//! The paper's exact pipeline is capped by the blocked Floyd–Warshall
//! stage — `O(n³)` work and `O(n²)` resident state — yet the neighborhood
//! graph it runs on has only `n·k` edges. This module keeps geodesic
//! computation sparse end to end:
//!
//! * [`CsrGraph`] ([`csr`]) — an immutable compressed-sparse-row view of
//!   the kNN neighborhood graph, built directly from the per-point kNN
//!   lists (symmetrized, deduplicated, column-sorted) without ever
//!   materializing dense blocks.
//! * [`dijkstra`] — a batched multi-source Dijkstra over the CSR graph:
//!   sources fan out over the engine's worker pool
//!   (`engine::executor`), each source runs a binary-heap Dijkstra with
//!   per-thread scratch reuse, and the output is bit-deterministic for
//!   any pool size.
//!
//! Consumers: `coordinator::apsp::solve_sparse` feeds squared-geodesic
//! row panels straight into the centering stage (the dense APSP RDD is
//! never built — `isospark run --geodesics sparse-dijkstra`), and the
//! landmark / streaming fits compute their `m × n` landmark geodesics
//! through the same pooled path with no dense `n × n` state at all.
//!
//! See `docs/ARCHITECTURE.md` ("Sparse geodesics") for where this sits in
//! the full dataflow.

pub mod csr;
pub mod dijkstra;

pub use csr::CsrGraph;
pub use dijkstra::{
    geodesics_squared, geodesics_squared_with_policy, multi_source, multi_source_with_policy,
    sssp_into, DijkstraScratch,
};
