//! Seeded random-projection forest for approximate kNN lists.
//!
//! **Build** (per tree, seeded): recursively split the point set on a
//! random gaussian hyperplane — project every member onto the direction,
//! sort by `(projection, point id)` (`total_cmp`, so the order is total
//! and canonical), and cut at the median — until a node holds at most
//! `leaf_size` points. **Query** (in-sample): routing a point down the
//! tree it was built from lands exactly in the leaf that holds it, so the
//! leaf partition *is* the routing result; a point's candidates are the
//! union of its leaf co-members across all `T` trees. **Rescore**: each
//! leaf's member rows are gathered into per-thread scratch and pushed
//! through the tiled symmetric distance kernel
//! ([`crate::kernels::sqdist::dist_block_sym`]), whose per-pair distance
//! is a pure function of the two rows — bit-identical wherever the pair
//! is evaluated — then per-member top-k selection
//! ([`crate::kernels::kselect::TopK`]) keeps the `k` smallest with the
//! crate's canonical `(distance, index)` tie-break. Per-tree lists are
//! merged per point (sort + dedup by index — duplicates across trees are
//! bit-identical, so they land adjacent) and truncated to `k`.
//!
//! **Determinism**: tree `t` draws from `Rng::seed(seed ⊕ mix(t))`, split
//! directions are consumed in fixed pre-order, trees are merged in fixed
//! tree order, and every fan-out runs over the engine executor's
//! `run_tasks` (submission-order results) — so the lists are
//! bit-identical for any worker count.
//!
//! **Cost**: build is `O(T · n log(n/leaf) · D)`, rescoring
//! `O(T · n · leaf · D)` FLOPs against the exact stage's `O(n² · D)` —
//! the candidate-pair fraction is `≈ T·leaf/(2n)` of `n²` and *shrinks*
//! as `n` grows (0.8% at `n = 32768` with the defaults).
//!
//! ```
//! use isospark::knn_approx::{knn_lists, RpForestParams};
//! use isospark::linalg::Matrix;
//!
//! // 64 points on a line: median splits cut the line into contiguous
//! // runs, so point 10's true neighbors (9 and 11) share its leaf.
//! let x = Matrix::from_vec(64, 1, (0..64).map(|i| i as f64).collect());
//! let params = RpForestParams { trees: 2, leaf_size: 8, seed: 7 };
//! let (lists, stats) = knn_lists(&x, 2, &params, 1).unwrap();
//! let ids: Vec<usize> = lists[10].iter().map(|&(_, j)| j).collect();
//! assert_eq!(ids, vec![9, 11]);
//! assert!(stats.candidate_pairs > 0);
//! ```

use crate::engine::executor::{resolve_workers, run_tasks_with_policy};
use crate::engine::fault::TaskPolicy;
use crate::kernels::kselect::{Neighbor, TopK};
use crate::kernels::sqdist;
use crate::linalg::Matrix;
use crate::util::Rng;
use anyhow::{bail, Result};
use std::cell::RefCell;

thread_local! {
    /// Per-thread gather buffer for leaf rescoring: each pool worker
    /// reuses one backing allocation across every leaf it claims.
    static GATHER: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// Forest hyper-parameters. `leaf_size` is the recall/cost knob: each
/// point is exactly rescored against ≈ `trees · leaf_size` candidates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RpForestParams {
    /// Number of trees `T` (independent seeded random partitions).
    pub trees: usize,
    /// Maximum leaf population; splitting stops at or below this size.
    pub leaf_size: usize,
    /// Base seed; tree `t` uses an independent derived stream.
    pub seed: u64,
}

impl RpForestParams {
    /// Reject degenerate configurations up front, with the constraint in
    /// the message: zero trees find nothing, and a leaf that cannot hold
    /// `k` co-members cannot fill a top-k list from any single tree.
    pub fn validate(&self, k: usize) -> Result<()> {
        if self.trees == 0 {
            bail!("rp-forest: tree count T must be ≥ 1 (got 0)");
        }
        if self.leaf_size <= k {
            bail!(
                "rp-forest: leaf size {} must exceed k = {k} (a leaf holds a point plus \
                 its candidates; use rp_leaf = 0 for the automatic default)",
                self.leaf_size
            );
        }
        Ok(())
    }
}

/// Evidence from an rp-forest run — the candidate-generation counters the
/// `run`/`fit` reports surface next to the stage metrics, including the
/// recall proxy (list fullness + distinct-candidate depth).
#[derive(Clone, Debug)]
pub struct RpForestStats {
    /// Point count the forest indexed.
    pub n: usize,
    /// Neighbors requested per point.
    pub k: usize,
    /// Trees built.
    pub trees: usize,
    /// Leaf-size bound used (after resolving the automatic default).
    pub leaf_size: usize,
    /// Total leaves across all trees.
    pub leaves: usize,
    /// Exactly rescored candidate pairs, `Σ_leaves L(L−1)/2` — the FLOP
    /// count that replaces the exact stage's `n(n−1)/2`.
    pub candidate_pairs: u64,
    /// Mean distinct candidates per point that survived into the merge
    /// (unioned across trees, before truncation to `k`).
    pub mean_distinct_candidates: f64,
    /// Fraction of points whose merged candidate set had ≥ `k` distinct
    /// members — with every list full and candidates ≫ k, low recall
    /// would require all trees to co-locate the same wrong neighbors.
    pub full_fraction: f64,
}

impl RpForestStats {
    /// Candidate pairs as a fraction of `n²` (the acceptance metric; the
    /// exact stage sits at `(n−1)/(2n) ≈ 0.5`).
    pub fn pair_fraction(&self) -> f64 {
        self.candidate_pairs as f64 / (self.n as f64 * self.n as f64)
    }

    /// One-line human summary for run reports.
    pub fn describe(&self) -> String {
        format!(
            "rp-forest (T={}, leaf={}, {} leaves): {} candidate pairs = {:.2}% of n² \
             | recall proxy: {:.1} distinct candidates/point, {:.1}% lists full",
            self.trees,
            self.leaf_size,
            self.leaves,
            self.candidate_pairs,
            100.0 * self.pair_fraction(),
            self.mean_distinct_candidates,
            100.0 * self.full_fraction,
        )
    }
}

/// A built forest: per tree, the leaf partition of `0..n` (each leaf
/// sorted ascending by point id). For in-sample queries the partition is
/// the routing result, so this is all a kNN build needs to retain.
#[derive(Clone, Debug)]
pub struct RpForest {
    trees: Vec<Vec<Vec<u32>>>,
    params: RpForestParams,
}

impl RpForest {
    /// Build `params.trees` trees over the rows of `x`, fanned out over
    /// `workers` pool threads (`0` = all cores). Bit-deterministic for
    /// any worker count: each tree is an independent task with its own
    /// seeded stream, and results come back in tree order.
    pub fn build(x: &Matrix, params: &RpForestParams, workers: usize) -> Result<RpForest> {
        Self::build_with_policy(x, params, workers, None)
    }

    /// [`RpForest::build`] with a fault-tolerance policy in front of every
    /// per-tree task (stage `knn:rpforest:build`). `None` is the untouched
    /// fast path.
    pub fn build_with_policy(
        x: &Matrix,
        params: &RpForestParams,
        workers: usize,
        policy: Option<&TaskPolicy>,
    ) -> Result<RpForest> {
        if x.nrows() < 2 {
            bail!("rp-forest: need at least 2 points, got {}", x.nrows());
        }
        let workers = resolve_workers(workers).min(params.trees);
        let tree_ids: Vec<usize> = (0..params.trees).collect();
        let trees = run_tasks_with_policy(policy, "knn:rpforest:build", workers, tree_ids, |t| {
            // Independent stream per tree: the SplitMix64 expansion in
            // `Rng::seed` decorrelates nearby seeds.
            let mut rng = Rng::seed(params.seed ^ (*t as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let mut leaves = Vec::new();
            let idx: Vec<u32> = (0..x.nrows() as u32).collect();
            split_node(x, idx, params.leaf_size, &mut rng, &mut leaves);
            leaves
        });
        Ok(RpForest { trees, params: *params })
    }

    /// Total leaves across all trees.
    pub fn num_leaves(&self) -> usize {
        self.trees.iter().map(Vec::len).sum()
    }

    /// Exact-rescored approximate kNN lists: every leaf's co-member pairs
    /// are scored with the tiled symmetric distance kernel, per-tree
    /// top-k lists are merged per point in fixed tree order, deduplicated
    /// by index, and truncated to `k`. Output matches the exact stage's
    /// shape and tie-break contract; bit-deterministic for any worker
    /// count.
    pub fn knn_lists(
        &self,
        x: &Matrix,
        k: usize,
        workers: usize,
    ) -> Result<(Vec<Vec<Neighbor>>, RpForestStats)> {
        self.knn_lists_with_policy(x, k, workers, None)
    }

    /// [`RpForest::knn_lists`] with a fault-tolerance policy in front of
    /// the rescore (`knn:rpforest:rescore`) and merge
    /// (`knn:rpforest:merge`) fan-outs. `None` is the untouched fast path.
    pub fn knn_lists_with_policy(
        &self,
        x: &Matrix,
        k: usize,
        workers: usize,
        policy: Option<&TaskPolicy>,
    ) -> Result<(Vec<Vec<Neighbor>>, RpForestStats)> {
        self.params.validate(k)?;
        let n = x.nrows();
        let workers = resolve_workers(workers);

        // Rescore every leaf (all trees flattened — leaf tasks are
        // independent and results return in submission order).
        let leaf_tasks: Vec<&[u32]> =
            self.trees.iter().flat_map(|t| t.iter().map(Vec::as_slice)).collect();
        let scored = run_tasks_with_policy(
            policy,
            "knn:rpforest:rescore",
            workers.min(leaf_tasks.len().max(1)),
            leaf_tasks,
            |members| score_leaf(x, members, k),
        );

        // Driver-side scatter, in (tree, leaf, member) order: each point
        // collects exactly one candidate list per tree.
        let mut cand: Vec<Vec<Neighbor>> = vec![Vec::new(); n];
        let mut candidate_pairs = 0u64;
        for (lists, pairs) in scored {
            candidate_pairs += pairs;
            for (g, list) in lists {
                cand[g as usize].extend(list);
            }
        }

        // Merge per point: canonical sort, dedup by index (cross-tree
        // duplicates carry bit-identical distances, so they sort
        // adjacent), truncate to k. Chunk ownership — not arrival order —
        // decides placement, so any pool size gives the same lists.
        let chunk = n.div_ceil(workers).max(1);
        let tasks: Vec<&mut [Vec<Neighbor>]> = cand.chunks_mut(chunk).collect();
        let partials = run_tasks_with_policy(
            policy,
            "knn:rpforest:merge",
            workers.min(tasks.len().max(1)),
            tasks,
            |slice| {
            let mut distinct = 0u64;
            let mut full = 0u64;
            for list in slice.iter_mut() {
                list.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                list.dedup_by_key(|e| e.1);
                distinct += list.len() as u64;
                if list.len() >= k {
                    full += 1;
                }
                list.truncate(k);
                list.shrink_to_fit();
            }
            (distinct, full)
        });
        let (distinct, full) =
            partials.iter().fold((0u64, 0u64), |(d, f), &(pd, pf)| (d + pd, f + pf));

        let stats = RpForestStats {
            n,
            k,
            trees: self.params.trees,
            leaf_size: self.params.leaf_size,
            leaves: self.num_leaves(),
            candidate_pairs,
            mean_distinct_candidates: distinct as f64 / n.max(1) as f64,
            full_fraction: full as f64 / n.max(1) as f64,
        };
        Ok((cand, stats))
    }
}

/// Build + query in one call — the shape `coordinator::knn` consumes.
pub fn knn_lists(
    x: &Matrix,
    k: usize,
    params: &RpForestParams,
    workers: usize,
) -> Result<(Vec<Vec<Neighbor>>, RpForestStats)> {
    knn_lists_with_policy(x, k, params, workers, None)
}

/// [`knn_lists`] with a fault-tolerance policy threaded through all three
/// forest fan-outs (build, rescore, merge). `None` is the untouched fast
/// path.
pub fn knn_lists_with_policy(
    x: &Matrix,
    k: usize,
    params: &RpForestParams,
    workers: usize,
    policy: Option<&TaskPolicy>,
) -> Result<(Vec<Vec<Neighbor>>, RpForestStats)> {
    params.validate(k)?;
    let forest = RpForest::build_with_policy(x, params, workers, policy)?;
    forest.knn_lists_with_policy(x, k, workers, policy)
}

/// Recursive median split. `idx` arrives in arbitrary order; leaves are
/// stored sorted ascending so candidate scans are canonical. Pre-order
/// recursion (left before right) fixes the rng consumption order.
fn split_node(
    x: &Matrix,
    mut idx: Vec<u32>,
    leaf_size: usize,
    rng: &mut Rng,
    out: &mut Vec<Vec<u32>>,
) {
    if idx.len() <= leaf_size {
        idx.sort_unstable();
        out.push(idx);
        return;
    }
    let d = x.ncols();
    let dir: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
    let mut keyed: Vec<(f64, u32)> = idx
        .into_iter()
        .map(|i| {
            let row = x.row(i as usize);
            let proj = row.iter().zip(&dir).map(|(a, b)| a * b).sum::<f64>();
            (proj, i)
        })
        .collect();
    // Total order: projection (total_cmp) then point id — ties (e.g. a
    // degenerate direction or duplicate points) still halve the node, so
    // recursion always terminates.
    keyed.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let half = keyed.len() / 2;
    let right: Vec<u32> = keyed[half..].iter().map(|&(_, i)| i).collect();
    keyed.truncate(half);
    let left: Vec<u32> = keyed.into_iter().map(|(_, i)| i).collect();
    split_node(x, left, leaf_size, rng, out);
    split_node(x, right, leaf_size, rng, out);
}

/// Score one leaf: gather member rows into per-thread scratch, run the
/// tiled symmetric distance kernel, and keep each member's k smallest
/// co-members (canonical tie-break: members are scanned ascending by
/// global id, and `TopK` keeps the first-seen on threshold ties).
/// Returns the per-member lists plus the pair count `L(L−1)/2`.
#[allow(clippy::type_complexity)]
fn score_leaf(x: &Matrix, members: &[u32], k: usize) -> (Vec<(u32, Vec<Neighbor>)>, u64) {
    let l = members.len();
    let d = x.ncols();
    let mut buf = GATHER.with(|c| std::mem::take(&mut *c.borrow_mut()));
    buf.clear();
    buf.reserve(l * d);
    for &m in members {
        buf.extend_from_slice(x.row(m as usize));
    }
    let sub = Matrix::from_vec(l, d, buf);
    let dist = sqdist::dist_block_sym(&sub);
    let mut out = Vec::with_capacity(l);
    for (r, &gr) in members.iter().enumerate() {
        let mut top = TopK::new(k);
        for (c, &gc) in members.iter().enumerate() {
            if c != r {
                top.push(dist[(r, c)], gc as usize);
            }
        }
        out.push((gr, top.into_sorted()));
    }
    GATHER.with(|c| *c.borrow_mut() = sub.into_vec());
    (out, (l as u64) * (l as u64 - 1) / 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::data::swiss_roll;

    fn swiss(n: usize, seed: u64) -> Matrix {
        swiss_roll::euler_isometric(n, seed).points
    }

    #[test]
    fn lists_are_well_formed() {
        let x = swiss(512, 3);
        let params = RpForestParams { trees: 4, leaf_size: 32, seed: 1 };
        let (lists, stats) = knn_lists(&x, 6, &params, 1).unwrap();
        assert_eq!(lists.len(), 512);
        assert_eq!(stats.n, 512);
        assert!(stats.leaves >= 4, "at least one leaf per tree");
        for (i, list) in lists.iter().enumerate() {
            assert_eq!(list.len(), 6, "point {i}");
            for w in list.windows(2) {
                assert!(
                    w[0].0 < w[1].0 || (w[0].0 == w[1].0 && w[0].1 < w[1].1),
                    "point {i}: unsorted or duplicate"
                );
            }
            assert!(list.iter().all(|&(_, j)| j != i), "point {i} lists itself");
        }
    }

    #[test]
    fn rescoring_is_exact_on_candidates() {
        // With one tree and leaf ≥ n the forest degenerates to the exact
        // brute-force lists — the rescoring path must reproduce them
        // entry for entry.
        let x = swiss(96, 5);
        let params = RpForestParams { trees: 1, leaf_size: 96, seed: 9 };
        let (lists, stats) = knn_lists(&x, 7, &params, 1).unwrap();
        let exact = baselines::brute_knn(&x, 7);
        for i in 0..96 {
            let got: Vec<usize> = lists[i].iter().map(|&(_, j)| j).collect();
            let want: Vec<usize> = exact[i].iter().map(|&(_, j)| j).collect();
            assert_eq!(got, want, "point {i}");
        }
        assert_eq!(stats.leaves, 1);
        assert_eq!(stats.candidate_pairs, 96 * 95 / 2);
        assert_eq!(stats.full_fraction, 1.0);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let x = swiss(400, 7);
        let params = RpForestParams { trees: 6, leaf_size: 24, seed: 11 };
        let (reference, ref_stats) = knn_lists(&x, 5, &params, 1).unwrap();
        for workers in [2, 4, 8] {
            let (lists, stats) = knn_lists(&x, 5, &params, workers).unwrap();
            assert_eq!(stats.candidate_pairs, ref_stats.candidate_pairs);
            for (i, (a, b)) in reference.iter().zip(&lists).enumerate() {
                assert_eq!(a.len(), b.len(), "workers={workers} point {i}");
                for (u, v) in a.iter().zip(b) {
                    assert_eq!(u.0.to_bits(), v.0.to_bits(), "workers={workers} point {i}");
                    assert_eq!(u.1, v.1, "workers={workers} point {i}");
                }
            }
        }
    }

    #[test]
    fn seed_changes_the_forest() {
        let x = swiss(256, 13);
        let a = RpForest::build(&x, &RpForestParams { trees: 2, leaf_size: 16, seed: 1 }, 1)
            .unwrap();
        let b = RpForest::build(&x, &RpForestParams { trees: 2, leaf_size: 16, seed: 2 }, 1)
            .unwrap();
        assert_ne!(a.trees, b.trees, "different seeds must give different partitions");
        let a2 = RpForest::build(&x, &RpForestParams { trees: 2, leaf_size: 16, seed: 1 }, 4)
            .unwrap();
        assert_eq!(a.trees, a2.trees, "same seed must give the same forest at any pool size");
    }

    #[test]
    fn leaves_partition_the_points() {
        let x = swiss(333, 17);
        let params = RpForestParams { trees: 3, leaf_size: 20, seed: 4 };
        let forest = RpForest::build(&x, &params, 2).unwrap();
        for (t, tree) in forest.trees.iter().enumerate() {
            let mut seen = vec![false; 333];
            for leaf in tree {
                assert!(leaf.len() <= 20, "tree {t}: oversized leaf");
                for &i in leaf {
                    assert!(!seen[i as usize], "tree {t}: point {i} in two leaves");
                    seen[i as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "tree {t}: point missing from partition");
        }
    }

    #[test]
    fn degenerate_params_rejected() {
        let x = swiss(64, 19);
        let err = knn_lists(&x, 5, &RpForestParams { trees: 0, leaf_size: 32, seed: 1 }, 1)
            .unwrap_err();
        assert!(format!("{err:#}").contains("T must be ≥ 1"), "{err:#}");
        let err = knn_lists(&x, 5, &RpForestParams { trees: 2, leaf_size: 5, seed: 1 }, 1)
            .unwrap_err();
        assert!(format!("{err:#}").contains("must exceed k"), "{err:#}");
        let one = Matrix::zeros(1, 3);
        assert!(RpForest::build(&one, &RpForestParams { trees: 1, leaf_size: 8, seed: 1 }, 1)
            .is_err());
    }

    #[test]
    fn constant_data_terminates() {
        // All projections tie: the id tie-break must still halve nodes.
        let x = Matrix::full(100, 4, 1.0);
        let params = RpForestParams { trees: 2, leaf_size: 8, seed: 21 };
        let (lists, _) = knn_lists(&x, 3, &params, 1).unwrap();
        assert_eq!(lists.len(), 100);
        // All distances are zero: neighbors are the smallest co-member ids.
        assert_eq!(lists[0].iter().map(|&(_, j)| j).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn recall_close_to_one_on_swiss_roll() {
        let x = swiss(1024, 23);
        let params = RpForestParams { trees: 8, leaf_size: 40, seed: 42 };
        let (lists, stats) = knn_lists(&x, 10, &params, 2).unwrap();
        let exact = baselines::brute_knn(&x, 10);
        let recall = crate::eval::recall_at_k(&lists, &exact, 10);
        assert!(recall >= 0.95, "recall@10 = {recall}");
        assert!(stats.pair_fraction() < 0.5, "must beat all-pairs");
        assert!(stats.full_fraction > 0.99);
    }
}
