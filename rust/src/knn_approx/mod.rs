//! Approximate kNN front ends — sub-quadratic candidate generation for
//! the pipeline's one remaining all-pairs stage.
//!
//! Every fit in the crate starts from per-point kNN lists
//! ([`crate::coordinator::knn::build_lists`]). The exact front end
//! computes all `n(n−1)/2` pairwise distances in blocked form — `O(n²)`
//! FLOPs and the hard ceiling on fit size once the geodesics stage is
//! sparse (`--geodesics sparse-dijkstra` needs only the lists). This
//! module provides the randomized alternative the megaman system
//! (arXiv 1603.02763) identifies as the key to manifold learning at
//! millions of points:
//!
//! * [`rpforest`] — a seeded random-projection forest: `T` trees of
//!   recursive median splits on random hyperplanes route every point to
//!   one leaf per tree; leaf co-members are the candidate set, and only
//!   candidate pairs are exactly rescored (tiled [`crate::kernels::sqdist`]
//!   kernels + [`crate::kernels::kselect`] top-k). Candidate generation is
//!   `O(T·n log n)` and rescoring `O(T·n·leaf)` — at `n = 32768` with the
//!   defaults, under 1% of the exact pair count.
//!
//! The output is the same `Vec<Vec<Neighbor>>` shape the exact stage
//! produces, bit-deterministic for any worker count (seeded
//! [`crate::util::Rng`] per tree, `total_cmp` + index tie-breaks, fixed
//! tree order), so the exact pipeline, landmark, and streaming fits all
//! consume it unchanged via the `--knn {exact|rp-forest}` fork.

pub mod rpforest;

pub use rpforest::{knn_lists, knn_lists_with_policy, RpForest, RpForestParams, RpForestStats};
