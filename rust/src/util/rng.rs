//! Deterministic pseudo-random number generation.
//!
//! `rand` is not available offline, so this implements xoshiro256++ seeded
//! via SplitMix64 — the same construction NumPy's and rand's generators are
//! built from — plus the handful of distributions the data generators need
//! (uniform, gaussian via Box–Muller, permutation via Fisher–Yates).

/// xoshiro256++ PRNG (Blackman & Vigna). Deterministic given a seed,
/// suitable for reproducible dataset generation and property tests.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box–Muller draw.
    gauss_spare: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 — used to expand a single u64 seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (polar form avoided for determinism).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] so ln is finite.
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `m` distinct indices from 0..n (reservoir when m << n).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} from {n}");
        let mut p = self.permutation(n);
        p.truncate(m);
        p.sort_unstable();
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed(7);
        let mut b = Rng::seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed(1);
        let mut b = Rng::seed(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::seed(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seed(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_unbiased_small() {
        let mut r = Rng::seed(6);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::seed(8);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for i in p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::seed(9);
        let s = r.sample_indices(1000, 50);
        assert_eq!(s.len(), 50);
        for w in s.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
