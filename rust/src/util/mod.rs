//! Small self-contained substrates the rest of the crate builds on.
//!
//! The build environment is fully offline, so facilities that would normally
//! come from crates.io (`rand`, `serde_json`, a CLI parser, a bench harness)
//! are implemented here from scratch.

pub mod cli;
pub mod fmt;
pub mod json;
pub mod rng;

pub use rng::Rng;

/// Wall-clock stopwatch with split support, used by metrics and benches.
#[derive(Debug)]
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    /// Start a new stopwatch.
    pub fn start() -> Self {
        Self { start: std::time::Instant::now() }
    }

    /// Seconds elapsed since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds elapsed since start.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Reset and return the elapsed seconds up to the reset.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = std::time::Instant::now();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::start();
        let a = sw.secs();
        let b = sw.secs();
        assert!(b >= a);
        let lap = sw.lap();
        assert!(lap >= b);
        assert!(sw.secs() < lap + 1.0);
    }
}
