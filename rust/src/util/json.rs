//! Minimal JSON reader/writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and for machine-readable experiment reports.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric content as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Array content, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // Integer-valued floats print without ".0" — except -0.0,
                // which must stay "-0" so parse → serialize → parse is
                // bit-exact (serving relies on that roundtrip).
                if x.fract() == 0.0 && x.abs() < 9e15 && (*x != 0.0 || x.is_sign_positive()) {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact JSON serialization (`to_string()` comes with it for free).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for constructing JSON reports.
impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|e| e.to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for s in ["null", "true", "false", "42", "-1.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"ops":[{"name":"minplus","b":128}],"version":1}"#).unwrap();
        let ops = v.get("ops").unwrap().as_arr().unwrap();
        assert_eq!(ops[0].get("name").unwrap().as_str(), Some("minplus"));
        assert_eq!(ops[0].get("b").unwrap().as_usize(), Some(128));
        assert_eq!(v.get("version").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn negative_zero_roundtrips_bit_exact() {
        let v = Json::Num(-0.0);
        assert_eq!(v.to_string(), "-0");
        let back = Json::parse(&v.to_string()).unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
        // Positive zero still prints as a plain integer.
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ Aüñ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ Aüñ");
        let rt = Json::parse(&v.to_string()).unwrap();
        assert_eq!(rt, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
