//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; produces helpful errors and a usage string.

use std::collections::BTreeMap;

/// Parsed arguments: options by name plus positionals in order.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates option parsing.
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("option --{rest} expects a value"))?;
                    out.opts.insert(rest.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Value of `--key`, if present.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("invalid value {s:?} for --{key}")),
        }
    }

    /// Whether a boolean `--flag` was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// All provided option keys (for unknown-option validation).
    pub fn opt_keys(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(|s| s.as_str())
    }

    /// Error if any provided option is not in the allowed set.
    pub fn reject_unknown(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.opt_keys() {
            if !allowed.contains(&k) {
                return Err(format!("unknown option --{k} (allowed: {})", allowed.join(", ")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(s(&["run", "--n", "100", "--verbose", "--b=64", "x"]), &["verbose"]).unwrap();
        assert_eq!(a.positional(), &["run".to_string(), "x".to_string()]);
        assert_eq!(a.opt("n"), Some("100"));
        assert_eq!(a.opt("b"), Some("64"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(s(&["--k", "12"]), &[]).unwrap();
        assert_eq!(a.get("k", 10usize).unwrap(), 12);
        assert_eq!(a.get("d", 2usize).unwrap(), 2);
        assert!(a.get::<usize>("k", 0).is_ok());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(s(&["--n"]), &[]).is_err());
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = Args::parse(s(&["--k", "abc"]), &[]).unwrap();
        assert!(a.get::<usize>("k", 1).is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = Args::parse(s(&["--a", "1", "--", "--b", "2"]), &[]).unwrap();
        assert_eq!(a.opt("a"), Some("1"));
        assert_eq!(a.positional(), &["--b".to_string(), "2".to_string()]);
    }

    #[test]
    fn reject_unknown_works() {
        let a = Args::parse(s(&["--zzz", "1"]), &[]).unwrap();
        assert!(a.reject_unknown(&["n", "k"]).is_err());
        let b = Args::parse(s(&["--n", "1"]), &[]).unwrap();
        assert!(b.reject_unknown(&["n", "k"]).is_ok());
    }
}
