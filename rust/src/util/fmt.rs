//! Human-readable formatting helpers for reports and tables.

/// Format seconds as the paper's tables do (minutes with 2 decimals) when
/// large, falling back to seconds/milliseconds for small quantities.
pub fn human_duration(secs: f64) -> String {
    if secs >= 60.0 {
        format!("{:.2} min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2} s")
    } else {
        format!("{:.2} ms", secs * 1e3)
    }
}

/// Format a byte count with binary units.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = bytes as f64;
    let mut u = 0;
    while x >= 1024.0 && u + 1 < UNITS.len() {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{x:.2} {}", UNITS[u])
    }
}

/// Simple monospace table renderer: pads each column to its widest cell.
/// The first row is treated as the header and underlined.
pub fn render_table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let ncols = rows.iter().map(|r| r.len()).max().unwrap_or(0);
    let mut widths = vec![0usize; ncols];
    for row in rows {
        for (c, cell) in row.iter().enumerate() {
            widths[c] = widths[c].max(cell.chars().count());
        }
    }
    let mut out = String::new();
    for (i, row) in rows.iter().enumerate() {
        let mut line = String::new();
        for (c, cell) in row.iter().enumerate() {
            if c > 0 {
                line.push_str("  ");
            }
            let pad = widths[c] - cell.chars().count();
            line.push_str(cell);
            line.push_str(&" ".repeat(pad));
        }
        out.push_str(line.trim_end());
        out.push('\n');
        if i == 0 {
            let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(human_duration(120.0), "2.00 min");
        assert_eq!(human_duration(2.5), "2.50 s");
        assert_eq!(human_duration(0.0125), "12.50 ms");
    }

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn table_alignment() {
        let t = render_table(&[
            vec!["name".into(), "time".into()],
            vec!["swiss50".into(), "1.0".into()],
            vec!["emnist125".into(), "2.0".into()],
        ]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("swiss50"));
    }
}
