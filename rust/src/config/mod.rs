//! Typed configuration: Isomap hyper-parameters, cluster topology, and the
//! INI-style config-file loader used by the launcher (`isospark run
//! --config cluster.toml`). A hand-rolled parser (serde/toml are not
//! available offline) supporting `[section]`, `key = value`, and comments.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// How the exact pipeline computes geodesic distances (config key
/// `geodesics` in the `isomap` section; CLI `--geodesics`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeodesicsMode {
    /// The paper's dense blocked Floyd–Warshall APSP: `O(n³)` work over
    /// the `∞`-filled neighborhood blocks.
    DenseFw,
    /// CSR graph + pooled multi-source Dijkstra (`crate::graph`):
    /// `O(n·(n + nk) log n)` work, no dense APSP RDD — the path that
    /// stays feasible when an `n × n` matrix no longer fits in memory.
    SparseDijkstra,
}

impl GeodesicsMode {
    /// Canonical config/CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            GeodesicsMode::DenseFw => "dense-fw",
            GeodesicsMode::SparseDijkstra => "sparse-dijkstra",
        }
    }

    /// One-line human description for run reports.
    pub fn describe(self) -> &'static str {
        match self {
            GeodesicsMode::DenseFw => "dense-fw (blocked Floyd–Warshall over dense blocks)",
            GeodesicsMode::SparseDijkstra => {
                "sparse-dijkstra (CSR graph + pooled multi-source Dijkstra; no dense APSP RDD)"
            }
        }
    }
}

impl std::fmt::Display for GeodesicsMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for GeodesicsMode {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "dense-fw" | "dense" | "fw" => Ok(GeodesicsMode::DenseFw),
            "sparse-dijkstra" | "sparse" | "dijkstra" => Ok(GeodesicsMode::SparseDijkstra),
            other => Err(format!("unknown geodesics mode {other:?} (dense-fw|sparse-dijkstra)")),
        }
    }
}

/// How the kNN lists every fit starts from are computed (config key `knn`
/// in the `isomap` section; CLI `--knn`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnnMode {
    /// All-pairs blocked distance stage: `n(n−1)/2` exact distances,
    /// `O(n²)` FLOPs — the reference answer, and the paper's only option.
    Exact,
    /// Seeded random-projection forest ([`crate::knn_approx`]): only leaf
    /// co-member pairs are exactly rescored — `O(T·n·leaf)` FLOPs, the
    /// sub-quadratic front end that, with `--geodesics sparse-dijkstra`,
    /// removes the last `O(n²)` stage from the pipeline.
    RpForest,
}

impl KnnMode {
    /// Canonical config/CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            KnnMode::Exact => "exact",
            KnnMode::RpForest => "rp-forest",
        }
    }

    /// One-line human description for run reports.
    pub fn describe(self) -> &'static str {
        match self {
            KnnMode::Exact => "exact (all-pairs blocked distance stage)",
            KnnMode::RpForest => {
                "rp-forest (random-projection forest candidates, exact rescoring)"
            }
        }
    }
}

impl std::fmt::Display for KnnMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for KnnMode {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "exact" | "brute" | "all-pairs" => Ok(KnnMode::Exact),
            "rp-forest" | "rpforest" | "forest" => Ok(KnnMode::RpForest),
            other => Err(format!("unknown knn mode {other:?} (exact|rp-forest)")),
        }
    }
}

/// How the squared-geodesic feature matrix is held through centering and
/// power iteration (config key `feature` in the `isomap` section; CLI
/// `--feature`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureMode {
    /// Keep all `q(q+1)/2` upper-triangular blocks resident — the paper's
    /// layout, `O(n²)` memory, the reference semantics.
    Materialized,
    /// Stream `b × n` geodesic row panels on demand from the CSR graph
    /// (`crate::coordinator::panels`): `O(n·k + b·n)` peak memory, one
    /// Dijkstra sweep (or durable-spill re-read) per power iteration.
    /// Requires `--geodesics sparse-dijkstra`.
    Implicit,
}

impl FeatureMode {
    /// Canonical config/CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            FeatureMode::Materialized => "materialized",
            FeatureMode::Implicit => "implicit",
        }
    }

    /// One-line human description for run reports.
    pub fn describe(self) -> &'static str {
        match self {
            FeatureMode::Materialized => "materialized (resident upper-triangular blocks)",
            FeatureMode::Implicit => {
                "implicit (geodesic panels recomputed/spilled per iteration; O(n·k + b·n) memory)"
            }
        }
    }
}

impl std::fmt::Display for FeatureMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for FeatureMode {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "materialized" | "resident" | "dense" => Ok(FeatureMode::Materialized),
            "implicit" | "panels" | "streamed" => Ok(FeatureMode::Implicit),
            other => Err(format!("unknown feature mode {other:?} (materialized|implicit)")),
        }
    }
}

/// Isomap algorithm parameters (paper Alg. 1 + §IV defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct IsomapConfig {
    /// Neighborhood size (paper: k = 10).
    pub k: usize,
    /// Target dimensionality (paper: d = 2 for visualization).
    pub d: usize,
    /// Logical block size b (paper sweet spot 1000–2500 at n < 100k;
    /// laptop-scale default 128).
    pub block: usize,
    /// Power-iteration convergence threshold (paper: 1e-9).
    pub tol: f64,
    /// Power-iteration max iterations (paper: 100).
    pub max_iter: usize,
    /// Checkpoint the APSP lineage every this many diagonal iterations
    /// (paper: 10). 0 disables checkpointing.
    pub checkpoint_every: usize,
    /// Random seed used by data generators / landmark selection.
    pub seed: u64,
    /// Geodesic-distance backend of the exact pipeline (the approximate
    /// landmark / streaming fits always use the sparse Dijkstra path).
    pub geodesics: GeodesicsMode,
    /// kNN front end: exact all-pairs or the rp-forest approximate index.
    /// Every fit (exact, landmark, streaming) honors this.
    pub knn: KnnMode,
    /// rp-forest tree count `T` (more trees → higher recall, more FLOPs).
    pub rp_trees: usize,
    /// rp-forest leaf-size bound. `0` (the default) resolves to
    /// `max(4k, 32)` — empirically ≥ 0.99 recall@10 on swiss-roll at the
    /// default tree count; see [`IsomapConfig::rp_leaf_resolved`].
    pub rp_leaf: usize,
    /// Feature-matrix residency through centering + power iteration:
    /// materialized blocks (the default) or streamed geodesic panels.
    pub feature: FeatureMode,
}

impl Default for IsomapConfig {
    fn default() -> Self {
        Self {
            k: 10,
            d: 2,
            block: 128,
            tol: 1e-9,
            max_iter: 100,
            checkpoint_every: 10,
            seed: 42,
            geodesics: GeodesicsMode::DenseFw,
            knn: KnnMode::Exact,
            rp_trees: 8,
            rp_leaf: 0,
            feature: FeatureMode::Materialized,
        }
    }
}

impl IsomapConfig {
    /// Validate parameter sanity against a dataset size.
    pub fn validate(&self, n: usize) -> Result<()> {
        if self.k == 0 || self.k >= n {
            bail!("k={} must be in 1..n={n}", self.k);
        }
        if self.d == 0 || self.d > n {
            bail!("d={} must be in 1..=n", self.d);
        }
        if self.block == 0 {
            bail!("block size must be positive");
        }
        if self.tol <= 0.0 || self.tol.is_nan() {
            bail!("tol must be positive");
        }
        if self.max_iter == 0 {
            bail!("max_iter must be positive");
        }
        if self.knn == KnnMode::RpForest {
            if self.rp_trees == 0 {
                bail!("rp_trees must be ≥ 1 for --knn rp-forest");
            }
            let leaf = self.rp_leaf_resolved();
            if leaf <= self.k {
                bail!(
                    "rp_leaf={leaf} must exceed k={} (a leaf holds a point plus its \
                     candidates; rp_leaf = 0 selects the automatic default)",
                    self.k
                );
            }
        }
        if self.feature == FeatureMode::Implicit && self.geodesics != GeodesicsMode::SparseDijkstra
        {
            bail!(
                "--feature implicit requires --geodesics sparse-dijkstra (panels are \
                 recomputed from the CSR graph; dense-fw materializes every block anyway)"
            );
        }
        Ok(())
    }

    /// The effective rp-forest leaf-size bound: `rp_leaf` itself when set,
    /// otherwise `max(4k, 32)` — roughly 4 candidate co-members per wanted
    /// neighbor, the knee of the recall/FLOP curve on the swiss-roll
    /// benchmarks (leaf 32 → 0.999 recall@10, leaf 64 → 1.000 at T = 8).
    pub fn rp_leaf_resolved(&self) -> usize {
        if self.rp_leaf == 0 {
            (4 * self.k).max(32)
        } else {
            self.rp_leaf
        }
    }
}

/// Simulated cluster topology (paper §IV testbed: 25 nodes, 20 cores,
/// GbE, one executor per node, 56 GB heap).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Number of executor nodes.
    pub nodes: usize,
    /// Cores per executor (degree of intra-node task parallelism).
    pub cores_per_node: usize,
    /// Network bandwidth per link, bytes/second (GbE ≈ 117 MiB/s effective).
    pub net_bandwidth: f64,
    /// Per-message network latency, seconds.
    pub net_latency: f64,
    /// Driver scheduling overhead charged per task, seconds. Models the
    /// Spark driver cost that grows with lineage (paper §III-B).
    pub sched_overhead: f64,
    /// Executor memory in bytes (56 GB in the paper); the engine fails a
    /// run whose resident blocks exceed node capacity, reproducing the "-"
    /// (impossible) entries of Table I.
    pub mem_per_node: u64,
    /// Local disk bandwidth (bytes/s) charged by `checkpoint()` — the
    /// paper's nodes have standard SATA drives. Creates the checkpoint
    /// cadence trade-off (§III-B: every 10 iterations performed best).
    pub disk_bandwidth: f64,
    /// Multiplier from measured single-core seconds on *this* machine to
    /// virtual seconds on one simulated core (calibration knob).
    pub compute_scale: f64,
    /// OS worker threads executing real block tasks — the *physical*
    /// executor pool, independent of the simulated `nodes × cores_per_node`
    /// topology. `0` = use all available cores. Numerical results, record
    /// order, lineage/metrics structure and shuffle bytes are bit-identical
    /// for any value (enforced by the determinism test suite). Virtual-time
    /// figures are replayed from *measured* task durations, so they vary
    /// run to run as they always have — and core contention under a large
    /// pool can inflate them; use `parallelism = 1` (or `compute_scale`
    /// recalibration) when reproducing calibrated Table-I-style numbers.
    pub parallelism: usize,
    /// Live fault-injection rate in `[0, 1]` (`[fault] rate`,
    /// `--fault-rate`): probability that a task's first attempt is served
    /// an injected panic or transient error by the seeded
    /// [`crate::engine::fault::FaultPlan`]. `0.0` (the default) installs
    /// no plan at all — every stage runs the plain fast path. Injection is
    /// a pure function of `(fault_seed, stage, task, attempt)`, so the
    /// output stays bit-identical to the fault-free run at any rate.
    pub fault_rate: f64,
    /// Seed of the deterministic fault schedule (`[fault] seed`,
    /// `--fault-seed`).
    pub fault_seed: u64,
    /// Attempt ceiling per task under injection (`[fault] max_attempts`,
    /// `--max-attempts`); exhausting it fails the stage with the original
    /// payload annotated with stage name and attempt count.
    pub fault_max_attempts: usize,
    /// Durable checkpoint directory (`[fault] checkpoint_dir`,
    /// `--checkpoint-dir`): when set, `checkpoint()` spills RDD blocks to
    /// checksummed files under this directory and the APSP / streaming
    /// fits restore from the latest valid checkpoint on startup. `None`
    /// keeps checkpoints purely simulated (virtual disk charge only).
    pub checkpoint_dir: Option<String>,
    /// Real worker processes (`[dist] workers`, `--workers
    /// host:port,...`): when non-empty, the sparse geodesic panel stage
    /// executes on these `isospark worker` processes over the TCP
    /// block-shuffle transport instead of the in-process pool. Requires
    /// `--geodesics sparse-dijkstra` with the materialized feature path.
    /// Empty (the default) keeps the run single-process. Worker count
    /// never changes output bits — only wall-clock.
    pub dist_workers: Vec<String>,
    /// Per-response deadline on the dist transport, seconds (`[dist]
    /// task_timeout_secs`). A worker holding a task longer is treated as
    /// dead and its tasks are retried elsewhere.
    pub dist_task_timeout_secs: f64,
    /// Worker connect + handshake deadline, seconds (`[dist]
    /// connect_timeout_secs`). Unlike mid-run losses, a worker that is
    /// unreachable at startup fails the run — that is a config error.
    pub dist_connect_timeout_secs: f64,
}

impl ClusterConfig {
    /// Local mode: a single executor, zero-cost network — used for
    /// correctness runs where virtual time does not matter.
    pub fn local() -> Self {
        Self {
            nodes: 1,
            cores_per_node: 1,
            net_bandwidth: f64::INFINITY,
            net_latency: 0.0,
            sched_overhead: 0.0,
            mem_per_node: u64::MAX,
            disk_bandwidth: f64::INFINITY,
            compute_scale: 1.0,
            parallelism: 1,
            fault_rate: 0.0,
            fault_seed: 0,
            fault_max_attempts: crate::engine::fault::DEFAULT_MAX_ATTEMPTS,
            checkpoint_dir: None,
            dist_workers: Vec::new(),
            dist_task_timeout_secs: 60.0,
            dist_connect_timeout_secs: 5.0,
        }
    }

    /// The paper's testbed with `nodes` executors: 20-core Xeon E5v3 nodes,
    /// gigabit Ethernet, 56 GB executor heap.
    pub fn paper_testbed(nodes: usize) -> Self {
        Self {
            nodes,
            cores_per_node: 20,
            net_bandwidth: 117.0e6, // effective GbE payload rate
            net_latency: 250e-6,    // typical GbE + JVM serialization setup
            sched_overhead: 3e-3,   // Spark driver per-task scheduling cost
            mem_per_node: 56 * (1u64 << 30),
            disk_bandwidth: 100.0e6, // SATA HDD sequential
            compute_scale: 1.0,
            parallelism: 0, // physical pool: all available cores
            fault_rate: 0.0,
            fault_seed: 0,
            fault_max_attempts: crate::engine::fault::DEFAULT_MAX_ATTEMPTS,
            checkpoint_dir: None,
            dist_workers: Vec::new(),
            dist_task_timeout_secs: 60.0,
            dist_connect_timeout_secs: 5.0,
        }
    }

    /// Total cores across the cluster.
    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node
    }
}

/// Raw INI-ish file: sections of key/value pairs.
#[derive(Debug, Default)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse from text. Lines: `[section]`, `key = value`, `# comment`.
    pub fn parse(text: &str) -> Result<Self> {
        let mut out = RawConfig::default();
        let mut current = String::from("global");
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                current = name.trim().to_string();
            } else if let Some((k, v)) = line.split_once('=') {
                out.sections
                    .entry(current.clone())
                    .or_default()
                    .insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
            } else {
                bail!("line {}: expected `key = value`, got {raw:?}", lineno + 1);
            }
        }
        Ok(out)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }

    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    fn typed<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> Result<T> {
        match self.get(section, key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value {s:?} for {section}.{key}")),
        }
    }

    /// Materialize an [`IsomapConfig`], starting from defaults.
    pub fn isomap(&self) -> Result<IsomapConfig> {
        let d = IsomapConfig::default();
        Ok(IsomapConfig {
            k: self.typed("isomap", "k", d.k)?,
            d: self.typed("isomap", "d", d.d)?,
            block: self.typed("isomap", "block", d.block)?,
            tol: self.typed("isomap", "tol", d.tol)?,
            max_iter: self.typed("isomap", "max_iter", d.max_iter)?,
            checkpoint_every: self.typed("isomap", "checkpoint_every", d.checkpoint_every)?,
            seed: self.typed("isomap", "seed", d.seed)?,
            geodesics: self.typed("isomap", "geodesics", d.geodesics)?,
            knn: self.typed("isomap", "knn", d.knn)?,
            rp_trees: self.typed("isomap", "rp_trees", d.rp_trees)?,
            rp_leaf: self.typed("isomap", "rp_leaf", d.rp_leaf)?,
            feature: self.typed("isomap", "feature", d.feature)?,
        })
    }

    /// Materialize a [`ClusterConfig`], starting from the paper testbed.
    pub fn cluster(&self) -> Result<ClusterConfig> {
        let d = ClusterConfig::paper_testbed(4);
        Ok(ClusterConfig {
            nodes: self.typed("cluster", "nodes", d.nodes)?,
            cores_per_node: self.typed("cluster", "cores_per_node", d.cores_per_node)?,
            net_bandwidth: self.typed("cluster", "net_bandwidth", d.net_bandwidth)?,
            net_latency: self.typed("cluster", "net_latency", d.net_latency)?,
            sched_overhead: self.typed("cluster", "sched_overhead", d.sched_overhead)?,
            mem_per_node: self.typed("cluster", "mem_per_node", d.mem_per_node)?,
            disk_bandwidth: self.typed("cluster", "disk_bandwidth", d.disk_bandwidth)?,
            compute_scale: self.typed("cluster", "compute_scale", d.compute_scale)?,
            parallelism: self.typed("cluster", "parallelism", d.parallelism)?,
            fault_rate: self.typed("fault", "rate", d.fault_rate)?,
            fault_seed: self.typed("fault", "seed", d.fault_seed)?,
            fault_max_attempts: self.typed("fault", "max_attempts", d.fault_max_attempts)?,
            checkpoint_dir: self.get("fault", "checkpoint_dir").map(str::to_string),
            dist_workers: self
                .get("dist", "workers")
                .map(parse_worker_list)
                .unwrap_or_default(),
            dist_task_timeout_secs: self.typed(
                "dist",
                "task_timeout_secs",
                d.dist_task_timeout_secs,
            )?,
            dist_connect_timeout_secs: self.typed(
                "dist",
                "connect_timeout_secs",
                d.dist_connect_timeout_secs,
            )?,
        })
    }

    /// Assemble a [`ServeConfig`] from the `[serve]` section (validated).
    pub fn serve(&self) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        let cfg = ServeConfig {
            host: self.get("serve", "host").unwrap_or(&d.host).to_string(),
            port: self.typed("serve", "port", d.port)?,
            threads: self.typed("serve", "threads", d.threads)?,
            threads_min: self.typed("serve", "threads_min", d.threads_min)?,
            threads_max: self.typed("serve", "threads_max", d.threads_max)?,
            max_batch: self.typed("serve", "max_batch", d.max_batch)?,
            batch_min: self.typed("serve", "batch_min", d.batch_min)?,
            target_p95_ms: self.typed("serve", "target_p95_ms", d.target_p95_ms)?,
            max_queue: self.typed("serve", "max_queue", d.max_queue)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Configuration of the serve tier (`isospark serve` / `[serve]` section).
///
/// Two knob families layer over the legacy fixed-pool shape:
///
/// * **Pool autoscaling** — when `threads_max > 0` the worker pool floats
///   between `threads_min..=threads_max` driven by queue depth and
///   arrival rate; `threads` is ignored. When `threads_max == 0`
///   (default) the pool is fixed at `threads` workers (0 = all cores),
///   exactly the pre-autoscaling behavior.
/// * **Adaptive micro-batching** — when `target_p95_ms > 0` the batch
///   executor's drain cap floats between `batch_min..=max_batch`,
///   shrinking while the windowed embed p95 is over target and growing
///   while it is under half the target. `target_p95_ms == 0` pins the
///   cap at `max_batch` (the pre-adaptive behavior).
///
/// Neither knob can change output bits: batch composition and pool size
/// are invisible to `FittedModel::map_points_with`.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Interface to bind.
    pub host: String,
    /// TCP port; 0 picks an ephemeral port.
    pub port: u16,
    /// Fixed pool size when autoscaling is off (0 = all cores).
    pub threads: usize,
    /// Autoscale lower bound (only meaningful when `threads_max > 0`).
    pub threads_min: usize,
    /// Autoscale upper bound; 0 disables autoscaling.
    pub threads_max: usize,
    /// Ceiling on points drained into one pooled `map_points` call.
    pub max_batch: usize,
    /// Floor of the adaptive drain cap.
    pub batch_min: usize,
    /// Embed-latency p95 target (ms) for adaptive batching; 0 disables.
    pub target_p95_ms: f64,
    /// Accept-queue bound: queued embeds beyond this are shed. 0 sheds
    /// every embed (useful to drain a replica out of rotation).
    pub max_queue: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            host: "127.0.0.1".to_string(),
            port: 0,
            threads: 0,
            threads_min: 0,
            threads_max: 0,
            max_batch: 1024,
            batch_min: 32,
            target_p95_ms: 50.0,
            max_queue: 4096,
        }
    }
}

impl ServeConfig {
    /// Resolved `(min, max)` worker-pool bounds. Fixed-pool mode
    /// collapses both to the resolved `threads` count.
    pub fn pool_bounds(&self) -> (usize, usize) {
        if self.threads_max > 0 {
            let min = self.threads_min.max(1);
            (min, self.threads_max.max(min))
        } else {
            let w = crate::engine::executor::resolve_workers(self.threads);
            (w, w)
        }
    }

    /// Reject contradictory knob combinations before binding a socket.
    pub fn validate(&self) -> Result<()> {
        if self.threads_max > 0 && self.threads_min > self.threads_max {
            anyhow::bail!(
                "serve: threads_min ({}) must be <= threads_max ({})",
                self.threads_min,
                self.threads_max
            );
        }
        if self.max_batch == 0 {
            anyhow::bail!("serve: max_batch must be >= 1");
        }
        if self.batch_min == 0 || self.batch_min > self.max_batch {
            anyhow::bail!(
                "serve: batch_min ({}) must be in 1..=max_batch ({})",
                self.batch_min,
                self.max_batch
            );
        }
        if !self.target_p95_ms.is_finite() || self.target_p95_ms < 0.0 {
            anyhow::bail!("serve: target_p95_ms must be finite and >= 0");
        }
        Ok(())
    }
}

/// Split a `host:port,host:port,...` list (config `[dist] workers` /
/// `--workers`), dropping empty entries so trailing commas are harmless.
pub fn parse_worker_list(s: &str) -> Vec<String> {
    s.split(',').map(|w| w.trim().to_string()).filter(|w| !w.is_empty()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = IsomapConfig::default();
        assert_eq!(c.k, 10);
        assert_eq!(c.tol, 1e-9);
        assert_eq!(c.max_iter, 100);
        assert_eq!(c.checkpoint_every, 10);
    }

    #[test]
    fn validation() {
        let c = IsomapConfig::default();
        assert!(c.validate(1000).is_ok());
        assert!(c.validate(5).is_err()); // k >= n
        let bad = IsomapConfig { block: 0, ..Default::default() };
        assert!(bad.validate(1000).is_err());
        let bad_tol = IsomapConfig { tol: 0.0, ..Default::default() };
        assert!(bad_tol.validate(1000).is_err());
    }

    #[test]
    fn parse_ini() {
        let raw = RawConfig::parse(
            "# comment\n[isomap]\nk = 12\nblock=256\n[cluster]\nnodes = 8\ncores_per_node = 4\n",
        )
        .unwrap();
        let iso = raw.isomap().unwrap();
        assert_eq!(iso.k, 12);
        assert_eq!(iso.block, 256);
        assert_eq!(iso.d, 2); // default survives
        let cl = raw.cluster().unwrap();
        assert_eq!(cl.nodes, 8);
        assert_eq!(cl.cores_per_node, 4);
    }

    #[test]
    fn serve_section_overrides_defaults() {
        let raw = RawConfig::parse(
            "[serve]\nport = 8088\nthreads_min = 2\nthreads_max = 8\nbatch_min = 16\n\
             max_batch = 512\ntarget_p95_ms = 25.5\nmax_queue = 100\n",
        )
        .unwrap();
        let s = raw.serve().unwrap();
        assert_eq!(s.port, 8088);
        assert_eq!(s.pool_bounds(), (2, 8));
        assert_eq!(s.batch_min, 16);
        assert_eq!(s.max_batch, 512);
        assert_eq!(s.target_p95_ms, 25.5);
        assert_eq!(s.max_queue, 100);
        assert_eq!(s.host, "127.0.0.1"); // default survives
    }

    #[test]
    fn serve_validation_rejects_contradictions() {
        let base = ServeConfig::default();
        assert!(base.validate().is_ok());
        let bad = ServeConfig { threads_min: 8, threads_max: 2, ..base.clone() };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { batch_min: 0, ..base.clone() };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { batch_min: 2048, max_batch: 1024, ..base.clone() };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { target_p95_ms: f64::NAN, ..base.clone() };
        assert!(bad.validate().is_err());
        let raw = RawConfig::parse("[serve]\nthreads_min = 9\nthreads_max = 3\n").unwrap();
        assert!(raw.serve().is_err());
    }

    #[test]
    fn serve_pool_bounds_fixed_mode_collapses() {
        let s = ServeConfig { threads: 3, ..Default::default() };
        assert_eq!(s.pool_bounds(), (3, 3));
        let auto = ServeConfig { threads_max: 6, ..Default::default() };
        let (lo, hi) = auto.pool_bounds();
        assert_eq!((lo, hi), (1, 6));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(RawConfig::parse("[unterminated\n").is_err());
        assert!(RawConfig::parse("not a kv line\n").is_err());
    }

    #[test]
    fn bad_typed_value() {
        let raw = RawConfig::parse("[isomap]\nk = banana\n").unwrap();
        assert!(raw.isomap().is_err());
    }

    #[test]
    fn local_cluster_free_network() {
        let c = ClusterConfig::local();
        assert_eq!(c.nodes, 1);
        assert_eq!(c.net_latency, 0.0);
        assert_eq!(c.parallelism, 1); // local correctness runs stay sequential
        assert_eq!(ClusterConfig::paper_testbed(25).total_cores(), 500);
        assert_eq!(ClusterConfig::paper_testbed(25).parallelism, 0); // auto
    }

    #[test]
    fn geodesics_mode_parses() {
        assert_eq!(IsomapConfig::default().geodesics, GeodesicsMode::DenseFw);
        let raw = RawConfig::parse("[isomap]\ngeodesics = sparse-dijkstra\n").unwrap();
        assert_eq!(raw.isomap().unwrap().geodesics, GeodesicsMode::SparseDijkstra);
        let raw = RawConfig::parse("[isomap]\ngeodesics = dense-fw\n").unwrap();
        assert_eq!(raw.isomap().unwrap().geodesics, GeodesicsMode::DenseFw);
        assert!(RawConfig::parse("[isomap]\ngeodesics = bogus\n").unwrap().isomap().is_err());
        assert_eq!("sparse".parse::<GeodesicsMode>().unwrap(), GeodesicsMode::SparseDijkstra);
        assert_eq!(GeodesicsMode::SparseDijkstra.to_string(), "sparse-dijkstra");
    }

    #[test]
    fn knn_mode_parses() {
        assert_eq!(IsomapConfig::default().knn, KnnMode::Exact);
        let raw = RawConfig::parse("[isomap]\nknn = rp-forest\nrp_trees = 12\nrp_leaf = 64\n")
            .unwrap();
        let iso = raw.isomap().unwrap();
        assert_eq!(iso.knn, KnnMode::RpForest);
        assert_eq!(iso.rp_trees, 12);
        assert_eq!(iso.rp_leaf, 64);
        let raw = RawConfig::parse("[isomap]\nknn = exact\n").unwrap();
        assert_eq!(raw.isomap().unwrap().knn, KnnMode::Exact);
        assert!(RawConfig::parse("[isomap]\nknn = bogus\n").unwrap().isomap().is_err());
        assert!(RawConfig::parse("[isomap]\nrp_trees = -3\n").unwrap().isomap().is_err());
        assert_eq!("rpforest".parse::<KnnMode>().unwrap(), KnnMode::RpForest);
        assert_eq!(KnnMode::RpForest.to_string(), "rp-forest");
    }

    #[test]
    fn feature_mode_parses() {
        assert_eq!(IsomapConfig::default().feature, FeatureMode::Materialized);
        let raw =
            RawConfig::parse("[isomap]\nfeature = implicit\ngeodesics = sparse-dijkstra\n")
                .unwrap();
        let iso = raw.isomap().unwrap();
        assert_eq!(iso.feature, FeatureMode::Implicit);
        assert!(RawConfig::parse("[isomap]\nfeature = bogus\n").unwrap().isomap().is_err());
        assert_eq!("panels".parse::<FeatureMode>().unwrap(), FeatureMode::Implicit);
        assert_eq!(FeatureMode::Implicit.to_string(), "implicit");
    }

    #[test]
    fn implicit_feature_requires_sparse_geodesics() {
        let cfg = IsomapConfig { feature: FeatureMode::Implicit, ..Default::default() };
        let err = cfg.validate(100).unwrap_err();
        assert!(err.to_string().contains("sparse-dijkstra"), "{err}");
        let ok = IsomapConfig {
            feature: FeatureMode::Implicit,
            geodesics: GeodesicsMode::SparseDijkstra,
            ..Default::default()
        };
        assert!(ok.validate(100).is_ok());
    }

    #[test]
    fn rp_leaf_resolution_and_validation() {
        let c = IsomapConfig { knn: KnnMode::RpForest, ..Default::default() };
        assert_eq!(c.rp_leaf_resolved(), 40); // max(4·10, 32)
        let small_k = IsomapConfig { k: 3, knn: KnnMode::RpForest, ..Default::default() };
        assert_eq!(small_k.rp_leaf_resolved(), 32); // floor kicks in
        let explicit = IsomapConfig { rp_leaf: 100, ..c.clone() };
        assert_eq!(explicit.rp_leaf_resolved(), 100);
        assert!(c.validate(1000).is_ok());
        // Degenerate forest shapes are rejected up front.
        let no_trees = IsomapConfig { rp_trees: 0, ..c.clone() };
        assert!(no_trees.validate(1000).is_err());
        let tiny_leaf = IsomapConfig { rp_leaf: 10, ..c.clone() };
        assert!(tiny_leaf.validate(1000).is_err());
        // ... but only when the rp-forest path is actually selected.
        let exact = IsomapConfig { rp_trees: 0, rp_leaf: 1, ..Default::default() };
        assert!(exact.validate(1000).is_ok());
    }

    #[test]
    fn parallelism_key_parses() {
        let raw = RawConfig::parse("[cluster]\nnodes = 2\nparallelism = 6\n").unwrap();
        assert_eq!(raw.cluster().unwrap().parallelism, 6);
    }

    #[test]
    fn fault_section_parses_with_safe_defaults() {
        // Defaults: injection off, no durable checkpoint directory.
        let none = RawConfig::parse("[cluster]\nnodes = 2\n").unwrap().cluster().unwrap();
        assert_eq!(none.fault_rate, 0.0);
        assert_eq!(none.fault_seed, 0);
        assert_eq!(none.fault_max_attempts, crate::engine::fault::DEFAULT_MAX_ATTEMPTS);
        assert_eq!(none.checkpoint_dir, None);

        let raw = RawConfig::parse(
            "[fault]\nrate = 0.25\nseed = 7\nmax_attempts = 3\ncheckpoint_dir = /tmp/ckpt\n",
        )
        .unwrap();
        let cl = raw.cluster().unwrap();
        assert_eq!(cl.fault_rate, 0.25);
        assert_eq!(cl.fault_seed, 7);
        assert_eq!(cl.fault_max_attempts, 3);
        assert_eq!(cl.checkpoint_dir.as_deref(), Some("/tmp/ckpt"));

        let bad = RawConfig::parse("[fault]\nrate = often\n").unwrap();
        assert!(bad.cluster().is_err());
    }

    #[test]
    fn dist_section_parses_with_single_process_default() {
        let none = RawConfig::parse("[cluster]\nnodes = 2\n").unwrap().cluster().unwrap();
        assert!(none.dist_workers.is_empty());
        assert_eq!(none.dist_task_timeout_secs, 60.0);
        assert_eq!(none.dist_connect_timeout_secs, 5.0);

        let raw = RawConfig::parse(
            "[dist]\nworkers = 127.0.0.1:7001, 127.0.0.1:7002,\ntask_timeout_secs = 12.5\n\
             connect_timeout_secs = 2\n",
        )
        .unwrap();
        let cl = raw.cluster().unwrap();
        assert_eq!(cl.dist_workers, vec!["127.0.0.1:7001", "127.0.0.1:7002"]);
        assert_eq!(cl.dist_task_timeout_secs, 12.5);
        assert_eq!(cl.dist_connect_timeout_secs, 2.0);
        // The flag-side parser is the same function: trailing commas and
        // stray whitespace never become empty worker addresses.
        assert_eq!(parse_worker_list(" a:1 ,, b:2, "), vec!["a:1", "b:2"]);
    }
}
