//! Interconnect model for the simulated cluster.
//!
//! The paper's testbed is gigabit Ethernet; shuffles dominated their tuning
//! decisions (§III-B: combineByKey vs collect/broadcast vs HDFS). The
//! model charges each node's NIC for the bytes it sends/receives — links
//! run in parallel across nodes but a node's own traffic serializes — plus
//! a per-message latency that models TCP/serialization setup.

use crate::config::ClusterConfig;

/// Per-shuffle traffic summary used for charging time.
#[derive(Clone, Debug, Default)]
pub struct Traffic {
    /// Bytes entering each node.
    pub in_bytes: Vec<u64>,
    /// Bytes leaving each node.
    pub out_bytes: Vec<u64>,
    /// Number of distinct messages (records crossing nodes).
    pub messages: u64,
}

impl Traffic {
    pub fn new(nodes: usize) -> Self {
        Self { in_bytes: vec![0; nodes], out_bytes: vec![0; nodes], messages: 0 }
    }

    /// Record one record moving `src → dst` (no cost when co-located).
    pub fn record(&mut self, src: usize, dst: usize, bytes: u64) {
        if src != dst {
            self.out_bytes[src] += bytes;
            self.in_bytes[dst] += bytes;
            self.messages += 1;
        }
    }

    /// Total bytes crossing the network.
    pub fn total(&self) -> u64 {
        self.in_bytes.iter().sum()
    }
}

/// The network model itself (parameters come from [`ClusterConfig`]).
#[derive(Clone, Debug)]
pub struct NetworkModel {
    bandwidth: f64,
    latency: f64,
    nodes: usize,
}

impl NetworkModel {
    pub fn new(cfg: &ClusterConfig) -> Self {
        Self { bandwidth: cfg.net_bandwidth, latency: cfg.net_latency, nodes: cfg.nodes }
    }

    /// Virtual seconds for an all-to-all shuffle with the given traffic.
    /// Bottleneck = the busiest NIC (max of its in/out serialized), plus
    /// latency for that node's message share (messages pipeline across
    /// nodes).
    pub fn shuffle_time(&self, t: &Traffic) -> f64 {
        if t.total() == 0 {
            return 0.0;
        }
        let mut worst: f64 = 0.0;
        for v in 0..self.nodes {
            let bytes = t.in_bytes[v].max(t.out_bytes[v]) as f64;
            worst = worst.max(bytes / self.bandwidth);
        }
        let msg_share = (t.messages as f64 / self.nodes as f64).ceil();
        worst + self.latency * msg_share
    }

    /// Collect to the driver: all bytes land on the driver's single NIC.
    pub fn collect_time(&self, bytes: u64, messages: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        bytes as f64 / self.bandwidth + self.latency * messages as f64
    }

    /// Torrent-style broadcast from the driver to all executors:
    /// `log2(nodes)` store-and-forward rounds of the full payload.
    pub fn broadcast_time(&self, bytes: u64) -> f64 {
        if bytes == 0 || self.nodes <= 1 {
            return 0.0;
        }
        let rounds = (self.nodes as f64).log2().ceil().max(1.0);
        (bytes as f64 / self.bandwidth + self.latency) * rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(nodes: usize) -> NetworkModel {
        let mut cfg = ClusterConfig::paper_testbed(nodes);
        cfg.net_bandwidth = 100.0; // bytes/s for easy arithmetic
        cfg.net_latency = 0.5;
        NetworkModel::new(&cfg)
    }

    #[test]
    fn local_traffic_is_free() {
        let m = model(4);
        let mut t = Traffic::new(4);
        t.record(2, 2, 1_000_000);
        assert_eq!(t.total(), 0);
        assert_eq!(m.shuffle_time(&t), 0.0);
    }

    #[test]
    fn shuffle_bottleneck_is_busiest_nic() {
        let m = model(4);
        let mut t = Traffic::new(4);
        // Node 0 sends 400 bytes to node 1; node 2 sends 100 to node 3.
        t.record(0, 1, 400);
        t.record(2, 3, 100);
        // busiest NIC moves 400 bytes at 100 B/s = 4 s; 2 msgs over 4 nodes
        // -> ceil(0.5) = 1 latency unit.
        assert!((m.shuffle_time(&t) - 4.5).abs() < 1e-12);
    }

    #[test]
    fn shuffle_scales_down_with_spread() {
        let m = model(4);
        // Same total volume, concentrated vs spread.
        let mut conc = Traffic::new(4);
        conc.record(0, 1, 300);
        conc.record(0, 2, 300);
        let mut spread = Traffic::new(4);
        spread.record(0, 1, 300);
        spread.record(2, 3, 300);
        assert!(m.shuffle_time(&spread) < m.shuffle_time(&conc));
    }

    #[test]
    fn collect_and_broadcast() {
        let m = model(8);
        assert_eq!(m.collect_time(0, 0), 0.0);
        assert!((m.collect_time(1000, 2) - (10.0 + 1.0)).abs() < 1e-12);
        // 8 nodes -> 3 rounds of (bytes/bw + latency).
        assert!((m.broadcast_time(100) - 3.0 * 1.5).abs() < 1e-12);
    }

    #[test]
    fn single_node_broadcast_free() {
        let m = model(1);
        assert_eq!(m.broadcast_time(1 << 30), 0.0);
    }
}
