//! Block RDDs: eager, keyed, partitioned collections with Spark-shaped
//! transformations.
//!
//! Every transformation (a) really executes its closure over each block on
//! this machine — concurrently, one worker thread per claimed partition,
//! up to the [`crate::config::ClusterConfig::parallelism`] pool size —
//! (b) measures per-partition compute time and replays it on the virtual
//! cluster, (c) charges shuffles/collects/broadcasts to the network model,
//! and (d) records a lineage node whose depth drives the driver-overhead
//! model. The op names mirror PySpark's.
//!
//! Payloads are held behind `Arc`: replicating a block to many shuffle
//! destinations (the APSP pivot broadcast) is a refcount bump, not a deep
//! copy, and [`BlockRdd::join_update`] mutates blocks copy-on-write — a
//! block nobody else references is updated in place, a shared one is
//! cloned lazily on first write. The simulated network still charges the
//! full payload size per message ([`HasBytes`] looks through the `Arc`),
//! so zero-copy execution never changes the modeled cluster numbers.
//!
//! Determinism contract: worker count affects wall-clock only. Results,
//! record order, lineage shape and task counts are bit-identical for any
//! `parallelism` — the determinism test suite enforces this.

use super::block::{BlockId, HasBytes};
use super::clock::Task;
use super::context::SparkContext;
use super::executor;
use super::metrics::StageMetrics;
use super::network::Traffic;
use super::partitioner::Partitioner;
use crate::util::Stopwatch;
use std::collections::BTreeMap;
use std::sync::Arc;

/// A partitioned, keyed collection of blocks.
pub struct BlockRdd<T> {
    ctx: SparkContext,
    items: BTreeMap<BlockId, Arc<T>>,
    part: Arc<dyn Partitioner>,
    /// Lineage node of this RDD.
    pub lineage_id: usize,
}

impl<T> std::fmt::Debug for BlockRdd<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "BlockRdd({} blocks, {} partitions, lineage #{})",
            self.items.len(),
            self.part.num_partitions(),
            self.lineage_id
        )
    }
}

/// Copy-on-write view of one block during [`BlockRdd::join_update`].
///
/// Reads are free ([`BlockRef::get`] / `Deref`). The first
/// [`BlockRef::make_mut`] clones the payload *only if* another RDD still
/// shares it (a filtered view, a persisted ancestor); a uniquely-held
/// block is mutated in place. [`BlockRef::set_shared`] installs an
/// incoming `Arc` payload wholesale without any copy — the APSP diagonal
/// swap.
pub struct BlockRef<'a, T: Clone> {
    slot: &'a mut Arc<T>,
}

impl<'a, T: Clone> BlockRef<'a, T> {
    /// Borrow the block read-only.
    pub fn get(&self) -> &T {
        &**self.slot
    }

    /// Mutable access; clones the block only when it is shared.
    pub fn make_mut(&mut self) -> &mut T {
        Arc::make_mut(self.slot)
    }

    /// Replace the block with a freshly built value.
    pub fn set(&mut self, value: T) {
        *self.slot = Arc::new(value);
    }

    /// Replace the block with an already-shared payload (zero-copy).
    pub fn set_shared(&mut self, value: Arc<T>) {
        *self.slot = value;
    }
}

impl<'a, T: Clone> std::ops::Deref for BlockRef<'a, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &**self.slot
    }
}

/// Keyed records emitted by `flat_map`, not yet reduced: each record knows
/// the node that produced it so the following wide op can charge the
/// network for records that change nodes.
pub struct Keyed<U> {
    ctx: SparkContext,
    records: Vec<(BlockId, U, usize)>,
    pub lineage_id: usize,
}

impl SparkContext {
    /// Create an RDD from driver-side data (the paper's initial load of X
    /// into an RDD + `combineByKey` into blocks). Charges a broadcast-like
    /// distribution of the data to the executors.
    pub fn parallelize<T: HasBytes>(
        &self,
        name: &str,
        items: Vec<(BlockId, T)>,
        part: Arc<dyn Partitioner>,
    ) -> BlockRdd<T> {
        let lineage_id = self.lineage_add(name, &[]);
        let bytes: u64 = items.iter().map(|(_, v)| v.nbytes()).sum();
        let dt = self.charge_collect(bytes, items.len() as u64); // driver -> executors
        self.push_metrics(StageMetrics {
            name: format!("{name}:parallelize"),
            tasks: items.len(),
            compute_real: 0.0,
            virtual_span: 0.0,
            shuffle_bytes: bytes,
            network_time: dt,
            driver_time: 0.0,
        });
        BlockRdd {
            ctx: self.clone(),
            items: items.into_iter().map(|(k, v)| (k, Arc::new(v))).collect(),
            part,
            lineage_id,
        }
    }
}

/// Drain worker results — `(partition, blocks, measured secs)` triples in
/// submission order — into the stage's item map and per-partition timings.
fn collect_results<U>(
    results: Vec<(usize, Vec<(BlockId, Arc<U>)>, f64)>,
) -> (BTreeMap<BlockId, Arc<U>>, BTreeMap<usize, f64>) {
    let mut items = BTreeMap::new();
    let mut per_part = BTreeMap::new();
    for (p, outs, secs) in results {
        per_part.insert(p, secs);
        items.extend(outs);
    }
    (items, per_part)
}

/// Close out a stage: lineage node, driver charge, virtual-cluster replay,
/// metrics — shared by narrow and wide transformations.
fn finish_stage<U: HasBytes>(
    ctx: &SparkContext,
    name: &str,
    parents: &[usize],
    items: BTreeMap<BlockId, Arc<U>>,
    per_part: BTreeMap<usize, f64>,
    part: Arc<dyn Partitioner>,
    shuffle_bytes: u64,
    network_time: f64,
) -> BlockRdd<U> {
    let lineage_id = ctx.lineage_add(name, parents);
    let depth = ctx.lineage_depth(lineage_id);
    let nparts = part.num_partitions();
    let tasks: Vec<Task> = per_part
        .iter()
        .map(|(&p, &dur)| Task { node: ctx.node_of(p, nparts), duration: dur })
        .collect();
    let driver_time = ctx.charge_driver(name, tasks.len(), depth);
    let span = ctx.run_stage(&tasks);
    ctx.push_metrics(StageMetrics {
        name: name.to_string(),
        tasks: tasks.len(),
        compute_real: per_part.values().sum(),
        virtual_span: span,
        shuffle_bytes,
        network_time,
        driver_time,
    });
    BlockRdd { ctx: ctx.clone(), items, part, lineage_id }
}

impl<T: HasBytes + Send + Sync> BlockRdd<T> {
    /// Number of blocks.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Borrow one block.
    pub fn get(&self, id: BlockId) -> Option<&T> {
        self.items.get(&id).map(|a| a.as_ref())
    }

    /// Iterate blocks in key order.
    pub fn iter(&self) -> impl Iterator<Item = (&BlockId, &T)> {
        self.items.iter().map(|(k, v)| (k, v.as_ref()))
    }

    /// The partitioner in force.
    pub fn partitioner(&self) -> Arc<dyn Partitioner> {
        Arc::clone(&self.part)
    }

    /// The owning context.
    pub fn context(&self) -> SparkContext {
        self.ctx.clone()
    }

    /// Resident bytes per executor node (for the memory model).
    pub fn per_node_bytes(&self) -> Vec<u64> {
        let mut per = vec![0u64; self.ctx.nodes()];
        for (&id, v) in &self.items {
            per[self.ctx.node_of(self.part.partition(id), self.part.num_partitions())] += v.nbytes();
        }
        per
    }

    /// Persist this RDD under `tag` in the executor-memory model.
    pub fn persist(&self, tag: &str) -> anyhow::Result<()> {
        self.ctx.set_resident(tag, self.per_node_bytes())
    }

    /// Checkpoint: charge a disk write and prune this RDD's lineage
    /// (paper §III-B, every ~10 APSP iterations).
    pub fn checkpoint(&self) {
        let per_node = self.per_node_bytes();
        self.ctx.charge_checkpoint(self.lineage_id, &per_node);
    }

    /// [`BlockRdd::checkpoint`], made durable when `--checkpoint-dir` is
    /// set: in addition to the simulated disk charge and lineage prune,
    /// really spill every block through the durable store as checkpoint
    /// `step` of `job`, recording the spill in the resilience counters and
    /// a `checkpoint:durable` metrics row. Without a configured store this
    /// is exactly `checkpoint()`. Returns the payload bytes spilled.
    pub fn checkpoint_durable(&self, job: &str, step: usize) -> anyhow::Result<u64>
    where
        T: std::borrow::Borrow<crate::linalg::Matrix>,
    {
        self.checkpoint();
        let Some(store) = self.ctx.checkpoint_store() else {
            return Ok(0);
        };
        let blocks: Vec<(BlockId, &crate::linalg::Matrix)> = self
            .items
            .iter()
            .map(|(&id, v)| (id, std::borrow::Borrow::borrow(v.as_ref())))
            .collect();
        let sw = Stopwatch::start();
        let bytes = store.save(job, step, &blocks)?;
        self.ctx.resilience().record_spill(bytes);
        self.ctx.push_metrics(StageMetrics {
            name: "checkpoint:durable".to_string(),
            tasks: blocks.len(),
            compute_real: 0.0,
            virtual_span: 0.0,
            shuffle_bytes: 0,
            network_time: 0.0,
            driver_time: sw.secs(),
        });
        Ok(bytes)
    }

    /// Group block references by partition, in partition order. Each entry
    /// is one schedulable task of the stage; blocks within a partition
    /// stay in key order.
    fn partition_tasks(&self) -> Vec<(usize, Vec<(BlockId, &Arc<T>)>)> {
        let mut per: BTreeMap<usize, Vec<(BlockId, &Arc<T>)>> = BTreeMap::new();
        for (&id, v) in &self.items {
            per.entry(self.part.partition(id)).or_default().push((id, v));
        }
        per.into_iter().collect()
    }

    /// Narrow transformation: apply `f` to every block, preserving keys and
    /// partitioning (PySpark `mapValues`). Partitions execute concurrently
    /// on the worker pool.
    pub fn map_values<U: HasBytes + Send + Sync>(
        &self,
        name: &str,
        f: impl Fn(BlockId, &T) -> U + Sync,
    ) -> BlockRdd<U> {
        let f = &f;
        let policy = self.ctx.task_policy();
        let results = executor::run_tasks_with_policy(
            policy.as_ref(),
            name,
            self.ctx.parallelism(),
            self.partition_tasks(),
            move |(p, blocks)| {
                let sw = Stopwatch::start();
                let outs: Vec<(BlockId, Arc<U>)> = std::mem::take(blocks)
                    .into_iter()
                    .map(|(id, v)| (id, Arc::new(f(id, v.as_ref()))))
                    .collect();
                (*p, outs, sw.secs())
            },
        );
        let (out, per_part) = collect_results(results);
        finish_stage(
            &self.ctx,
            name,
            &[self.lineage_id],
            out,
            per_part,
            Arc::clone(&self.part),
            0,
            0.0,
        )
    }

    /// Narrow in-place transformation: apply `f` to every block through
    /// copy-on-write, preserving keys and partitioning. Consumes the RDD
    /// so sole-owner blocks mutate in place with zero copies; a block
    /// still shared (persisted lineage, `filter_blocks` alias) is cloned
    /// once by [`Arc::make_mut`] before `f` sees it. The cheap sibling of
    /// [`map_values`] for `T → T` updates like centering's apply stage.
    pub fn update_values(self, name: &str, f: impl Fn(BlockId, &mut T) + Sync) -> BlockRdd<T>
    where
        T: Clone,
    {
        let BlockRdd { ctx, items, part, lineage_id } = self;
        let mut per: BTreeMap<usize, Vec<(BlockId, Arc<T>)>> = BTreeMap::new();
        for (id, arc) in items {
            per.entry(part.partition(id)).or_default().push((id, arc));
        }
        let f = &f;
        let policy = ctx.task_policy();
        let results = executor::run_tasks_with_policy(
            policy.as_ref(),
            name,
            ctx.parallelism(),
            per.into_iter().collect::<Vec<_>>(),
            move |(p, blocks)| {
                let sw = Stopwatch::start();
                let outs: Vec<(BlockId, Arc<T>)> = std::mem::take(blocks)
                    .into_iter()
                    .map(|(id, mut arc)| {
                        f(id, Arc::make_mut(&mut arc));
                        (id, arc)
                    })
                    .collect();
                (*p, outs, sw.secs())
            },
        );
        let (out, per_part) = collect_results(results);
        finish_stage(&ctx, name, &[lineage_id], out, per_part, part, 0, 0.0)
    }

    /// Narrow transformation keeping only blocks satisfying `pred`
    /// (PySpark `filter` over keys). Kept blocks are shared, not copied.
    pub fn filter_blocks(&self, name: &str, pred: impl Fn(BlockId) -> bool + Sync) -> BlockRdd<T> {
        let mut out = BTreeMap::new();
        let mut per_part: BTreeMap<usize, f64> = BTreeMap::new();
        for (&id, v) in &self.items {
            let sw = Stopwatch::start();
            let keep = pred(id);
            *per_part.entry(self.part.partition(id)).or_default() += sw.secs();
            if keep {
                out.insert(id, Arc::clone(v));
            }
        }
        finish_stage(
            &self.ctx,
            name,
            &[self.lineage_id],
            out,
            per_part,
            Arc::clone(&self.part),
            0,
            0.0,
        )
    }

    /// Emit keyed records from every block (PySpark `flatMap`). The records
    /// remain unshuffled until a wide op consumes them.
    pub fn flat_map<U: HasBytes + Send>(
        &self,
        name: &str,
        f: impl Fn(BlockId, &T) -> Vec<(BlockId, U)> + Sync,
    ) -> Keyed<U> {
        self.flat_map_impl(name, move |id, v| f(id, v.as_ref()))
    }

    /// `flat_map` variant exposing the block's shared handle, so emitting
    /// the same block to many destinations is a refcount bump instead of a
    /// deep copy per destination (the APSP pivot replication, the kNN pair
    /// broadcast). The simulated shuffle still charges full payload bytes
    /// per emitted record.
    pub fn flat_map_arc<U: HasBytes + Send>(
        &self,
        name: &str,
        f: impl Fn(BlockId, &Arc<T>) -> Vec<(BlockId, U)> + Sync,
    ) -> Keyed<U> {
        self.flat_map_impl(name, f)
    }

    fn flat_map_impl<U: HasBytes + Send>(
        &self,
        name: &str,
        f: impl Fn(BlockId, &Arc<T>) -> Vec<(BlockId, U)> + Sync,
    ) -> Keyed<U> {
        let f = &f;
        let policy = self.ctx.task_policy();
        let results = executor::run_tasks_with_policy(
            policy.as_ref(),
            name,
            self.ctx.parallelism(),
            self.partition_tasks(),
            move |(p, blocks)| {
                let sw = Stopwatch::start();
                let emitted: Vec<(BlockId, Vec<(BlockId, U)>)> =
                    std::mem::take(blocks).into_iter().map(|(id, v)| (id, f(id, v))).collect();
                (*p, emitted, sw.secs())
            },
        );
        // Reassemble records in source-block key order — exactly the
        // sequential emission order, independent of worker scheduling.
        let mut per_part: BTreeMap<usize, f64> = BTreeMap::new();
        let mut by_src: BTreeMap<BlockId, (usize, Vec<(BlockId, U)>)> = BTreeMap::new();
        for (p, emitted, secs) in results {
            per_part.insert(p, secs);
            let src_node = self.ctx.node_of(p, self.part.num_partitions());
            for (src, recs) in emitted {
                by_src.insert(src, (src_node, recs));
            }
        }
        let mut records = Vec::new();
        for (_, (node, recs)) in by_src {
            records.extend(recs.into_iter().map(|(k, u)| (k, u, node)));
        }

        let lineage_id = self.ctx.lineage_add(name, &[self.lineage_id]);
        let depth = self.ctx.lineage_depth(lineage_id);
        let tasks: Vec<Task> = per_part
            .iter()
            .map(|(&p, &dur)| Task { node: self.ctx.node_of(p, self.part.num_partitions()), duration: dur })
            .collect();
        let driver_time = self.ctx.charge_driver(name, tasks.len(), depth);
        let span = self.ctx.run_stage(&tasks);
        self.ctx.push_metrics(StageMetrics {
            name: name.to_string(),
            tasks: tasks.len(),
            compute_real: per_part.values().sum(),
            virtual_span: span,
            shuffle_bytes: 0,
            network_time: 0.0,
            driver_time,
        });
        Keyed { ctx: self.ctx.clone(), records, lineage_id }
    }

    /// The paper's `union` + `partitionBy` + `combineByKey` pattern: route
    /// `incoming` records to this RDD's partitioning and fold them into the
    /// matching blocks copy-on-write. `f` is invoked for *every* block —
    /// with an empty record vector when nothing was routed to it — matching
    /// Spark's combineByKey-over-union semantics where the combiner sees
    /// each original block exactly once. Consumes the RDD so that blocks
    /// nobody else shares are updated in place without any clone; a block
    /// `f` never writes to ([`BlockRef::make_mut`]) is never copied at all.
    pub fn join_update<U: HasBytes + Send + Sync>(
        self,
        name: &str,
        incoming: Keyed<U>,
        f: impl Fn(BlockId, &mut BlockRef<T>, Vec<U>) + Sync,
    ) -> BlockRdd<T>
    where
        T: Clone,
    {
        let BlockRdd { ctx, items, part, lineage_id } = self;

        // Shuffle accounting: records that land on a different node pay.
        let mut traffic = Traffic::new(ctx.nodes());
        for (k, u, src) in &incoming.records {
            let dst = ctx.node_of(part.partition(*k), part.num_partitions());
            traffic.record(*src, dst, u.nbytes());
        }
        let (shuffle_bytes, network_time) = ctx.charge_shuffle(&traffic);

        // Group records by destination key, preserving arrival order.
        let mut grouped: BTreeMap<BlockId, Vec<U>> = BTreeMap::new();
        for (k, u, _) in incoming.records {
            grouped.entry(k).or_default().push(u);
        }

        // One task per partition; each owns its blocks plus routed records.
        let mut per: BTreeMap<usize, Vec<(BlockId, Arc<T>, Vec<U>)>> = BTreeMap::new();
        for (id, arc) in items {
            let recs = grouped.remove(&id).unwrap_or_default();
            per.entry(part.partition(id)).or_default().push((id, arc, recs));
        }
        debug_assert!(
            grouped.is_empty(),
            "join_update: {} records had no matching block (first key {:?})",
            grouped.len(),
            grouped.keys().next()
        );

        let f = &f;
        let policy = ctx.task_policy();
        let results = executor::run_tasks_with_policy(
            policy.as_ref(),
            name,
            ctx.parallelism(),
            per.into_iter().collect::<Vec<_>>(),
            move |(p, blocks)| {
                let sw = Stopwatch::start();
                let outs: Vec<(BlockId, Arc<T>)> = std::mem::take(blocks)
                    .into_iter()
                    .map(|(id, mut arc, recs)| {
                        let mut slot = BlockRef { slot: &mut arc };
                        f(id, &mut slot, recs);
                        (id, arc)
                    })
                    .collect();
                (*p, outs, sw.secs())
            },
        );
        let (out, per_part) = collect_results(results);
        finish_stage(
            &ctx,
            name,
            &[lineage_id, incoming.lineage_id],
            out,
            per_part,
            part,
            shuffle_bytes,
            network_time,
        )
    }

    /// Action: bring every block to the driver (PySpark `collect`).
    pub fn collect(&self) -> BTreeMap<BlockId, T>
    where
        T: Clone,
    {
        let bytes: u64 = self.items.values().map(|v| v.nbytes()).sum();
        let dt = self.ctx.charge_collect(bytes, self.items.len() as u64);
        self.ctx.push_metrics(StageMetrics {
            name: "collect".to_string(),
            tasks: 0,
            compute_real: 0.0,
            virtual_span: 0.0,
            shuffle_bytes: bytes,
            network_time: dt,
            driver_time: 0.0,
        });
        self.items.iter().map(|(&k, v)| (k, v.as_ref().clone())).collect()
    }
}

impl<U: HasBytes + Send + Sync> Keyed<U> {
    /// Number of pending records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Route records to partitions of `part`, preserving record order
    /// within each partition, and account the shuffle.
    fn shuffle_to(
        self,
        part: &Arc<dyn Partitioner>,
    ) -> (SparkContext, usize, BTreeMap<usize, Vec<(BlockId, U)>>, u64, f64) {
        let ctx = self.ctx.clone();
        let mut traffic = Traffic::new(ctx.nodes());
        for (k, u, src) in &self.records {
            let dst = ctx.node_of(part.partition(*k), part.num_partitions());
            traffic.record(*src, dst, u.nbytes());
        }
        let (shuffle_bytes, network_time) = ctx.charge_shuffle(&traffic);
        let mut per: BTreeMap<usize, Vec<(BlockId, U)>> = BTreeMap::new();
        for (k, u, _) in self.records {
            per.entry(part.partition(k)).or_default().push((k, u));
        }
        (ctx, self.lineage_id, per, shuffle_bytes, network_time)
    }

    /// Wide op: shuffle records to `part` and fold values sharing a key
    /// with `f` (PySpark `reduceByKey`/`combineByKey`). Partitions fold
    /// concurrently; within a key the fold order is record-arrival order,
    /// identical to sequential execution.
    pub fn reduce_by_key(
        self,
        name: &str,
        part: Arc<dyn Partitioner>,
        f: impl Fn(U, U) -> U + Sync,
    ) -> BlockRdd<U> {
        let (ctx, parent, per, shuffle_bytes, network_time) = self.shuffle_to(&part);
        let f = &f;
        let policy = ctx.task_policy();
        let results = executor::run_tasks_with_policy(
            policy.as_ref(),
            name,
            ctx.parallelism(),
            per.into_iter().collect::<Vec<_>>(),
            move |(p, recs)| {
                let sw = Stopwatch::start();
                let mut acc: BTreeMap<BlockId, U> = BTreeMap::new();
                for (k, u) in std::mem::take(recs) {
                    match acc.remove(&k) {
                        None => {
                            acc.insert(k, u);
                        }
                        Some(prev) => {
                            acc.insert(k, f(prev, u));
                        }
                    }
                }
                let outs: Vec<(BlockId, Arc<U>)> =
                    acc.into_iter().map(|(k, u)| (k, Arc::new(u))).collect();
                (*p, outs, sw.secs())
            },
        );
        let (items, per_part) = collect_results(results);
        finish_stage(&ctx, name, &[parent], items, per_part, part, shuffle_bytes, network_time)
    }

    /// Wide op: shuffle and gather all values per key (PySpark
    /// `groupByKey`). The gather is real work and is timed per partition
    /// like every other stage.
    pub fn group_by_key(self, name: &str, part: Arc<dyn Partitioner>) -> BlockRdd<Vec<U>> {
        let (ctx, parent, per, shuffle_bytes, network_time) = self.shuffle_to(&part);
        let policy = ctx.task_policy();
        let results = executor::run_tasks_with_policy(
            policy.as_ref(),
            name,
            ctx.parallelism(),
            per.into_iter().collect::<Vec<_>>(),
            move |(p, recs)| {
                let sw = Stopwatch::start();
                let mut acc: BTreeMap<BlockId, Vec<U>> = BTreeMap::new();
                for (k, u) in std::mem::take(recs) {
                    acc.entry(k).or_default().push(u);
                }
                let outs: Vec<(BlockId, Arc<Vec<U>>)> =
                    acc.into_iter().map(|(k, v)| (k, Arc::new(v))).collect();
                (*p, outs, sw.secs())
            },
        );
        let (items, per_part) = collect_results(results);
        finish_stage(&ctx, name, &[parent], items, per_part, part, shuffle_bytes, network_time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::engine::partitioner::HashPartitioner;

    fn ctx(nodes: usize) -> SparkContext {
        SparkContext::new(ClusterConfig { nodes, ..ClusterConfig::local() })
    }

    fn small_rdd(ctx: &SparkContext) -> BlockRdd<f64> {
        let items: Vec<(BlockId, f64)> =
            (0..6).map(|i| (BlockId::new(i, i), i as f64)).collect();
        ctx.parallelize("x", items, Arc::new(HashPartitioner::new(3)))
    }

    #[test]
    fn map_values_preserves_keys() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        let m = r.map_values("double", |_, v| v * 2.0);
        assert_eq!(m.len(), 6);
        assert_eq!(*m.get(BlockId::new(3, 3)).unwrap(), 6.0);
    }

    #[test]
    fn filter_drops() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        let f = r.filter_blocks("even", |id| id.i % 2 == 0);
        assert_eq!(f.len(), 3);
        assert!(f.get(BlockId::new(1, 1)).is_none());
    }

    #[test]
    fn flat_map_reduce_by_key() {
        let ctx = ctx(3);
        let r = small_rdd(&ctx);
        // Emit every value to key (0,0) and sum.
        let k = r.flat_map("emit", |_, v| vec![(BlockId::new(0, 0), *v)]);
        assert_eq!(k.len(), 6);
        let red = k.reduce_by_key("sum", Arc::new(HashPartitioner::new(2)), |a, b| a + b);
        assert_eq!(red.len(), 1);
        assert_eq!(*red.get(BlockId::new(0, 0)).unwrap(), 15.0);
    }

    #[test]
    fn group_by_key_gathers() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        let k = r.flat_map("emit", |id, v| vec![(BlockId::new(id.i % 2, 0), *v)]);
        let g = k.group_by_key("group", Arc::new(HashPartitioner::new(2)));
        assert_eq!(g.len(), 2);
        let evens = g.get(BlockId::new(0, 0)).unwrap();
        assert_eq!(evens.iter().sum::<f64>(), 0.0 + 2.0 + 4.0);
    }

    #[test]
    fn group_by_key_times_the_gather() {
        // Regression: grouping does real work, so its stage must report
        // real tasks with measured durations (was hard-coded to zero).
        let ctx = ctx(2);
        let items: Vec<(BlockId, f64)> =
            (0..64).map(|i| (BlockId::new(i, 0), i as f64)).collect();
        let r = ctx.parallelize("x", items, Arc::new(HashPartitioner::new(4)));
        let k = r.flat_map("emit", |id, v| {
            (0..200).map(|j| (BlockId::new(id.i % 8, j % 4), *v)).collect()
        });
        let g = k.group_by_key("group", Arc::new(HashPartitioner::new(4)));
        assert!(g.len() > 1);
        let agg = ctx.stage_aggregate("group");
        assert!(agg.tasks > 0, "group stage must have tasks");
        assert!(agg.compute_real > 0.0, "gather work must be timed");
    }

    #[test]
    fn join_update_applies_and_passes_through() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        let inc = r.flat_map("emit", |id, v| {
            if id.i < 2 {
                vec![(id, v + 100.0)]
            } else {
                vec![]
            }
        });
        let j = r.join_update("apply", inc, |_, v, us| {
            let v = v.make_mut();
            for u in us {
                *v += u;
            }
        });
        assert_eq!(*j.get(BlockId::new(0, 0)).unwrap(), 100.0); // 0 + (0+100)
        assert_eq!(*j.get(BlockId::new(1, 1)).unwrap(), 102.0); // 1 + (1+100)
        assert_eq!(*j.get(BlockId::new(5, 5)).unwrap(), 5.0); // untouched
    }

    #[test]
    fn update_values_mutates_in_place_and_respects_sharing() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        // Alias every block, so update_values must copy-on-write rather
        // than scribble over the shared payloads.
        let alias = r.filter_blocks("alias", |_| true);
        let u = r.update_values("bump", |_, v| *v += 10.0);
        assert_eq!(*u.get(BlockId::new(0, 0)).unwrap(), 10.0);
        assert_eq!(*u.get(BlockId::new(5, 5)).unwrap(), 15.0);
        assert_eq!(*alias.get(BlockId::new(5, 5)).unwrap(), 5.0); // untouched

        // Sole-owner path: no alias, the same Arc allocation survives.
        let ptr_before: *const f64 = Arc::as_ptr(u.items.get(&BlockId::new(0, 0)).unwrap());
        let u2 = u.update_values("bump2", |_, v| *v += 1.0);
        let ptr_after: *const f64 = Arc::as_ptr(u2.items.get(&BlockId::new(0, 0)).unwrap());
        assert_eq!(ptr_before, ptr_after, "sole-owner block must mutate in place");
        assert_eq!(*u2.get(BlockId::new(0, 0)).unwrap(), 11.0);
        assert!(ctx.stage_aggregate("bump").tasks > 0);
    }

    #[test]
    fn join_update_copy_on_write_swaps_shared_payload() {
        let ctx = ctx(1);
        let r = small_rdd(&ctx);
        let shared = Arc::new(42.0f64);
        let inc = r.flat_map("emit", |id, _| vec![(id, 0.0f64)]);
        let j = r.join_update("swap", inc, |_, v, _| {
            v.set_shared(Arc::clone(&shared));
        });
        for (_, v) in j.iter() {
            assert_eq!(*v, 42.0);
        }
    }

    #[test]
    fn shuffle_bytes_counted_multi_node() {
        let ctx = ctx(4);
        let r = small_rdd(&ctx);
        let before = ctx.total_shuffle_bytes();
        let k = r.flat_map("emit", |_, v| vec![(BlockId::new(0, 0), *v)]);
        let _ = k.reduce_by_key("sum", Arc::new(HashPartitioner::new(4)), |a, b| a + b);
        // With 4 nodes at least some records cross nodes.
        assert!(ctx.total_shuffle_bytes() > before);
    }

    #[test]
    fn collect_returns_all() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        let c = r.collect();
        assert_eq!(c.len(), 6);
    }

    #[test]
    fn virtual_time_advances() {
        let mut cfg = ClusterConfig::local();
        cfg.sched_overhead = 0.001;
        let ctx = SparkContext::new(cfg);
        let r = small_rdd(&ctx);
        let t0 = ctx.virtual_now();
        let _ = r.map_values("work", |_, v| {
            // Busy-ish loop so measured durations are nonzero.
            let mut acc = *v;
            for i in 0..2000 {
                acc += (i as f64).sqrt();
            }
            acc
        });
        assert!(ctx.virtual_now() > t0);
    }

    #[test]
    fn parallel_results_bit_identical_to_sequential() {
        // The worker pool must never change values, record order, lineage
        // shape or task counts — only wall-clock.
        let run = |threads: usize| -> (Vec<(BlockId, u64)>, usize, usize, usize) {
            let cfg = ClusterConfig { parallelism: threads, ..ClusterConfig::local() };
            let c = SparkContext::new(cfg);
            let items: Vec<(BlockId, f64)> =
                (0..32).map(|i| (BlockId::new(i, i), (i as f64).sin())).collect();
            let r = c.parallelize("x", items, Arc::new(HashPartitioner::new(8)));
            let m = r.map_values("sqrtsum", |_, v| {
                let mut acc = *v;
                for k in 0..100 {
                    acc += (k as f64 + acc.abs()).sqrt();
                }
                acc
            });
            let keyed = m.flat_map("emit", |id, v| {
                vec![(BlockId::new(id.i % 4, 0), *v), (BlockId::new(id.i % 3, 1), -*v)]
            });
            let red =
                keyed.reduce_by_key("sum", Arc::new(HashPartitioner::new(4)), |a, b| a + b);
            let vals: Vec<(BlockId, u64)> =
                red.iter().map(|(&k, v)| (k, v.to_bits())).collect();
            (vals, c.total_tasks(), c.stage_count(), c.lineage_len())
        };
        let seq = run(1);
        let par = run(4);
        assert_eq!(seq, par);
    }

    #[test]
    fn persist_and_memory_limit() {
        let mut cfg = ClusterConfig::local();
        cfg.mem_per_node = 100; // tiny
        let ctx = SparkContext::new(cfg);
        let items: Vec<(BlockId, crate::linalg::Matrix)> =
            vec![(BlockId::new(0, 0), crate::linalg::Matrix::zeros(10, 10))];
        let r = ctx.parallelize("m", items, Arc::new(HashPartitioner::new(1)));
        assert!(r.persist("m").is_err());
    }

    #[test]
    fn lineage_depth_grows_and_checkpoint_resets() {
        let ctx = ctx(1);
        let mut r = small_rdd(&ctx);
        for i in 0..12 {
            r = r.map_values(&format!("it{i}"), |_, v| *v);
        }
        assert!(ctx.lineage_depth(r.lineage_id) >= 12);
        r.checkpoint();
        assert_eq!(ctx.lineage_depth(r.lineage_id), 0);
    }
}
