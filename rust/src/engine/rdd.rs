//! Block RDDs: eager, keyed, partitioned collections with Spark-shaped
//! transformations.
//!
//! Every transformation (a) really executes its closure over each block on
//! this machine, (b) measures per-partition compute time and replays it on
//! the virtual cluster, (c) charges shuffles/collects/broadcasts to the
//! network model, and (d) records a lineage node whose depth drives the
//! driver-overhead model. The op names mirror PySpark's.

use super::block::{BlockId, HasBytes};
use super::clock::Task;
use super::context::SparkContext;
use super::metrics::StageMetrics;
use super::network::Traffic;
use super::partitioner::Partitioner;
use crate::util::Stopwatch;
use std::collections::BTreeMap;
use std::rc::Rc;

/// A partitioned, keyed collection of blocks.
pub struct BlockRdd<T> {
    ctx: SparkContext,
    items: BTreeMap<BlockId, T>,
    part: Rc<dyn Partitioner>,
    /// Lineage node of this RDD.
    pub lineage_id: usize,
}

impl<T> std::fmt::Debug for BlockRdd<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "BlockRdd({} blocks, {} partitions, lineage #{})",
            self.items.len(),
            self.part.num_partitions(),
            self.lineage_id
        )
    }
}

/// Keyed records emitted by `flat_map`, not yet reduced: each record knows
/// the node that produced it so the following wide op can charge the
/// network for records that change nodes.
pub struct Keyed<U> {
    ctx: SparkContext,
    records: Vec<(BlockId, U, usize)>,
    pub lineage_id: usize,
}

impl SparkContext {
    /// Create an RDD from driver-side data (the paper's initial load of X
    /// into an RDD + `combineByKey` into blocks). Charges a broadcast-like
    /// distribution of the data to the executors.
    pub fn parallelize<T: HasBytes>(
        &self,
        name: &str,
        items: Vec<(BlockId, T)>,
        part: Rc<dyn Partitioner>,
    ) -> BlockRdd<T> {
        let lineage_id = self.lineage_add(name, &[]);
        let bytes: u64 = items.iter().map(|(_, v)| v.nbytes()).sum();
        let dt = self.charge_collect(bytes, items.len() as u64); // driver -> executors
        self.push_metrics(StageMetrics {
            name: format!("{name}:parallelize"),
            tasks: items.len(),
            compute_real: 0.0,
            virtual_span: 0.0,
            shuffle_bytes: bytes,
            network_time: dt,
            driver_time: 0.0,
        });
        BlockRdd { ctx: self.clone(), items: items.into_iter().collect(), part, lineage_id }
    }
}

impl<T: HasBytes> BlockRdd<T> {
    /// Number of blocks.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Borrow one block.
    pub fn get(&self, id: BlockId) -> Option<&T> {
        self.items.get(&id)
    }

    /// Iterate blocks in key order.
    pub fn iter(&self) -> impl Iterator<Item = (&BlockId, &T)> {
        self.items.iter()
    }

    /// The partitioner in force.
    pub fn partitioner(&self) -> Rc<dyn Partitioner> {
        Rc::clone(&self.part)
    }

    /// The owning context.
    pub fn context(&self) -> SparkContext {
        self.ctx.clone()
    }

    /// Resident bytes per executor node (for the memory model).
    pub fn per_node_bytes(&self) -> Vec<u64> {
        let mut per = vec![0u64; self.ctx.nodes()];
        for (&id, v) in &self.items {
            per[self.ctx.node_of(self.part.partition(id), self.part.num_partitions())] += v.nbytes();
        }
        per
    }

    /// Persist this RDD under `tag` in the executor-memory model.
    pub fn persist(&self, tag: &str) -> anyhow::Result<()> {
        self.ctx.set_resident(tag, self.per_node_bytes())
    }

    /// Checkpoint: charge a disk write and prune this RDD's lineage
    /// (paper §III-B, every ~10 APSP iterations).
    pub fn checkpoint(&self) {
        let per_node = self.per_node_bytes();
        self.ctx.charge_checkpoint(self.lineage_id, &per_node);
    }

    fn finish_stage<U: HasBytes>(
        &self,
        name: &str,
        parents: &[usize],
        items: BTreeMap<BlockId, U>,
        per_part: BTreeMap<usize, f64>,
        part: Rc<dyn Partitioner>,
        shuffle_bytes: u64,
        network_time: f64,
    ) -> BlockRdd<U> {
        let lineage_id = self.ctx.lineage_add(name, parents);
        let depth = self.ctx.lineage_depth(lineage_id);
        let tasks: Vec<Task> = per_part
            .iter()
            .map(|(&p, &dur)| Task { node: self.ctx.node_of(p, self.part.num_partitions()), duration: dur })
            .collect();
        let driver_time = self.ctx.charge_driver(name, tasks.len(), depth);
        let span = self.ctx.run_stage(&tasks);
        self.ctx.push_metrics(StageMetrics {
            name: name.to_string(),
            tasks: tasks.len(),
            compute_real: per_part.values().sum(),
            virtual_span: span,
            shuffle_bytes,
            network_time,
            driver_time,
        });
        BlockRdd { ctx: self.ctx.clone(), items, part, lineage_id }
    }

    /// Narrow transformation: apply `f` to every block, preserving keys and
    /// partitioning (PySpark `mapValues`).
    pub fn map_values<U: HasBytes>(
        &self,
        name: &str,
        mut f: impl FnMut(BlockId, &T) -> U,
    ) -> BlockRdd<U> {
        let mut out = BTreeMap::new();
        let mut per_part: BTreeMap<usize, f64> = BTreeMap::new();
        for (&id, v) in &self.items {
            let sw = Stopwatch::start();
            let u = f(id, v);
            *per_part.entry(self.part.partition(id)).or_default() += sw.secs();
            out.insert(id, u);
        }
        self.finish_stage(name, &[self.lineage_id], out, per_part, Rc::clone(&self.part), 0, 0.0)
    }

    /// Narrow transformation keeping only blocks satisfying `pred`
    /// (PySpark `filter` over keys).
    pub fn filter_blocks(&self, name: &str, mut pred: impl FnMut(BlockId) -> bool) -> BlockRdd<T>
    where
        T: Clone,
    {
        let mut out = BTreeMap::new();
        let mut per_part: BTreeMap<usize, f64> = BTreeMap::new();
        for (&id, v) in &self.items {
            let sw = Stopwatch::start();
            let keep = pred(id);
            *per_part.entry(self.part.partition(id)).or_default() += sw.secs();
            if keep {
                out.insert(id, v.clone());
            }
        }
        self.finish_stage(name, &[self.lineage_id], out, per_part, Rc::clone(&self.part), 0, 0.0)
    }

    /// Emit keyed records from every block (PySpark `flatMap`). The records
    /// remain unshuffled until a wide op consumes them.
    pub fn flat_map<U: HasBytes>(
        &self,
        name: &str,
        mut f: impl FnMut(BlockId, &T) -> Vec<(BlockId, U)>,
    ) -> Keyed<U> {
        let mut records = Vec::new();
        let mut per_part: BTreeMap<usize, f64> = BTreeMap::new();
        for (&id, v) in &self.items {
            let sw = Stopwatch::start();
            let emitted = f(id, v);
            let p = self.part.partition(id);
            *per_part.entry(p).or_default() += sw.secs();
            let src = self.ctx.node_of(p, self.part.num_partitions());
            records.extend(emitted.into_iter().map(|(k, u)| (k, u, src)));
        }
        let lineage_id = self.ctx.lineage_add(name, &[self.lineage_id]);
        let depth = self.ctx.lineage_depth(lineage_id);
        let tasks: Vec<Task> = per_part
            .iter()
            .map(|(&p, &dur)| Task { node: self.ctx.node_of(p, self.part.num_partitions()), duration: dur })
            .collect();
        let driver_time = self.ctx.charge_driver(name, tasks.len(), depth);
        let span = self.ctx.run_stage(&tasks);
        self.ctx.push_metrics(StageMetrics {
            name: name.to_string(),
            tasks: tasks.len(),
            compute_real: per_part.values().sum(),
            virtual_span: span,
            shuffle_bytes: 0,
            network_time: 0.0,
            driver_time,
        });
        Keyed { ctx: self.ctx.clone(), records, lineage_id }
    }

    /// The paper's `union` + `partitionBy` + `combineByKey` pattern: route
    /// `incoming` records to this RDD's partitioning and fold them into the
    /// matching blocks in place (via clone-on-write). `f` is invoked for
    /// *every* block — with an empty record vector when nothing was routed
    /// to it — matching Spark's combineByKey-over-union semantics where the
    /// combiner sees each original block exactly once.
    pub fn join_update<U: HasBytes>(
        &self,
        name: &str,
        incoming: Keyed<U>,
        mut f: impl FnMut(BlockId, &mut T, Vec<U>),
    ) -> BlockRdd<T>
    where
        T: Clone,
    {
        // Shuffle accounting: records that land on a different node pay.
        let mut traffic = Traffic::new(self.ctx.nodes());
        for (k, u, src) in &incoming.records {
            let dst = self.ctx.node_of(self.part.partition(*k), self.part.num_partitions());
            traffic.record(*src, dst, u.nbytes());
        }
        let (shuffle_bytes, network_time) = self.ctx.charge_shuffle(&traffic);

        // Group records by destination key.
        let mut grouped: BTreeMap<BlockId, Vec<U>> = BTreeMap::new();
        for (k, u, _) in incoming.records {
            grouped.entry(k).or_default().push(u);
        }

        let mut out = BTreeMap::new();
        let mut per_part: BTreeMap<usize, f64> = BTreeMap::new();
        for (&id, v) in &self.items {
            let sw = Stopwatch::start();
            let mut nv = v.clone();
            f(id, &mut nv, grouped.remove(&id).unwrap_or_default());
            *per_part.entry(self.part.partition(id)).or_default() += sw.secs();
            out.insert(id, nv);
        }
        debug_assert!(
            grouped.is_empty(),
            "join_update: {} records had no matching block (first key {:?})",
            grouped.len(),
            grouped.keys().next()
        );
        self.finish_stage(
            name,
            &[self.lineage_id, incoming.lineage_id],
            out,
            per_part,
            Rc::clone(&self.part),
            shuffle_bytes,
            network_time,
        )
    }

    /// Action: bring every block to the driver (PySpark `collect`).
    pub fn collect(&self) -> BTreeMap<BlockId, T>
    where
        T: Clone,
    {
        let bytes: u64 = self.items.values().map(HasBytes::nbytes).sum();
        let dt = self.ctx.charge_collect(bytes, self.items.len() as u64);
        self.ctx.push_metrics(StageMetrics {
            name: "collect".to_string(),
            tasks: 0,
            compute_real: 0.0,
            virtual_span: 0.0,
            shuffle_bytes: bytes,
            network_time: dt,
            driver_time: 0.0,
        });
        self.items.clone()
    }
}

impl<U: HasBytes> Keyed<U> {
    /// Number of pending records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Wide op: shuffle records to `part` and fold values sharing a key
    /// with `f` (PySpark `reduceByKey`/`combineByKey`).
    pub fn reduce_by_key(
        self,
        name: &str,
        part: Rc<dyn Partitioner>,
        mut f: impl FnMut(U, U) -> U,
    ) -> BlockRdd<U> {
        let ctx = self.ctx.clone();
        let mut traffic = Traffic::new(ctx.nodes());
        for (k, u, src) in &self.records {
            let dst = ctx.node_of(part.partition(*k), part.num_partitions());
            traffic.record(*src, dst, u.nbytes());
        }
        let (shuffle_bytes, network_time) = ctx.charge_shuffle(&traffic);

        let mut acc: BTreeMap<BlockId, U> = BTreeMap::new();
        let mut per_part: BTreeMap<usize, f64> = BTreeMap::new();
        for (k, u, _) in self.records {
            let sw = Stopwatch::start();
            match acc.remove(&k) {
                None => {
                    acc.insert(k, u);
                }
                Some(prev) => {
                    acc.insert(k, f(prev, u));
                }
            }
            *per_part.entry(part.partition(k)).or_default() += sw.secs();
        }

        let lineage_id = ctx.lineage_add(name, &[self.lineage_id]);
        let depth = ctx.lineage_depth(lineage_id);
        let tasks: Vec<Task> = per_part
            .iter()
            .map(|(&p, &dur)| Task { node: ctx.node_of(p, part.num_partitions()), duration: dur })
            .collect();
        let driver_time = ctx.charge_driver(name, tasks.len(), depth);
        let span = ctx.run_stage(&tasks);
        ctx.push_metrics(StageMetrics {
            name: name.to_string(),
            tasks: tasks.len(),
            compute_real: per_part.values().sum(),
            virtual_span: span,
            shuffle_bytes,
            network_time,
            driver_time,
        });
        BlockRdd { ctx, items: acc, part, lineage_id }
    }

    /// Wide op: shuffle and gather all values per key (PySpark
    /// `groupByKey`).
    pub fn group_by_key(self, name: &str, part: Rc<dyn Partitioner>) -> BlockRdd<Vec<U>> {
        let ctx = self.ctx.clone();
        let mut traffic = Traffic::new(ctx.nodes());
        for (k, u, src) in &self.records {
            let dst = ctx.node_of(part.partition(*k), part.num_partitions());
            traffic.record(*src, dst, u.nbytes());
        }
        let (shuffle_bytes, network_time) = ctx.charge_shuffle(&traffic);

        let mut acc: BTreeMap<BlockId, Vec<U>> = BTreeMap::new();
        for (k, u, _) in self.records {
            acc.entry(k).or_default().push(u);
        }

        let lineage_id = ctx.lineage_add(name, &[self.lineage_id]);
        let depth = ctx.lineage_depth(lineage_id);
        let tasks: Vec<Task> = acc
            .keys()
            .map(|&k| part.partition(k))
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .map(|p| Task { node: ctx.node_of(p, part.num_partitions()), duration: 0.0 })
            .collect();
        let driver_time = ctx.charge_driver(name, tasks.len(), depth);
        let span = ctx.run_stage(&tasks);
        ctx.push_metrics(StageMetrics {
            name: name.to_string(),
            tasks: tasks.len(),
            compute_real: 0.0,
            virtual_span: span,
            shuffle_bytes,
            network_time,
            driver_time,
        });
        BlockRdd { ctx, items: acc, part, lineage_id }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::engine::partitioner::HashPartitioner;

    fn ctx(nodes: usize) -> SparkContext {
        SparkContext::new(ClusterConfig { nodes, ..ClusterConfig::local() })
    }

    fn small_rdd(ctx: &SparkContext) -> BlockRdd<f64> {
        let items: Vec<(BlockId, f64)> =
            (0..6).map(|i| (BlockId::new(i, i), i as f64)).collect();
        ctx.parallelize("x", items, Rc::new(HashPartitioner::new(3)))
    }

    #[test]
    fn map_values_preserves_keys() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        let m = r.map_values("double", |_, v| v * 2.0);
        assert_eq!(m.len(), 6);
        assert_eq!(*m.get(BlockId::new(3, 3)).unwrap(), 6.0);
    }

    #[test]
    fn filter_drops() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        let f = r.filter_blocks("even", |id| id.i % 2 == 0);
        assert_eq!(f.len(), 3);
        assert!(f.get(BlockId::new(1, 1)).is_none());
    }

    #[test]
    fn flat_map_reduce_by_key() {
        let ctx = ctx(3);
        let r = small_rdd(&ctx);
        // Emit every value to key (0,0) and sum.
        let k = r.flat_map("emit", |_, v| vec![(BlockId::new(0, 0), *v)]);
        assert_eq!(k.len(), 6);
        let red = k.reduce_by_key("sum", Rc::new(HashPartitioner::new(2)), |a, b| a + b);
        assert_eq!(red.len(), 1);
        assert_eq!(*red.get(BlockId::new(0, 0)).unwrap(), 15.0);
    }

    #[test]
    fn group_by_key_gathers() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        let k = r.flat_map("emit", |id, v| vec![(BlockId::new(id.i % 2, 0), *v)]);
        let g = k.group_by_key("group", Rc::new(HashPartitioner::new(2)));
        assert_eq!(g.len(), 2);
        let evens = g.get(BlockId::new(0, 0)).unwrap();
        assert_eq!(evens.iter().sum::<f64>(), 0.0 + 2.0 + 4.0);
    }

    #[test]
    fn join_update_applies_and_passes_through() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        let inc = r.flat_map("emit", |id, v| {
            if id.i < 2 {
                vec![(id, v + 100.0)]
            } else {
                vec![]
            }
        });
        let j = r.join_update("apply", inc, |_, v, us| {
            for u in us {
                *v += u;
            }
        });
        assert_eq!(*j.get(BlockId::new(0, 0)).unwrap(), 100.0); // 0 + (0+100)
        assert_eq!(*j.get(BlockId::new(1, 1)).unwrap(), 102.0); // 1 + (1+100)
        assert_eq!(*j.get(BlockId::new(5, 5)).unwrap(), 5.0); // untouched
    }

    #[test]
    fn shuffle_bytes_counted_multi_node() {
        let ctx = ctx(4);
        let r = small_rdd(&ctx);
        let before = ctx.total_shuffle_bytes();
        let k = r.flat_map("emit", |_, v| vec![(BlockId::new(0, 0), *v)]);
        let _ = k.reduce_by_key("sum", Rc::new(HashPartitioner::new(4)), |a, b| a + b);
        // With 4 nodes at least some records cross nodes.
        assert!(ctx.total_shuffle_bytes() > before);
    }

    #[test]
    fn collect_returns_all() {
        let ctx = ctx(2);
        let r = small_rdd(&ctx);
        let c = r.collect();
        assert_eq!(c.len(), 6);
    }

    #[test]
    fn virtual_time_advances() {
        let mut cfg = ClusterConfig::local();
        cfg.sched_overhead = 0.001;
        let ctx = SparkContext::new(cfg);
        let r = small_rdd(&ctx);
        let t0 = ctx.virtual_now();
        let _ = r.map_values("work", |_, v| {
            // Busy-ish loop so measured durations are nonzero.
            let mut acc = *v;
            for i in 0..2000 {
                acc += (i as f64).sqrt();
            }
            acc
        });
        assert!(ctx.virtual_now() > t0);
    }

    #[test]
    fn persist_and_memory_limit() {
        let mut cfg = ClusterConfig::local();
        cfg.mem_per_node = 100; // tiny
        let ctx = SparkContext::new(cfg);
        let items: Vec<(BlockId, crate::linalg::Matrix)> =
            vec![(BlockId::new(0, 0), crate::linalg::Matrix::zeros(10, 10))];
        let r = ctx.parallelize("m", items, Rc::new(HashPartitioner::new(1)));
        assert!(r.persist("m").is_err());
    }

    #[test]
    fn lineage_depth_grows_and_checkpoint_resets() {
        let ctx = ctx(1);
        let mut r = small_rdd(&ctx);
        for i in 0..12 {
            r = r.map_values(&format!("it{i}"), |_, v| *v);
        }
        assert!(ctx.lineage_depth(r.lineage_id) >= 12);
        r.checkpoint();
        assert_eq!(ctx.lineage_depth(r.lineage_id), 0);
    }
}
