//! RDD lineage tracking.
//!
//! The paper observes (§III-B) that the APSP loop creates a new RDD per
//! diagonal iteration whose lineage grows without bound, overwhelming the
//! Spark driver (which also schedules), and fixes it by checkpointing every
//! ~10 iterations. The engine executes eagerly but records the same DAG;
//! the driver model charges scheduling overhead that grows with the depth
//! of the RDD being computed, so disabling checkpointing measurably
//! degrades virtual time (the `ablation` benchmarks exercise this).

/// Node in the lineage DAG.
#[derive(Clone, Debug)]
pub struct LineageNode {
    pub id: usize,
    pub op: String,
    pub parents: Vec<usize>,
    /// Distance to the nearest checkpointed/root ancestor.
    pub depth: usize,
}

/// Append-only lineage DAG.
#[derive(Debug, Default)]
pub struct LineageGraph {
    nodes: Vec<LineageNode>,
}

impl LineageGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a new RDD produced by `op` from the given parents.
    pub fn add(&mut self, op: &str, parents: &[usize]) -> usize {
        let id = self.nodes.len();
        let depth = parents
            .iter()
            .map(|&p| self.nodes[p].depth + 1)
            .max()
            .unwrap_or(0);
        self.nodes.push(LineageNode { id, op: op.to_string(), parents: parents.to_vec(), depth });
        id
    }

    /// Mark an RDD as checkpointed: its lineage is pruned, depth resets.
    pub fn checkpoint(&mut self, id: usize) {
        self.nodes[id].depth = 0;
        self.nodes[id].parents.clear();
    }

    /// Depth of a node (0 for roots/checkpoints).
    pub fn depth(&self, id: usize) -> usize {
        self.nodes[id].depth
    }

    /// Number of recorded RDDs.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Count of ancestors reachable from `id` — the size of the lineage the
    /// driver would have to serialize/walk for recovery.
    pub fn ancestry_size(&self, id: usize) -> usize {
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![id];
        let mut count = 0;
        while let Some(x) = stack.pop() {
            if seen[x] {
                continue;
            }
            seen[x] = true;
            count += 1;
            stack.extend(&self.nodes[x].parents);
        }
        count - 1 // exclude self
    }

    /// Render the DAG as text (debugging / `isospark info --lineage`).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        for n in &self.nodes {
            out.push_str(&format!(
                "#{:<4} depth={:<3} {} <- {:?}\n",
                n.id, n.depth, n.op, n.parents
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_tracks_longest_parent_chain() {
        let mut g = LineageGraph::new();
        let a = g.add("parallelize", &[]);
        let b = g.add("map", &[a]);
        let c = g.add("flatMap", &[b]);
        let d = g.add("union", &[a, c]);
        assert_eq!(g.depth(a), 0);
        assert_eq!(g.depth(b), 1);
        assert_eq!(g.depth(c), 2);
        assert_eq!(g.depth(d), 3);
    }

    #[test]
    fn checkpoint_resets() {
        let mut g = LineageGraph::new();
        let mut cur = g.add("root", &[]);
        for _ in 0..20 {
            cur = g.add("iter", &[cur]);
        }
        assert_eq!(g.depth(cur), 20);
        g.checkpoint(cur);
        assert_eq!(g.depth(cur), 0);
        let next = g.add("iter", &[cur]);
        assert_eq!(g.depth(next), 1);
    }

    #[test]
    fn ancestry_size_counts_unique() {
        let mut g = LineageGraph::new();
        let a = g.add("a", &[]);
        let b = g.add("b", &[a]);
        let c = g.add("c", &[a, b]); // a reachable twice, counted once
        assert_eq!(g.ancestry_size(c), 2);
        g.checkpoint(b);
        assert_eq!(g.ancestry_size(c), 2); // c's own parents unchanged
        let d = g.add("d", &[b]);
        assert_eq!(g.ancestry_size(d), 1);
    }

    #[test]
    fn dump_contains_ops() {
        let mut g = LineageGraph::new();
        let a = g.add("parallelize", &[]);
        g.add("map", &[a]);
        let s = g.dump();
        assert!(s.contains("parallelize"));
        assert!(s.contains("map"));
    }
}
