//! Multi-core stage executor.
//!
//! Every stage the engine runs is a set of independent per-partition tasks
//! (the paper's Spark tasks). This module fans those tasks out over a pool
//! of OS worker threads — the *physical* executor — while the simulated
//! cluster ([`super::clock::VirtualClock`]) remains the *logical* one.
//! Results are returned in submission order regardless of which worker ran
//! what, so callers stay bit-deterministic: the only thing the worker count
//! changes is wall-clock time.
//!
//! Scheduling is a shared atomic cursor (dynamic load balancing — ragged
//! partitions and the APSP pivot row/column make static striping lumpy).
//! `workers == 1` short-circuits to a plain inline loop with zero thread
//! or locking overhead, which is also the reference execution the
//! determinism suite compares against.
//!
//! A panicking task does not tear down the pool with a poisoned-mutex
//! double panic: the first panic's payload is captured with its task
//! index, the remaining workers stop claiming work, and the payload is
//! re-raised on the driver thread — callers observe the *original* panic
//! (message and all), exactly as they would under sequential execution.

use super::fault::{backoff_ms, Inject, TaskPolicy};
use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a requested pool size: 0 means "all available cores", anything
/// else is taken literally; never returns 0. The single source of truth
/// for every pool in the crate (stage executor, `map_points`, the serve
/// worker pool).
pub(crate) fn resolve_workers(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Run `tasks` on up to `workers` OS threads, returning each task's output
/// in input order. `f` must be a pure function of its input for the
/// parallel execution to be observationally identical to the sequential
/// one (every closure the engine passes is). If a task panics, the first
/// panic is propagated to the caller with its original payload.
pub(crate) fn run_tasks<I, O, F>(workers: usize, tasks: Vec<I>, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(I) -> O + Sync,
{
    let n = tasks.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        // Same panic reporting as the pooled path below: sequential and
        // parallel failures must be indistinguishable to the caller (and
        // to whoever reads the driver log).
        let mut out = Vec::with_capacity(n);
        for (i, t) in tasks.into_iter().enumerate() {
            match catch_unwind(AssertUnwindSafe(|| f(t))) {
                Ok(o) => out.push(o),
                Err(payload) => {
                    eprintln!(
                        "engine executor: task {i} of {n} panicked; re-raising on the driver"
                    );
                    resume_unwind(payload);
                }
            }
        }
        return out;
    }

    // Each slot holds the pending input and, after execution, the output.
    // Slots are indexed by submission order, so the final collection is
    // deterministic no matter which worker claimed which task.
    let slots: Vec<Mutex<(Option<I>, Option<O>)>> =
        tasks.into_iter().map(|t| Mutex::new((Some(t), None))).collect();
    let cursor = AtomicUsize::new(0);
    // First panic wins: (task index, original payload). Later panics (rare
    // — workers stop claiming once `abort` is set) are dropped.
    let panicked: Mutex<Option<(usize, Box<dyn Any + Send>)>> = Mutex::new(None);
    let abort = AtomicBool::new(false);
    let f = &f;
    let slots_ref = &slots;
    let cursor_ref = &cursor;
    let panicked_ref = &panicked;
    let abort_ref = &abort;
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(move || loop {
                if abort_ref.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor_ref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let input = slots_ref[i].lock().unwrap().0.take().expect("task claimed twice");
                // AssertUnwindSafe: on panic the run is abandoned wholesale
                // (payload re-raised below), so no partially-updated state
                // is ever observed.
                match catch_unwind(AssertUnwindSafe(|| f(input))) {
                    Ok(out) => slots_ref[i].lock().unwrap().1 = Some(out),
                    Err(payload) => {
                        abort_ref.store(true, Ordering::Relaxed);
                        let mut first = panicked_ref.lock().unwrap();
                        if first.is_none() {
                            *first = Some((i, payload));
                        }
                        break;
                    }
                }
            });
        }
    });
    if let Some((i, payload)) = panicked.into_inner().unwrap() {
        eprintln!("engine executor: task {i} of {n} panicked; re-raising on the driver");
        resume_unwind(payload);
    }
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().1.expect("worker died before finishing task"))
        .collect()
}

/// [`run_tasks`] with a fault-tolerance policy in front of every task.
///
/// `policy: None` (the fault-free fast path) delegates straight to
/// [`run_tasks`] — no per-task branching, no extra allocation beyond the
/// closure adaptor. With a policy, each task runs an attempt loop of up to
/// [`crate::engine::fault::FaultPlan::max_attempts`]:
///
/// * An injected [`Inject::Panic`] / [`Inject::TransientErr`] aborts the
///   attempt *before the task body runs* — so `f` executes at most once
///   per task and retry is trivially idempotent even for closures that
///   consume their input — and charges capped exponential backoff to the
///   virtual clock (no real sleep; wall-clock is bounded by the work
///   itself).
/// * An injected [`Inject::StragglerDelay`] charges virtual delay, then
///   the attempt proceeds normally.
/// * A *real* panic from `f` is never retried: a deterministic task fails
///   deterministically, so retrying would at best waste attempts and at
///   worst (for input-consuming closures) succeed vacuously. The original
///   payload propagates immediately, exactly as under [`run_tasks`].
/// * Exhausting every attempt panics with the stage name, task index, and
///   attempt count wrapping the original failure message.
///
/// `f` takes `&mut I` (not `I`) so the retry loop can keep ownership of
/// the input across attempts — tasks whose inputs are un-clonable mutable
/// spans (Dijkstra rows, eigen paste targets) retry by re-borrowing.
///
/// Injection decisions key on the *global task index*, so the schedule —
/// and therefore the output — is identical for any worker count.
pub(crate) fn run_tasks_with_policy<I, O, F>(
    policy: Option<&TaskPolicy>,
    stage: &str,
    workers: usize,
    tasks: Vec<I>,
    f: F,
) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(&mut I) -> O + Sync,
{
    let Some(policy) = policy else {
        return run_tasks(workers, tasks, |mut t| f(&mut t));
    };
    let n = tasks.len();
    let indexed: Vec<(usize, I)> = tasks.into_iter().enumerate().collect();
    // Workers accumulate injected delay into integer atomics; the clock is
    // charged once below with the (order-independent) total, so virtual
    // time never depends on which worker recorded what first.
    let delay_before = policy.stats.virtual_delay_ms();
    let out = run_tasks(workers, indexed, |(i, mut input)| {
        attempt_loop(policy, stage, i, n, &mut input, &f)
    });
    let added = policy.stats.virtual_delay_ms().saturating_sub(delay_before);
    policy.charge_virtual_ms(added);
    out
}

/// Retry loop for one task under a policy; runs on the worker thread.
fn attempt_loop<I, O, F>(
    policy: &TaskPolicy,
    stage: &str,
    i: usize,
    n: usize,
    input: &mut I,
    f: &F,
) -> O
where
    F: Fn(&mut I) -> O + Sync,
{
    let max = policy.plan.max_attempts();
    let mut failed_before = false;
    for attempt in 0..max {
        let injected: Option<&'static str> = match policy.plan.decide(stage, i, attempt) {
            Some(Inject::Panic) => {
                policy.stats.record_injected_panic();
                Some("injected task panic")
            }
            Some(Inject::TransientErr) => {
                policy.stats.record_injected_error();
                Some("injected transient error")
            }
            Some(Inject::StragglerDelay(ms)) => {
                policy.stats.record_straggler(ms);
                None
            }
            None => None,
        };
        let failure = match injected {
            Some(msg) => msg,
            None => match catch_unwind(AssertUnwindSafe(|| f(input))) {
                Ok(out) => {
                    if failed_before {
                        policy.stats.record_recovered();
                    }
                    return out;
                }
                // Real panics are not retried — see the function docs.
                Err(payload) => resume_unwind(payload),
            },
        };
        failed_before = true;
        if attempt + 1 == max {
            policy.stats.record_exhausted();
            panic!("stage {stage}: task {i} of {n} failed after {max} attempts: {failure}");
        }
        policy.stats.record_retry(backoff_ms(attempt));
    }
    unreachable!("attempt loop either returns or panics")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let tasks: Vec<usize> = (0..100).collect();
        let out = run_tasks(4, tasks, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_and_parallel_agree() {
        let work = |i: usize| -> f64 {
            let mut acc = i as f64;
            for k in 0..100 {
                acc += (k as f64).sqrt();
            }
            acc
        };
        let seq = run_tasks(1, (0..64).collect(), work);
        let par = run_tasks(8, (0..64).collect(), work);
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<usize> = vec![];
        assert!(run_tasks(4, empty, |i: usize| i).is_empty());
        assert_eq!(run_tasks(4, vec![7usize], |i| i + 1), vec![8]);
    }

    #[test]
    fn more_workers_than_tasks() {
        let out = run_tasks(64, vec![1usize, 2, 3], |i| i);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn panicking_task_reraises_original_payload() {
        // Regression: a worker panic used to surface as a poisoned-mutex
        // "worker died before finishing task" double panic, hiding the
        // actual failure. The original message must reach the caller.
        let result = std::panic::catch_unwind(|| {
            run_tasks(4, (0..16).collect::<Vec<usize>>(), |i| {
                if i == 7 {
                    panic!("task 7 exploded with context");
                }
                i * 2
            })
        });
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("task 7 exploded with context"), "payload lost: {msg:?}");
    }

    #[test]
    fn sequential_panic_also_propagates() {
        let result = std::panic::catch_unwind(|| {
            run_tasks(1, vec![0usize], |_| -> usize { panic!("seq boom") })
        });
        assert!(result.is_err());
    }

    #[test]
    fn remaining_tasks_not_spuriously_poisoned_after_panic() {
        // Many tasks, early panic: the pool must shut down cleanly (no
        // secondary panics from poisoned slots) and still re-raise.
        for _ in 0..8 {
            let result = std::panic::catch_unwind(|| {
                run_tasks(8, (0..256).collect::<Vec<usize>>(), |i| {
                    if i == 0 {
                        panic!("early");
                    }
                    i
                })
            });
            assert!(result.is_err());
        }
    }

    use crate::config::ClusterConfig;
    use crate::engine::fault::{FaultPlan, ResilienceStats, TaskPolicy};
    use crate::engine::SparkContext;
    use std::sync::Arc;

    fn test_policy(rate: f64, seed: u64, attempts: usize) -> TaskPolicy {
        TaskPolicy::new(
            FaultPlan::new(rate, seed, attempts),
            Arc::new(ResilienceStats::default()),
            SparkContext::new(ClusterConfig::local()),
        )
    }

    #[test]
    fn no_policy_is_the_plain_fast_path() {
        let out =
            run_tasks_with_policy(None, "s", 4, (0..32).collect::<Vec<usize>>(), |i| *i * 3);
        assert_eq!(out, (0..32).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn injected_faults_recover_bit_identically_across_worker_counts() {
        // Rate 0.3 over 5 attempts: P(exhaust) per task ≈ 1.4e-8, so this
        // deterministic schedule recovers every task — and must produce
        // the same outputs as a fault-free run, for any pool size.
        let clean =
            run_tasks_with_policy(None, "stage", 1, (0..256).collect::<Vec<usize>>(), |i| {
                (*i as f64).sqrt()
            });
        for workers in [1usize, 4, 8] {
            let p = test_policy(0.3, 42, 5);
            let chaotic = run_tasks_with_policy(
                Some(&p),
                "stage",
                workers,
                (0..256).collect::<Vec<usize>>(),
                |i| (*i as f64).sqrt(),
            );
            for (a, b) in clean.iter().zip(&chaotic) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
            }
            let s = p.stats.snapshot();
            assert!(
                s.recovered_tasks > 0,
                "rate 0.3 over 256 tasks must hit something (workers={workers})"
            );
            assert_eq!(s.exhausted_tasks, 0, "workers={workers}");
        }
    }

    #[test]
    fn fault_schedule_is_worker_count_invariant() {
        // The *counters*, not just the outputs: which attempts fail is a
        // pure function of (seed, stage, task, attempt), so two pool
        // sizes must record identical injection/retry/recovery totals.
        let count = |workers: usize| {
            let p = test_policy(0.3, 7, 5);
            let _ = run_tasks_with_policy(
                Some(&p),
                "stage",
                workers,
                (0..200).collect::<Vec<usize>>(),
                |i| *i,
            );
            p.stats.snapshot()
        };
        assert_eq!(count(1), count(8));
    }

    #[test]
    fn exhausted_retries_carry_stage_and_attempt_count() {
        let p = test_policy(1.0, 3, 4);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_tasks_with_policy(Some(&p), "apsp:p3[0]", 2, vec![0usize, 1], |i| *i)
        }));
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("apsp:p3[0]"), "stage name lost: {msg:?}");
        assert!(msg.contains("failed after 4 attempts"), "attempt count lost: {msg:?}");
        assert!(p.stats.snapshot().exhausted_tasks >= 1);
    }

    #[test]
    fn real_panics_are_not_retried_and_keep_their_payload() {
        let p = test_policy(0.0, 0, 5);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_tasks_with_policy(Some(&p), "s", 2, (0..8).collect::<Vec<usize>>(), |i| {
                if *i == 3 {
                    panic!("genuine bug in task 3");
                }
                *i
            })
        }));
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("genuine bug in task 3"), "payload lost: {msg:?}");
        assert_eq!(p.stats.snapshot().retries, 0, "real panics must not be retried");
    }

    #[test]
    fn straggler_and_backoff_delay_is_charged_to_the_virtual_clock() {
        let p = test_policy(0.5, 11, 5);
        let ctx = p.ctx.clone();
        let before = ctx.virtual_now();
        let _ = run_tasks_with_policy(
            Some(&p),
            "stage",
            4,
            (0..128).collect::<Vec<usize>>(),
            |i| *i,
        );
        let delay_ms = p.stats.virtual_delay_ms();
        assert!(delay_ms > 0, "rate 0.5 over 128 tasks must delay something");
        let expect = delay_ms as f64 / 1000.0;
        assert!(
            (ctx.virtual_now() - before - expect).abs() < 1e-9,
            "clock moved {} for {expect}",
            ctx.virtual_now() - before
        );
    }
}
