//! Multi-core stage executor.
//!
//! Every stage the engine runs is a set of independent per-partition tasks
//! (the paper's Spark tasks). This module fans those tasks out over a pool
//! of OS worker threads — the *physical* executor — while the simulated
//! cluster ([`super::clock::VirtualClock`]) remains the *logical* one.
//! Results are returned in submission order regardless of which worker ran
//! what, so callers stay bit-deterministic: the only thing the worker count
//! changes is wall-clock time.
//!
//! Scheduling is a shared atomic cursor (dynamic load balancing — ragged
//! partitions and the APSP pivot row/column make static striping lumpy).
//! `workers == 1` short-circuits to a plain inline loop with zero thread
//! or locking overhead, which is also the reference execution the
//! determinism suite compares against.
//!
//! A panicking task does not tear down the pool with a poisoned-mutex
//! double panic: the first panic's payload is captured with its task
//! index, the remaining workers stop claiming work, and the payload is
//! re-raised on the driver thread — callers observe the *original* panic
//! (message and all), exactly as they would under sequential execution.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a requested pool size: 0 means "all available cores", anything
/// else is taken literally; never returns 0. The single source of truth
/// for every pool in the crate (stage executor, `map_points`, the serve
/// worker pool).
pub(crate) fn resolve_workers(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Run `tasks` on up to `workers` OS threads, returning each task's output
/// in input order. `f` must be a pure function of its input for the
/// parallel execution to be observationally identical to the sequential
/// one (every closure the engine passes is). If a task panics, the first
/// panic is propagated to the caller with its original payload.
pub(crate) fn run_tasks<I, O, F>(workers: usize, tasks: Vec<I>, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(I) -> O + Sync,
{
    let n = tasks.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return tasks.into_iter().map(f).collect();
    }

    // Each slot holds the pending input and, after execution, the output.
    // Slots are indexed by submission order, so the final collection is
    // deterministic no matter which worker claimed which task.
    let slots: Vec<Mutex<(Option<I>, Option<O>)>> =
        tasks.into_iter().map(|t| Mutex::new((Some(t), None))).collect();
    let cursor = AtomicUsize::new(0);
    // First panic wins: (task index, original payload). Later panics (rare
    // — workers stop claiming once `abort` is set) are dropped.
    let panicked: Mutex<Option<(usize, Box<dyn Any + Send>)>> = Mutex::new(None);
    let abort = AtomicBool::new(false);
    let f = &f;
    let slots_ref = &slots;
    let cursor_ref = &cursor;
    let panicked_ref = &panicked;
    let abort_ref = &abort;
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(move || loop {
                if abort_ref.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor_ref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let input = slots_ref[i].lock().unwrap().0.take().expect("task claimed twice");
                // AssertUnwindSafe: on panic the run is abandoned wholesale
                // (payload re-raised below), so no partially-updated state
                // is ever observed.
                match catch_unwind(AssertUnwindSafe(|| f(input))) {
                    Ok(out) => slots_ref[i].lock().unwrap().1 = Some(out),
                    Err(payload) => {
                        abort_ref.store(true, Ordering::Relaxed);
                        let mut first = panicked_ref.lock().unwrap();
                        if first.is_none() {
                            *first = Some((i, payload));
                        }
                        break;
                    }
                }
            });
        }
    });
    if let Some((i, payload)) = panicked.into_inner().unwrap() {
        eprintln!("engine executor: task {i} of {n} panicked; re-raising on the driver");
        resume_unwind(payload);
    }
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().1.expect("worker died before finishing task"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let tasks: Vec<usize> = (0..100).collect();
        let out = run_tasks(4, tasks, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_and_parallel_agree() {
        let work = |i: usize| -> f64 {
            let mut acc = i as f64;
            for k in 0..100 {
                acc += (k as f64).sqrt();
            }
            acc
        };
        let seq = run_tasks(1, (0..64).collect(), work);
        let par = run_tasks(8, (0..64).collect(), work);
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<usize> = vec![];
        assert!(run_tasks(4, empty, |i: usize| i).is_empty());
        assert_eq!(run_tasks(4, vec![7usize], |i| i + 1), vec![8]);
    }

    #[test]
    fn more_workers_than_tasks() {
        let out = run_tasks(64, vec![1usize, 2, 3], |i| i);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn panicking_task_reraises_original_payload() {
        // Regression: a worker panic used to surface as a poisoned-mutex
        // "worker died before finishing task" double panic, hiding the
        // actual failure. The original message must reach the caller.
        let result = std::panic::catch_unwind(|| {
            run_tasks(4, (0..16).collect::<Vec<usize>>(), |i| {
                if i == 7 {
                    panic!("task 7 exploded with context");
                }
                i * 2
            })
        });
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("task 7 exploded with context"), "payload lost: {msg:?}");
    }

    #[test]
    fn sequential_panic_also_propagates() {
        let result = std::panic::catch_unwind(|| {
            run_tasks(1, vec![0usize], |_| -> usize { panic!("seq boom") })
        });
        assert!(result.is_err());
    }

    #[test]
    fn remaining_tasks_not_spuriously_poisoned_after_panic() {
        // Many tasks, early panic: the pool must shut down cleanly (no
        // secondary panics from poisoned slots) and still re-raise.
        for _ in 0..8 {
            let result = std::panic::catch_unwind(|| {
                run_tasks(8, (0..256).collect::<Vec<usize>>(), |i| {
                    if i == 0 {
                        panic!("early");
                    }
                    i
                })
            });
            assert!(result.is_err());
        }
    }
}
