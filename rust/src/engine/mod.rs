//! The Spark-substitute blocked dataflow engine.
//!
//! Spark itself is not available (nor a cluster); per DESIGN.md §3 the
//! engine executes every task *really* — results are bit-exact — while
//! replaying measured task durations onto a simulated cluster: a
//! [`clock::VirtualClock`] of `nodes × cores`, a GbE [`network`] model for
//! shuffles/collects/broadcasts, a [`lineage`] DAG driving the
//! driver-overhead model, and an executor [`context::SparkContext`] memory
//! model that rejects runs exceeding node memory (Table I's `-` entries).
//!
//! Real execution is multi-core: each stage's per-partition tasks run
//! concurrently on an OS worker-thread pool ([`executor`], sized by
//! [`crate::config::ClusterConfig::parallelism`]), and shuffle payloads
//! move as `Arc`-shared blocks with copy-on-write updates — replicating a
//! pivot block to a whole row costs one refcount per destination, not one
//! deep copy. Worker count and sharing never change results: values, record
//! order, lineage shape, task counts and shuffle bytes are bit-identical
//! to sequential execution. Virtual time is still replayed from measured
//! durations, so it varies run to run exactly as it did sequentially.
//!
//! The op vocabulary ([`rdd::BlockRdd`]) mirrors the PySpark subset the
//! paper uses: `parallelize`, `mapValues`, `flatMap`, `filter`,
//! `reduceByKey`, `groupByKey`, `union+combineByKey` (as `join_update`),
//! `collect`, `broadcast`, `checkpoint`.

pub mod block;
pub mod clock;
pub mod context;
pub mod durable;
pub mod executor;
pub mod fault;
pub mod lineage;
pub mod metrics;
pub mod network;
pub mod partitioner;
pub mod rdd;

pub use block::{BlockId, HasBytes};
pub use context::SparkContext;
pub use partitioner::{GridPartitioner, HashPartitioner, Partitioner, UpperTriangularPartitioner};
pub use rdd::{BlockRdd, BlockRef, Keyed};
