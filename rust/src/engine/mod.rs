//! The Spark-substitute blocked dataflow engine.
//!
//! Spark itself is not available (nor a cluster); per DESIGN.md §3 the
//! engine executes every task *really* — results are bit-exact — while
//! replaying measured task durations onto a simulated cluster: a
//! [`clock::VirtualClock`] of `nodes × cores`, a GbE [`network`] model for
//! shuffles/collects/broadcasts, a [`lineage`] DAG driving the
//! driver-overhead model, and an executor [`context::SparkContext`] memory
//! model that rejects runs exceeding node memory (Table I's `-` entries).
//!
//! The op vocabulary ([`rdd::BlockRdd`]) mirrors the PySpark subset the
//! paper uses: `parallelize`, `mapValues`, `flatMap`, `filter`,
//! `reduceByKey`, `groupByKey`, `union+combineByKey` (as `join_update`),
//! `collect`, `broadcast`, `checkpoint`.

pub mod block;
pub mod clock;
pub mod context;
pub mod fault;
pub mod lineage;
pub mod metrics;
pub mod network;
pub mod partitioner;
pub mod rdd;

pub use block::{BlockId, HasBytes};
pub use context::SparkContext;
pub use partitioner::{GridPartitioner, HashPartitioner, Partitioner, UpperTriangularPartitioner};
pub use rdd::{BlockRdd, Keyed};
