//! Virtual-time executor model.
//!
//! Every task in a stage really executes (on this machine's single core)
//! and its measured duration is replayed onto a simulated cluster of
//! `nodes × cores` virtual cores: a task assigned to node `v` starts on
//! `v`'s earliest-free core no sooner than the stage's start, and the stage
//! (Spark stages are barriers) completes when the last task finishes.
//! Network and driver charges advance the global clock serially.

/// Virtual cluster clock: per-core free times plus a global barrier `now`.
#[derive(Clone, Debug)]
pub struct VirtualClock {
    /// `free[v][c]` = virtual time when core `c` of node `v` becomes idle.
    free: Vec<Vec<f64>>,
    now: f64,
}

/// One schedulable task: which node it must run on (data locality) and its
/// measured duration in seconds.
#[derive(Clone, Copy, Debug)]
pub struct Task {
    pub node: usize,
    pub duration: f64,
}

impl VirtualClock {
    pub fn new(nodes: usize, cores_per_node: usize) -> Self {
        assert!(nodes > 0 && cores_per_node > 0);
        Self { free: vec![vec![0.0; cores_per_node]; nodes], now: 0.0 }
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Serial charge on the critical path (driver work, network transfer).
    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative advance {dt}");
        self.now += dt;
    }

    /// Run one barrier stage. Tasks are placed greedily in the given order
    /// onto their node's earliest-free core. Returns the stage makespan
    /// (time from stage start to last task completion); `now` advances to
    /// the barrier.
    pub fn run_stage(&mut self, tasks: &[Task]) -> f64 {
        if tasks.is_empty() {
            return 0.0;
        }
        let start = self.now;
        // Cores idle before the stage cannot start tasks in the past.
        for node in &mut self.free {
            for c in node.iter_mut() {
                *c = c.max(start);
            }
        }
        let mut end = start;
        for t in tasks {
            let cores = &mut self.free[t.node];
            // Earliest-free core of the required node.
            let (ci, _) = cores
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let begin = cores[ci];
            let fin = begin + t.duration;
            cores[ci] = fin;
            end = end.max(fin);
        }
        self.now = end;
        end - start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_parallelism() {
        // 4 equal tasks on 4 single-core nodes -> makespan = 1 task.
        let mut c = VirtualClock::new(4, 1);
        let tasks: Vec<Task> = (0..4).map(|v| Task { node: v, duration: 2.0 }).collect();
        let span = c.run_stage(&tasks);
        assert!((span - 2.0).abs() < 1e-12);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn serialization_on_one_node() {
        // 4 equal tasks all pinned to node 0 with 1 core -> serial.
        let mut c = VirtualClock::new(2, 1);
        let tasks: Vec<Task> = (0..4).map(|_| Task { node: 0, duration: 1.0 }).collect();
        assert!((c.run_stage(&tasks) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn multicore_node() {
        // 4 tasks on one 2-core node -> 2 waves.
        let mut c = VirtualClock::new(1, 2);
        let tasks: Vec<Task> = (0..4).map(|_| Task { node: 0, duration: 1.0 }).collect();
        assert!((c.run_stage(&tasks) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stage_barrier_and_advance() {
        let mut c = VirtualClock::new(2, 1);
        c.run_stage(&[Task { node: 0, duration: 5.0 }, Task { node: 1, duration: 1.0 }]);
        // Barrier: both nodes now free at t=5.
        assert!((c.now() - 5.0).abs() < 1e-12);
        c.advance(0.5);
        let span = c.run_stage(&[Task { node: 1, duration: 1.0 }]);
        assert!((span - 1.0).abs() < 1e-12);
        assert!((c.now() - 6.5).abs() < 1e-12);
    }

    #[test]
    fn imbalanced_tasks_straggler() {
        // One long task dominates the makespan.
        let mut c = VirtualClock::new(4, 1);
        let mut tasks: Vec<Task> = (0..3).map(|v| Task { node: v, duration: 0.1 }).collect();
        tasks.push(Task { node: 3, duration: 9.0 });
        assert!((c.run_stage(&tasks) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stage_is_free() {
        let mut c = VirtualClock::new(1, 1);
        assert_eq!(c.run_stage(&[]), 0.0);
        assert_eq!(c.now(), 0.0);
    }
}
