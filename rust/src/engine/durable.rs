//! Durable checkpoints: real files behind the engine's simulated
//! `checkpoint()`.
//!
//! The simulated checkpoint (`SparkContext::charge_checkpoint`) prunes
//! lineage and charges virtual disk time but keeps every block in memory —
//! fine for pricing the paper's checkpoint-cadence trade-off, useless for
//! surviving a driver crash. When `--checkpoint-dir` is set, iterative
//! drivers (the APSP pivot loop, the streaming fit) additionally spill
//! their state through this store and restore from the newest *valid*
//! checkpoint on startup, skipping already-completed iterations.
//!
//! On disk a checkpoint is a directory per `(job, step)`:
//!
//! ```text
//! <root>/<job>/step-<N>/
//!   manifest.json          # kind, job, step, per-file shapes + checksums
//!   block-<i>-<j>.bin      # one data::io binary matrix per block
//! ```
//!
//! `job` is a caller-chosen key that must *bind the checkpoint to its
//! inputs* — the drivers embed an FNV fingerprint of the input data and
//! the relevant config, so a checkpoint directory reused across different
//! runs can never serve stale state: a different input hashes to a
//! different job and simply finds no checkpoint.
//!
//! Integrity follows the model-artifact manifest idiom
//! ([`crate::model`]): every block file's FNV-1a-64 checksum is recorded
//! in the manifest and re-verified on load; [`CheckpointStore::load`]
//! fails with context naming the offending file, and
//! [`CheckpointStore::latest_valid`] scans steps newest-first, skipping
//! (with a stderr note) any that fail validation — a truncated spill from
//! a killed run degrades to the previous step instead of poisoning the
//! restore.
//!
//! Restores are bit-exact: blocks round-trip through the little-endian
//! f64 binary format, so a run resumed from a checkpoint reproduces the
//! uninterrupted run's embedding bitwise (enforced by the chaos suite).

use super::block::BlockId;
use crate::data::io::{file_fnv1a64, read_bin, write_bin};
use crate::linalg::Matrix;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::PathBuf;

/// Manifest `kind` tag (defence against pointing the loader at some other
/// manifest, e.g. a model artifact).
const KIND: &str = "isospark-checkpoint";
/// On-disk checkpoint format version this build writes and reads.
const FORMAT_VERSION: usize = 1;
/// Manifest file name inside a step directory.
const MANIFEST_FILE: &str = "manifest.json";

/// A directory-backed store of durable checkpoints, rooted at
/// `--checkpoint-dir`.
#[derive(Clone, Debug)]
pub struct CheckpointStore {
    root: PathBuf,
}

impl CheckpointStore {
    /// A store rooted at `root` (created lazily on first save).
    pub fn new(root: impl Into<PathBuf>) -> Self {
        Self { root: root.into() }
    }

    fn step_dir(&self, job: &str, step: usize) -> PathBuf {
        self.root.join(job).join(format!("step-{step}"))
    }

    /// Spill `blocks` as checkpoint `step` of `job`, replacing any previous
    /// spill of the same step. Returns the payload bytes written (block
    /// files only, not the manifest). The manifest is written *last*, so a
    /// step directory without one (a killed run mid-spill) is never valid.
    pub fn save(&self, job: &str, step: usize, blocks: &[(BlockId, &Matrix)]) -> Result<u64> {
        let dir = self.step_dir(job, step);
        // Clear any partial previous attempt at this step.
        if dir.exists() {
            std::fs::remove_dir_all(&dir).with_context(|| format!("clear {dir:?}"))?;
        }
        std::fs::create_dir_all(&dir).with_context(|| format!("create {dir:?}"))?;
        let mut files: Vec<(String, Json)> = Vec::new();
        let mut bytes = 0u64;
        for (id, m) in blocks {
            let name = format!("block-{}-{}.bin", id.i, id.j);
            let path = dir.join(&name);
            write_bin(&path, m).with_context(|| format!("spill {name}"))?;
            bytes += std::fs::metadata(&path).map(|md| md.len()).unwrap_or(0);
            let sum = file_fnv1a64(&path).with_context(|| format!("checksum {name}"))?;
            files.push((
                name,
                Json::obj(vec![
                    ("i", Json::num(id.i as f64)),
                    ("j", Json::num(id.j as f64)),
                    ("rows", Json::num(m.nrows() as f64)),
                    ("cols", Json::num(m.ncols() as f64)),
                    ("fnv1a64", Json::str(format!("{sum:016x}"))),
                ]),
            ));
        }
        let refs: Vec<(&str, Json)> = files.iter().map(|(n, j)| (n.as_str(), j.clone())).collect();
        let manifest = Json::obj(vec![
            ("kind", Json::str(KIND)),
            ("format_version", Json::num(FORMAT_VERSION as f64)),
            ("job", Json::str(job)),
            ("step", Json::num(step as f64)),
            ("files", Json::obj(refs)),
        ]);
        let mpath = dir.join(MANIFEST_FILE);
        std::fs::write(&mpath, manifest.to_string()).with_context(|| format!("write {mpath:?}"))?;
        Ok(bytes)
    }

    /// Load checkpoint `step` of `job`, verifying the manifest kind, job
    /// binding, and every block's checksum and shape. Every failure names
    /// the offending file or field.
    pub fn load(&self, job: &str, step: usize) -> Result<Vec<(BlockId, Matrix)>> {
        let dir = self.step_dir(job, step);
        let mpath = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read checkpoint manifest {mpath:?}"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parse checkpoint manifest {}: {e}", mpath.display()))?;
        let kind = j.get("kind").and_then(Json::as_str).unwrap_or("<missing>");
        if kind != KIND {
            bail!("{}: kind {kind:?} is not a checkpoint manifest ({KIND:?})", mpath.display());
        }
        let version = j.get("format_version").and_then(index_field).unwrap_or(0);
        if version != FORMAT_VERSION {
            bail!(
                "{}: format version {version} (this build reads {FORMAT_VERSION})",
                mpath.display()
            );
        }
        let bound = j.get("job").and_then(Json::as_str).unwrap_or("<missing>");
        if bound != job {
            bail!("{}: bound to job {bound:?}, expected {job:?}", mpath.display());
        }
        let Some(Json::Obj(fm)) = j.get("files") else {
            bail!("{}: missing \"files\" object", mpath.display());
        };
        let mut out = Vec::with_capacity(fm.len());
        for (name, entry) in fm {
            let want = |key: &str| -> Result<usize> {
                entry.get(key).and_then(index_field).ok_or_else(|| {
                    anyhow!("{}: file {name}: missing/non-integer {key:?}", mpath.display())
                })
            };
            let (i, jj) = (want("i")?, want("j")?);
            let (rows, cols) = (want("rows")?, want("cols")?);
            let want_sum = entry
                .get("fnv1a64")
                .and_then(Json::as_str)
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| {
                    anyhow!("{}: file {name}: missing/garbled fnv1a64", mpath.display())
                })?;
            let path = dir.join(name);
            let got_sum = file_fnv1a64(&path)?;
            if got_sum != want_sum {
                bail!(
                    "{}: checksum mismatch (manifest {want_sum:016x}, file {got_sum:016x}) — \
                     checkpoint corrupt?",
                    path.display()
                );
            }
            let m = read_bin(&path).with_context(|| format!("load checkpoint block {name}"))?;
            if (m.nrows(), m.ncols()) != (rows, cols) {
                bail!(
                    "{}: stored shape {}×{} != manifest {rows}×{cols}",
                    path.display(),
                    m.nrows(),
                    m.ncols()
                );
            }
            out.push((BlockId::new(i, jj), m));
        }
        // BTreeMap iteration is lexicographic on file names; re-key by id so
        // callers get a deterministic block order independent of naming.
        out.sort_by_key(|(id, _)| *id);
        Ok(out)
    }

    /// Steps present on disk for `job` (any directory named `step-<N>`,
    /// valid or not), descending.
    fn steps(&self, job: &str) -> Vec<usize> {
        let Ok(entries) = std::fs::read_dir(self.root.join(job)) else {
            return Vec::new();
        };
        let mut steps: Vec<usize> = entries
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                e.file_name().to_str()?.strip_prefix("step-")?.parse::<usize>().ok()
            })
            .collect();
        steps.sort_unstable_by(|a, b| b.cmp(a));
        steps
    }

    /// The newest checkpoint of `job` that passes full validation, or
    /// `None` when the job has no usable checkpoint at all. Invalid steps
    /// (truncated spill, corrupt block, foreign manifest) are skipped with
    /// a stderr note — restore degrades instead of failing.
    pub fn latest_valid(&self, job: &str) -> Option<(usize, Vec<(BlockId, Matrix)>)> {
        for step in self.steps(job) {
            match self.load(job, step) {
                Ok(blocks) => return Some((step, blocks)),
                Err(e) => {
                    eprintln!("checkpoint {job}/step-{step} unusable, trying older: {e:#}");
                }
            }
        }
        None
    }
}

/// Strict non-negative integer from a JSON number (same rationale as the
/// model manifest: hand-edited or bit-rotted manifests fail loudly).
fn index_field(j: &Json) -> Option<usize> {
    let x = j.as_f64()?;
    if x.is_finite() && x.fract() == 0.0 && (0.0..=9e15).contains(&x) {
        Some(x as usize)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(name: &str) -> CheckpointStore {
        let dir =
            std::env::temp_dir().join(format!("isospark_durable_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        CheckpointStore::new(dir)
    }

    fn toy_blocks() -> Vec<(BlockId, Matrix)> {
        vec![
            (BlockId::new(0, 0), Matrix::from_rows(&[vec![1.5, -2.0], vec![0.25, 1e-3]])),
            (BlockId::new(0, 1), Matrix::from_rows(&[vec![std::f64::consts::PI]])),
            (BlockId::new(1, 1), Matrix::zeros(3, 2)),
        ]
    }

    fn save_toy(store: &CheckpointStore, job: &str, step: usize) -> u64 {
        let blocks = toy_blocks();
        let refs: Vec<(BlockId, &Matrix)> = blocks.iter().map(|(id, m)| (*id, m)).collect();
        store.save(job, step, &refs).unwrap()
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let store = tmp_store("roundtrip");
        let bytes = save_toy(&store, "job-a", 3);
        assert!(bytes > 0);
        let loaded = store.load("job-a", 3).unwrap();
        let original = toy_blocks();
        assert_eq!(loaded.len(), original.len());
        for ((id_a, m_a), (id_b, m_b)) in loaded.iter().zip(&original) {
            assert_eq!(id_a, id_b);
            let bits_a: Vec<u64> = m_a.as_slice().iter().map(|x| x.to_bits()).collect();
            let bits_b: Vec<u64> = m_b.as_slice().iter().map(|x| x.to_bits()).collect();
            assert_eq!(bits_a, bits_b);
        }
    }

    #[test]
    fn latest_valid_prefers_newest() {
        let store = tmp_store("newest");
        save_toy(&store, "j", 2);
        save_toy(&store, "j", 10);
        save_toy(&store, "j", 7);
        let (step, blocks) = store.latest_valid("j").unwrap();
        assert_eq!(step, 10);
        assert_eq!(blocks.len(), 3);
        assert_eq!(store.latest_valid("other-job"), None);
    }

    #[test]
    fn corrupt_block_is_rejected_with_checksum_context() {
        let store = tmp_store("corrupt");
        save_toy(&store, "j", 1);
        // Flip one payload byte; the file still parses as a matrix.
        let path = store.step_dir("j", 1).join("block-0-0.bin");
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", store.load("j", 1).unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("block-0-0.bin"), "{err}");
    }

    #[test]
    fn truncated_block_is_rejected() {
        let store = tmp_store("truncated");
        save_toy(&store, "j", 1);
        let path = store.step_dir("j", 1).join("block-0-1.bin");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        let err = format!("{:#}", store.load("j", 1).unwrap_err());
        assert!(err.contains("block-0-1.bin"), "{err}");
    }

    #[test]
    fn latest_valid_skips_bad_steps() {
        let store = tmp_store("skip");
        save_toy(&store, "j", 1);
        save_toy(&store, "j", 2);
        // Ruin step 2 (the newest): missing manifest = killed mid-spill.
        std::fs::remove_file(store.step_dir("j", 2).join(MANIFEST_FILE)).unwrap();
        let (step, _) = store.latest_valid("j").unwrap();
        assert_eq!(step, 1);
    }

    #[test]
    fn manifest_binds_job_and_kind() {
        let store = tmp_store("binding");
        save_toy(&store, "j", 1);
        // A manifest from a different job must not be served.
        let dir = store.step_dir("other", 4);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::copy(
            store.step_dir("j", 1).join(MANIFEST_FILE),
            dir.join(MANIFEST_FILE),
        )
        .unwrap();
        for (id, _) in toy_blocks() {
            std::fs::copy(
                store.step_dir("j", 1).join(format!("block-{}-{}.bin", id.i, id.j)),
                dir.join(format!("block-{}-{}.bin", id.i, id.j)),
            )
            .unwrap();
        }
        let err = format!("{:#}", store.load("other", 4).unwrap_err());
        assert!(err.contains("bound to job"), "{err}");
        // A foreign manifest kind is refused too.
        let mpath = store.step_dir("j", 1).join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace(KIND, "some-other-artifact")).unwrap();
        let err = format!("{:#}", store.load("j", 1).unwrap_err());
        assert!(err.contains("kind"), "{err}");
    }

    #[test]
    fn resave_replaces_partial_step() {
        let store = tmp_store("resave");
        save_toy(&store, "j", 5);
        // Leave debris that a naive re-save would merge with.
        std::fs::write(store.step_dir("j", 5).join("block-9-9.bin"), b"junk").unwrap();
        save_toy(&store, "j", 5);
        let blocks = store.load("j", 5).unwrap();
        assert_eq!(blocks.len(), 3);
        assert!(!store.step_dir("j", 5).join("block-9-9.bin").exists());
    }
}
