//! Per-stage execution metrics: real compute time, virtual cluster time,
//! shuffle volumes, task counts. The scalability tables are produced from
//! the virtual clock; the §Perf work reads the real timings.
//!
//! Also home of the **offload accounting** ([`OffloadStats`]): per-op
//! atomic counters of how every PJRT-eligible block operation was served —
//! exact-shape artifact, padded artifact, or counted fallback to the
//! native kernel. The runtime records into these from every worker thread;
//! [`crate::backend::Backend`] and `isospark info`/`run` surface them as
//! offload-coverage fractions.

use crate::util::fmt::{human_bytes, human_duration, render_table};
use std::sync::atomic::{AtomicU64, Ordering};

/// Record of one executed stage.
#[derive(Clone, Debug)]
pub struct StageMetrics {
    pub name: String,
    pub tasks: usize,
    /// Sum of measured single-core task durations (real seconds).
    pub compute_real: f64,
    /// Stage makespan on the virtual cluster.
    pub virtual_span: f64,
    /// Bytes that crossed the simulated network.
    pub shuffle_bytes: u64,
    /// Virtual seconds charged to the network for this stage.
    pub network_time: f64,
    /// Virtual seconds charged to the driver (scheduling × lineage).
    pub driver_time: f64,
}

/// Accumulated metrics for a run.
#[derive(Debug, Default)]
pub struct Metrics {
    pub stages: Vec<StageMetrics>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, s: StageMetrics) {
        self.stages.push(s);
    }

    pub fn total_compute_real(&self) -> f64 {
        self.stages.iter().map(|s| s.compute_real).sum()
    }

    pub fn total_shuffle_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.shuffle_bytes).sum()
    }

    pub fn total_network_time(&self) -> f64 {
        self.stages.iter().map(|s| s.network_time).sum()
    }

    pub fn total_driver_time(&self) -> f64 {
        self.stages.iter().map(|s| s.driver_time).sum()
    }

    pub fn total_tasks(&self) -> usize {
        self.stages.iter().map(|s| s.tasks).sum()
    }

    /// Aggregate stages by a prefix of their name (e.g. "knn", "apsp").
    pub fn by_prefix(&self, prefix: &str) -> StageMetrics {
        let mut agg = StageMetrics {
            name: prefix.to_string(),
            tasks: 0,
            compute_real: 0.0,
            virtual_span: 0.0,
            shuffle_bytes: 0,
            network_time: 0.0,
            driver_time: 0.0,
        };
        for s in self.stages.iter().filter(|s| s.name.starts_with(prefix)) {
            agg.tasks += s.tasks;
            agg.compute_real += s.compute_real;
            agg.virtual_span += s.virtual_span;
            agg.shuffle_bytes += s.shuffle_bytes;
            agg.network_time += s.network_time;
            agg.driver_time += s.driver_time;
        }
        agg
    }

    /// Text report of the per-prefix aggregates.
    pub fn report(&self, prefixes: &[&str]) -> String {
        let mut rows = vec![vec![
            "stage".to_string(),
            "tasks".to_string(),
            "compute(real)".to_string(),
            "virtual".to_string(),
            "shuffle".to_string(),
            "net".to_string(),
            "driver".to_string(),
        ]];
        for p in prefixes {
            let a = self.by_prefix(p);
            rows.push(vec![
                a.name,
                a.tasks.to_string(),
                human_duration(a.compute_real),
                human_duration(a.virtual_span),
                human_bytes(a.shuffle_bytes),
                human_duration(a.network_time),
                human_duration(a.driver_time),
            ]);
        }
        render_table(&rows)
    }
}

/// High-water mark of cluster-wide resident bytes, fed by the residency
/// model (`SparkContext::set_resident`) every time the resident set
/// changes. Makes the memory claim of a run a *measured* number: the
/// implicit feature path asserts its peak stays `O(n·k + b·n)` against the
/// materialized path's `O(n²)` by comparing these.
#[derive(Debug, Default)]
pub struct ResidentPeak {
    peak: u64,
}

impl ResidentPeak {
    /// Fold one observation of the current cluster-wide resident total.
    pub fn observe(&mut self, total: u64) {
        self.peak = self.peak.max(total);
    }

    /// Highest total observed so far (0 if nothing was ever resident).
    pub fn peak(&self) -> u64 {
        self.peak
    }
}

/// Upper bounds (µs) of the [`LatencyHistogram`] buckets; one implicit
/// overflow bucket follows the last bound.
pub const LATENCY_BUCKETS_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000];

/// Thread-safe fixed-bucket latency histogram (relaxed atomics — this is
/// monitoring data, not accounting the results depend on). One instance
/// accumulates over its owner's lifetime; *windowed* views — the signal
/// the serve tier's adaptive batching controller runs on — come from
/// diffing two [`LatencySnapshot`]s taken at different times.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
    buckets: [AtomicU64; LATENCY_BUCKETS_US.len() + 1],
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation in microseconds.
    pub fn record_us(&self, us: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
        let idx = LATENCY_BUCKETS_US
            .iter()
            .position(|&le| us <= le)
            .unwrap_or(LATENCY_BUCKETS_US.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of every counter.
    pub fn snapshot(&self) -> LatencySnapshot {
        LatencySnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
        }
    }
}

/// Copy of a [`LatencyHistogram`]'s counters at a point in time. Two
/// snapshots subtract into a *window* ([`LatencySnapshot::since`]), which
/// is how controllers read "the p95 of the last interval" off a histogram
/// that only ever accumulates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LatencySnapshot {
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
    pub buckets: [u64; LATENCY_BUCKETS_US.len() + 1],
}

impl LatencySnapshot {
    /// The window between `earlier` and `self`: per-bucket count deltas.
    /// Counters are monotone, so `saturating_sub` only guards against
    /// reordered relaxed loads; `max_us` stays the cumulative maximum
    /// (the buckets bound the window's tail on their own).
    pub fn since(&self, earlier: &LatencySnapshot) -> LatencySnapshot {
        LatencySnapshot {
            count: self.count.saturating_sub(earlier.count),
            sum_us: self.sum_us.saturating_sub(earlier.sum_us),
            max_us: self.max_us,
            buckets: std::array::from_fn(|i| {
                self.buckets[i].saturating_sub(earlier.buckets[i])
            }),
        }
    }

    /// Mean of the recorded observations (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Approximate quantile: the upper bound of the bucket holding the
    /// q-th observation (the observed max for the overflow bucket; 0 when
    /// the snapshot is empty).
    pub fn percentile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return match LATENCY_BUCKETS_US.get(i) {
                    Some(&le) => le as f64,
                    None => self.max_us as f64,
                };
            }
        }
        self.max_us as f64
    }
}

/// The PJRT-eligible block operations, in display order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OffloadOp {
    Dist,
    Minplus,
    Fw,
    Center,
    Gemm,
    Gemmt,
}

impl OffloadOp {
    /// Every op, in the order counters and reports are laid out.
    pub const ALL: [OffloadOp; 6] = [
        OffloadOp::Dist,
        OffloadOp::Minplus,
        OffloadOp::Fw,
        OffloadOp::Center,
        OffloadOp::Gemm,
        OffloadOp::Gemmt,
    ];

    /// Manifest / report name of the op.
    pub fn name(self) -> &'static str {
        match self {
            OffloadOp::Dist => "dist",
            OffloadOp::Minplus => "minplus",
            OffloadOp::Fw => "fw",
            OffloadOp::Center => "center",
            OffloadOp::Gemm => "gemm",
            OffloadOp::Gemmt => "gemmt",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

#[derive(Debug, Default)]
struct OffloadCounter {
    exact: AtomicU64,
    padded: AtomicU64,
    missed: AtomicU64,
}

/// Thread-safe per-op offload counters. One instance lives inside each
/// `PjrtEngine` (real or stub) and accumulates over the engine's lifetime:
/// `exact` = served by an exact-shape artifact, `padded` = served by a
/// larger artifact through neutral-element padding, `missed` = no artifact
/// (even padded) could serve the shape and the caller fell back to the
/// native kernel. Hard failures (compile/execution errors) are *not*
/// counted — they propagate instead of masquerading as shape misses.
#[derive(Debug, Default)]
pub struct OffloadStats {
    counters: [OffloadCounter; 6],
}

/// Snapshot of one op's counters at a point in time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OffloadOpSnapshot {
    pub op: OffloadOp,
    pub exact: u64,
    pub padded: u64,
    pub missed: u64,
}

impl OffloadOpSnapshot {
    /// Calls served by PJRT (exact or padded artifact).
    pub fn offloaded(&self) -> u64 {
        self.exact + self.padded
    }

    /// All calls recorded for this op.
    pub fn total(&self) -> u64 {
        self.exact + self.padded + self.missed
    }

    /// Fraction of calls served by PJRT (1.0 when no calls were made —
    /// nothing was forced off the offload path).
    pub fn coverage(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            1.0
        } else {
            self.offloaded() as f64 / t as f64
        }
    }
}

impl OffloadStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// An exact-shape artifact served the call.
    pub fn record_exact(&self, op: OffloadOp) {
        self.counters[op.idx()].exact.fetch_add(1, Ordering::Relaxed);
    }

    /// A larger artifact served the call through neutral-element padding.
    pub fn record_padded(&self, op: OffloadOp) {
        self.counters[op.idx()].padded.fetch_add(1, Ordering::Relaxed);
    }

    /// No artifact (even padded) covers the shape; the caller falls back
    /// to the native kernel and the miss is recorded here.
    pub fn record_miss(&self, op: OffloadOp) {
        self.counters[op.idx()].missed.fetch_add(1, Ordering::Relaxed);
    }

    /// Counters for one op.
    pub fn op_snapshot(&self, op: OffloadOp) -> OffloadOpSnapshot {
        let c = &self.counters[op.idx()];
        OffloadOpSnapshot {
            op,
            exact: c.exact.load(Ordering::Relaxed),
            padded: c.padded.load(Ordering::Relaxed),
            missed: c.missed.load(Ordering::Relaxed),
        }
    }

    /// Counters for every op, in [`OffloadOp::ALL`] order.
    pub fn snapshot(&self) -> Vec<OffloadOpSnapshot> {
        OffloadOp::ALL.iter().map(|&op| self.op_snapshot(op)).collect()
    }

    /// Total calls recorded across all ops.
    pub fn total_calls(&self) -> u64 {
        self.snapshot().iter().map(OffloadOpSnapshot::total).sum()
    }

    /// Total counted fallbacks across all ops.
    pub fn total_missed(&self) -> u64 {
        self.snapshot().iter().map(|s| s.missed).sum()
    }

    /// Render the per-op coverage table (ops with zero calls are omitted;
    /// a footer row aggregates the whole engine).
    pub fn report(&self) -> String {
        let snap = self.snapshot();
        let mut rows = vec![vec![
            "op".to_string(),
            "exact".to_string(),
            "padded".to_string(),
            "fallback".to_string(),
            "coverage".to_string(),
        ]];
        let mut agg = OffloadOpSnapshot { op: OffloadOp::Dist, exact: 0, padded: 0, missed: 0 };
        for s in snap.iter().filter(|s| s.total() > 0) {
            agg.exact += s.exact;
            agg.padded += s.padded;
            agg.missed += s.missed;
            rows.push(vec![
                s.op.name().to_string(),
                s.exact.to_string(),
                s.padded.to_string(),
                s.missed.to_string(),
                format!("{:.1}%", s.coverage() * 100.0),
            ]);
        }
        if agg.total() == 0 {
            return "offload: no block ops executed".to_string();
        }
        rows.push(vec![
            "total".to_string(),
            agg.exact.to_string(),
            agg.padded.to_string(),
            agg.missed.to_string(),
            format!("{:.1}%", agg.coverage() * 100.0),
        ]);
        render_table(&rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(name: &str, compute: f64, bytes: u64) -> StageMetrics {
        StageMetrics {
            name: name.to_string(),
            tasks: 2,
            compute_real: compute,
            virtual_span: compute / 2.0,
            shuffle_bytes: bytes,
            network_time: 0.1,
            driver_time: 0.01,
        }
    }

    #[test]
    fn totals_and_prefix_aggregation() {
        let mut m = Metrics::new();
        m.push(stage("knn:dist", 2.0, 100));
        m.push(stage("knn:topk", 1.0, 50));
        m.push(stage("apsp:iter0", 4.0, 200));
        assert_eq!(m.total_tasks(), 6);
        assert!((m.total_compute_real() - 7.0).abs() < 1e-12);
        assert_eq!(m.total_shuffle_bytes(), 350);
        let knn = m.by_prefix("knn");
        assert_eq!(knn.tasks, 4);
        assert!((knn.compute_real - 3.0).abs() < 1e-12);
        assert_eq!(knn.shuffle_bytes, 150);
    }

    #[test]
    fn report_renders() {
        let mut m = Metrics::new();
        m.push(stage("knn:dist", 2.0, 100));
        let r = m.report(&["knn"]);
        assert!(r.contains("knn"));
        assert!(r.contains("tasks"));
    }

    #[test]
    fn resident_peak_is_a_high_water_mark() {
        let mut p = ResidentPeak::default();
        assert_eq!(p.peak(), 0);
        p.observe(100);
        p.observe(40); // shrinking the resident set never lowers the peak
        assert_eq!(p.peak(), 100);
        p.observe(250);
        assert_eq!(p.peak(), 250);
    }

    #[test]
    fn offload_counters_accumulate_per_op() {
        let s = OffloadStats::new();
        s.record_exact(OffloadOp::Minplus);
        s.record_exact(OffloadOp::Minplus);
        s.record_padded(OffloadOp::Minplus);
        s.record_miss(OffloadOp::Dist);
        let mp = s.op_snapshot(OffloadOp::Minplus);
        assert_eq!((mp.exact, mp.padded, mp.missed), (2, 1, 0));
        assert_eq!(mp.offloaded(), 3);
        assert!((mp.coverage() - 1.0).abs() < 1e-12);
        let dist = s.op_snapshot(OffloadOp::Dist);
        assert_eq!((dist.exact, dist.padded, dist.missed), (0, 0, 1));
        assert_eq!(dist.coverage(), 0.0);
        assert_eq!(s.total_calls(), 4);
        assert_eq!(s.total_missed(), 1);
    }

    #[test]
    fn untouched_op_counts_as_full_coverage() {
        let s = OffloadStats::new();
        assert_eq!(s.op_snapshot(OffloadOp::Fw).coverage(), 1.0);
        assert_eq!(s.report(), "offload: no block ops executed");
    }

    #[test]
    fn offload_report_renders_only_active_ops() {
        let s = OffloadStats::new();
        s.record_padded(OffloadOp::Fw);
        s.record_miss(OffloadOp::Fw);
        let r = s.report();
        assert!(r.contains("fw"), "{r}");
        assert!(r.contains("50.0%"), "{r}");
        assert!(r.contains("total"), "{r}");
        assert!(!r.contains("gemmt"), "{r}");
        assert!(r.contains("coverage"), "{r}");
    }

    #[test]
    fn latency_histogram_percentiles_and_windows() {
        let h = LatencyHistogram::new();
        for _ in 0..90 {
            h.record_us(40); // bucket ≤ 50
        }
        for _ in 0..9 {
            h.record_us(700); // bucket ≤ 1000
        }
        h.record_us(400_000); // overflow bucket
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.percentile_us(0.50), 50.0);
        assert_eq!(s.percentile_us(0.95), 1_000.0);
        assert_eq!(s.percentile_us(1.0), 400_000.0);
        assert_eq!(s.max_us, 400_000);

        // A window that only saw fast observations reports a fast p95
        // even though the cumulative histogram carries the slow tail.
        let before = h.snapshot();
        for _ in 0..10 {
            h.record_us(45);
        }
        let win = h.snapshot().since(&before);
        assert_eq!(win.count, 10);
        assert_eq!(win.percentile_us(0.95), 50.0);
        assert_eq!(win.mean_us(), 45.0);
    }

    #[test]
    fn empty_latency_snapshot_is_zero() {
        let s = LatencyHistogram::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.percentile_us(0.95), 0.0);
        assert_eq!(s.mean_us(), 0.0);
        assert_eq!(s.since(&s), s);
    }

    #[test]
    fn offload_stats_shared_across_threads() {
        let s = std::sync::Arc::new(OffloadStats::new());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let s = std::sync::Arc::clone(&s);
                scope.spawn(move || {
                    for _ in 0..100 {
                        s.record_exact(OffloadOp::Gemm);
                    }
                });
            }
        });
        assert_eq!(s.op_snapshot(OffloadOp::Gemm).exact, 400);
    }
}
