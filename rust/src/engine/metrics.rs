//! Per-stage execution metrics: real compute time, virtual cluster time,
//! shuffle volumes, task counts. The scalability tables are produced from
//! the virtual clock; the §Perf work reads the real timings.

use crate::util::fmt::{human_bytes, human_duration, render_table};

/// Record of one executed stage.
#[derive(Clone, Debug)]
pub struct StageMetrics {
    pub name: String,
    pub tasks: usize,
    /// Sum of measured single-core task durations (real seconds).
    pub compute_real: f64,
    /// Stage makespan on the virtual cluster.
    pub virtual_span: f64,
    /// Bytes that crossed the simulated network.
    pub shuffle_bytes: u64,
    /// Virtual seconds charged to the network for this stage.
    pub network_time: f64,
    /// Virtual seconds charged to the driver (scheduling × lineage).
    pub driver_time: f64,
}

/// Accumulated metrics for a run.
#[derive(Debug, Default)]
pub struct Metrics {
    pub stages: Vec<StageMetrics>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, s: StageMetrics) {
        self.stages.push(s);
    }

    pub fn total_compute_real(&self) -> f64 {
        self.stages.iter().map(|s| s.compute_real).sum()
    }

    pub fn total_shuffle_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.shuffle_bytes).sum()
    }

    pub fn total_network_time(&self) -> f64 {
        self.stages.iter().map(|s| s.network_time).sum()
    }

    pub fn total_driver_time(&self) -> f64 {
        self.stages.iter().map(|s| s.driver_time).sum()
    }

    pub fn total_tasks(&self) -> usize {
        self.stages.iter().map(|s| s.tasks).sum()
    }

    /// Aggregate stages by a prefix of their name (e.g. "knn", "apsp").
    pub fn by_prefix(&self, prefix: &str) -> StageMetrics {
        let mut agg = StageMetrics {
            name: prefix.to_string(),
            tasks: 0,
            compute_real: 0.0,
            virtual_span: 0.0,
            shuffle_bytes: 0,
            network_time: 0.0,
            driver_time: 0.0,
        };
        for s in self.stages.iter().filter(|s| s.name.starts_with(prefix)) {
            agg.tasks += s.tasks;
            agg.compute_real += s.compute_real;
            agg.virtual_span += s.virtual_span;
            agg.shuffle_bytes += s.shuffle_bytes;
            agg.network_time += s.network_time;
            agg.driver_time += s.driver_time;
        }
        agg
    }

    /// Text report of the per-prefix aggregates.
    pub fn report(&self, prefixes: &[&str]) -> String {
        let mut rows = vec![vec![
            "stage".to_string(),
            "tasks".to_string(),
            "compute(real)".to_string(),
            "virtual".to_string(),
            "shuffle".to_string(),
            "net".to_string(),
            "driver".to_string(),
        ]];
        for p in prefixes {
            let a = self.by_prefix(p);
            rows.push(vec![
                a.name,
                a.tasks.to_string(),
                human_duration(a.compute_real),
                human_duration(a.virtual_span),
                human_bytes(a.shuffle_bytes),
                human_duration(a.network_time),
                human_duration(a.driver_time),
            ]);
        }
        render_table(&rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(name: &str, compute: f64, bytes: u64) -> StageMetrics {
        StageMetrics {
            name: name.to_string(),
            tasks: 2,
            compute_real: compute,
            virtual_span: compute / 2.0,
            shuffle_bytes: bytes,
            network_time: 0.1,
            driver_time: 0.01,
        }
    }

    #[test]
    fn totals_and_prefix_aggregation() {
        let mut m = Metrics::new();
        m.push(stage("knn:dist", 2.0, 100));
        m.push(stage("knn:topk", 1.0, 50));
        m.push(stage("apsp:iter0", 4.0, 200));
        assert_eq!(m.total_tasks(), 6);
        assert!((m.total_compute_real() - 7.0).abs() < 1e-12);
        assert_eq!(m.total_shuffle_bytes(), 350);
        let knn = m.by_prefix("knn");
        assert_eq!(knn.tasks, 4);
        assert!((knn.compute_real - 3.0).abs() < 1e-12);
        assert_eq!(knn.shuffle_bytes, 150);
    }

    #[test]
    fn report_renders() {
        let mut m = Metrics::new();
        m.push(stage("knn:dist", 2.0, 100));
        let r = m.report(&["knn"]);
        assert!(r.contains("knn"));
        assert!(r.contains("tasks"));
    }
}
