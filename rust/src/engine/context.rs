//! The driver context — the engine's analogue of Spark's `SparkContext`.
//!
//! Owns the simulated cluster (virtual clock + network model), the metrics
//! sink, the lineage DAG, and the per-node resident-memory model. All
//! transformations on [`super::rdd::BlockRdd`] report back through this
//! context. Execution is eager and in-process (every task really runs,
//! bit-exactly, on the worker-thread pool); *time* is simulated — see
//! DESIGN.md §3. The handle is `Send + Sync` (`Arc<Mutex<…>>`) so stage
//! workers can share it, though the driver-side bookkeeping itself is
//! always performed between stages, never inside task closures.

use super::clock::VirtualClock;
use super::durable::CheckpointStore;
use super::fault::{FaultPlan, ResilienceSnapshot, ResilienceStats, TaskPolicy};
use super::lineage::LineageGraph;
use super::metrics::{Metrics, ResidentPeak, StageMetrics};
use super::network::{NetworkModel, Traffic};
use crate::config::ClusterConfig;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

/// Extra driver scheduling cost per unit of lineage depth (fraction of the
/// base per-task overhead). Models the paper's observation that unbounded
/// lineage "overwhelms the Spark driver".
pub const LINEAGE_OVERHEAD_FACTOR: f64 = 0.05;

pub(crate) struct CtxState {
    pub cluster: ClusterConfig,
    pub clock: VirtualClock,
    pub net: NetworkModel,
    pub metrics: Metrics,
    pub lineage: LineageGraph,
    /// Persisted bytes per node, by tag (e.g. "G", "A").
    resident: BTreeMap<String, Vec<u64>>,
    /// High-water mark of the cluster-wide resident total.
    resident_peak: ResidentPeak,
    /// Live fault-injection schedule, installed when `fault_rate > 0`.
    /// `None` keeps every stage on the plain `run_tasks` fast path.
    fault_plan: Option<FaultPlan>,
    /// Retry / recovery / checkpoint counters, shared with worker threads
    /// through the [`TaskPolicy`] handed to each stage.
    resilience: Arc<ResilienceStats>,
}

/// Cheaply cloneable, thread-safe handle to the driver state.
#[derive(Clone)]
pub struct SparkContext {
    pub(crate) st: Arc<Mutex<CtxState>>,
}

impl SparkContext {
    /// Create a context over a simulated cluster.
    pub fn new(cluster: ClusterConfig) -> Self {
        let clock = VirtualClock::new(cluster.nodes, cluster.cores_per_node);
        let net = NetworkModel::new(&cluster);
        let fault_plan = (cluster.fault_rate > 0.0).then(|| {
            FaultPlan::new(cluster.fault_rate, cluster.fault_seed, cluster.fault_max_attempts)
        });
        Self {
            st: Arc::new(Mutex::new(CtxState {
                cluster,
                clock,
                net,
                metrics: Metrics::new(),
                lineage: LineageGraph::new(),
                resident: BTreeMap::new(),
                resident_peak: ResidentPeak::default(),
                fault_plan,
                resilience: Arc::new(ResilienceStats::default()),
            })),
        }
    }

    fn lock(&self) -> MutexGuard<'_, CtxState> {
        self.st.lock().expect("engine state poisoned (a task panicked)")
    }

    /// Executor node hosting a partition. Contiguous *ranges* of partition
    /// ids map to the same executor — Spark's locality-aware scheduling
    /// keeps consecutively-created partitions together, and this is the
    /// placement the paper's upper-triangular packing (Fig. 2) relies on:
    /// neighboring blocks → neighboring partitions → same executor.
    pub fn node_of(&self, partition: usize, num_partitions: usize) -> usize {
        let nodes = self.lock().cluster.nodes;
        (partition * nodes / num_partitions.max(1)).min(nodes - 1)
    }

    /// Number of executor nodes.
    pub fn nodes(&self) -> usize {
        self.lock().cluster.nodes
    }

    /// Resolved worker-thread count for real block-task execution:
    /// [`ClusterConfig::parallelism`], with 0 meaning "all available
    /// cores". Never affects results; virtual time stays measurement-based
    /// (see the `parallelism` field docs for the contention caveat).
    pub fn parallelism(&self) -> usize {
        super::executor::resolve_workers(self.lock().cluster.parallelism)
    }

    /// Cluster configuration snapshot.
    pub fn cluster(&self) -> ClusterConfig {
        self.lock().cluster.clone()
    }

    /// Current virtual time (seconds since run start).
    pub fn virtual_now(&self) -> f64 {
        self.lock().clock.now()
    }

    /// Borrow the metrics (cloned snapshot report). When any resilience
    /// event happened (retry, recovery, straggler, checkpoint spill or
    /// restore) a `resilience` block is appended after the stage table.
    pub fn metrics_report(&self, prefixes: &[&str]) -> String {
        let st = self.lock();
        let mut out = st.metrics.report(prefixes);
        let res = st.resilience.report();
        if !res.is_empty() {
            if !out.is_empty() && !out.ends_with('\n') {
                out.push('\n');
            }
            out.push_str(&res);
        }
        let peak = st.resident_peak.peak();
        if peak > 0 {
            if !out.is_empty() && !out.ends_with('\n') {
                out.push('\n');
            }
            out.push_str(&format!(
                "peak resident: {} cluster-wide\n",
                crate::util::fmt::human_bytes(peak)
            ));
        }
        out
    }

    /// The per-stage retry policy, or `None` when no fault plan is
    /// installed (`fault_rate == 0`) — stages then take the plain
    /// `run_tasks` fast path with zero overhead.
    pub(crate) fn task_policy(&self) -> Option<TaskPolicy> {
        let st = self.lock();
        let plan = st.fault_plan.clone()?;
        let stats = Arc::clone(&st.resilience);
        drop(st);
        Some(TaskPolicy::new(plan, stats, self.clone()))
    }

    /// Shared resilience counters (worker threads record through the
    /// policy; driver-side code like the durable store records here).
    pub(crate) fn resilience(&self) -> Arc<ResilienceStats> {
        Arc::clone(&self.lock().resilience)
    }

    /// Point-in-time copy of the resilience counters.
    pub fn resilience_snapshot(&self) -> ResilienceSnapshot {
        self.lock().resilience.snapshot()
    }

    /// The durable checkpoint store, when `--checkpoint-dir` is set.
    pub(crate) fn checkpoint_store(&self) -> Option<CheckpointStore> {
        let st = self.lock();
        st.cluster.checkpoint_dir.as_deref().map(CheckpointStore::new)
    }

    /// Total bytes shuffled so far.
    pub fn total_shuffle_bytes(&self) -> u64 {
        self.lock().metrics.total_shuffle_bytes()
    }

    /// Total measured compute seconds so far (sum over tasks).
    pub fn total_compute_real(&self) -> f64 {
        self.lock().metrics.total_compute_real()
    }

    /// Stage-level metrics aggregated by prefix.
    pub fn stage_aggregate(&self, prefix: &str) -> StageMetrics {
        self.lock().metrics.by_prefix(prefix)
    }

    /// Number of stages recorded so far (determinism suite: must not
    /// depend on the worker pool size).
    pub fn stage_count(&self) -> usize {
        self.lock().metrics.stages.len()
    }

    /// Lineage DAG dump for diagnostics.
    pub fn lineage_dump(&self) -> String {
        self.lock().lineage.dump()
    }

    /// Lineage depth of an RDD.
    pub fn lineage_depth(&self, id: usize) -> usize {
        self.lock().lineage.depth(id)
    }

    /// Number of lineage nodes recorded so far.
    pub fn lineage_len(&self) -> usize {
        self.lock().lineage.len()
    }

    /// Size of an RDD's ancestry (transformations replayed on recovery).
    pub fn lineage_ancestry(&self, id: usize) -> usize {
        self.lock().lineage.ancestry_size(id)
    }

    /// Total tasks executed so far.
    pub fn total_tasks(&self) -> usize {
        self.lock().metrics.total_tasks()
    }

    /// Advance the virtual clock by a serial charge (fault recovery).
    pub(crate) fn advance_clock(&self, dt: f64) {
        self.lock().clock.advance(dt);
    }

    pub(crate) fn lineage_add(&self, op: &str, parents: &[usize]) -> usize {
        self.lock().lineage.add(op, parents)
    }

    /// Charge the driver for scheduling `ntasks` tasks of an RDD at the
    /// given lineage depth. Serial on the critical path.
    pub(crate) fn charge_driver(&self, name: &str, ntasks: usize, depth: usize) -> f64 {
        let mut st = self.lock();
        let per_task = st.cluster.sched_overhead * (1.0 + LINEAGE_OVERHEAD_FACTOR * depth as f64);
        let dt = per_task * ntasks as f64;
        st.clock.advance(dt);
        let _ = name;
        dt
    }

    /// Charge a shuffle's network time; returns (bytes, seconds).
    pub(crate) fn charge_shuffle(&self, traffic: &Traffic) -> (u64, f64) {
        let mut st = self.lock();
        let dt = st.net.shuffle_time(traffic);
        st.clock.advance(dt);
        (traffic.total(), dt)
    }

    /// Charge a collect-to-driver of `bytes` in `messages` messages.
    pub(crate) fn charge_collect(&self, bytes: u64, messages: u64) -> f64 {
        let mut st = self.lock();
        let dt = st.net.collect_time(bytes, messages);
        st.clock.advance(dt);
        dt
    }

    /// Broadcast `bytes` from the driver to all executors (public: the
    /// coordinator broadcasts means and Q matrices).
    pub fn broadcast(&self, name: &str, bytes: u64) {
        let mut st = self.lock();
        let dt = st.net.broadcast_time(bytes);
        st.clock.advance(dt);
        let stage = StageMetrics {
            name: format!("{name}:broadcast"),
            tasks: 0,
            compute_real: 0.0,
            virtual_span: 0.0,
            shuffle_bytes: bytes,
            network_time: dt,
            driver_time: 0.0,
        };
        st.metrics.push(stage);
    }

    /// Run a barrier stage of `(node, duration)` tasks; durations are real
    /// measured seconds, scaled by the calibration factor.
    pub(crate) fn run_stage(&self, tasks: &[super::clock::Task]) -> f64 {
        let mut st = self.lock();
        let scale = st.cluster.compute_scale;
        let scaled: Vec<super::clock::Task> = tasks
            .iter()
            .map(|t| super::clock::Task { node: t.node, duration: t.duration * scale })
            .collect();
        st.clock.run_stage(&scaled)
    }

    pub(crate) fn push_metrics(&self, s: StageMetrics) {
        self.lock().metrics.push(s);
    }

    /// Register the resident footprint of a persisted RDD under `tag`,
    /// replacing any previous footprint with the same tag. Errors when a
    /// node would exceed executor memory — the paper's "impossible to
    /// process on given resources" (Table I `-`).
    pub fn set_resident(&self, tag: &str, per_node: Vec<u64>) -> Result<()> {
        let mut st = self.lock();
        st.resident.insert(tag.to_string(), per_node);
        let nodes = st.cluster.nodes;
        for v in 0..nodes {
            let total: u64 = st.resident.values().map(|r| r.get(v).copied().unwrap_or(0)).sum();
            if total > st.cluster.mem_per_node {
                let need = crate::util::fmt::human_bytes(total);
                let cap = crate::util::fmt::human_bytes(st.cluster.mem_per_node);
                bail!(
                    "dataset impossible on given resources: node {v} needs {need} resident, \
                     executor memory is {cap}"
                );
            }
        }
        let total: u64 = st.resident.values().flatten().sum();
        st.resident_peak.observe(total);
        Ok(())
    }

    /// Highest cluster-wide resident total ever registered (bytes). The
    /// measured side of the memory-model claims: materialized feature
    /// blocks peak at O(n²), the implicit panel source at O(n·k + b·n).
    pub fn peak_resident_bytes(&self) -> u64 {
        self.lock().resident_peak.peak()
    }

    /// Drop a resident tag (unpersist).
    pub fn clear_resident(&self, tag: &str) {
        self.lock().resident.remove(tag);
    }

    /// Charge a checkpoint of `per_node` bytes to local disk (max node is
    /// the straggler) and prune the RDD's lineage.
    pub fn charge_checkpoint(&self, lineage_id: usize, per_node: &[u64]) {
        let mut st = self.lock();
        let worst = per_node.iter().copied().max().unwrap_or(0) as f64;
        let dt = if st.cluster.disk_bandwidth.is_finite() {
            worst / st.cluster.disk_bandwidth
        } else {
            0.0
        };
        st.clock.advance(dt);
        st.lineage.checkpoint(lineage_id);
        let stage = StageMetrics {
            name: "checkpoint".to_string(),
            tasks: 0,
            compute_real: 0.0,
            virtual_span: dt,
            shuffle_bytes: 0,
            network_time: 0.0,
            driver_time: dt,
        };
        st.metrics.push(stage);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_assignment_contiguous_ranges() {
        let ctx = SparkContext::new(ClusterConfig { nodes: 3, ..ClusterConfig::local() });
        // 9 partitions over 3 nodes: 0-2 -> node 0, 3-5 -> node 1, 6-8 -> 2.
        assert_eq!(ctx.node_of(0, 9), 0);
        assert_eq!(ctx.node_of(2, 9), 0);
        assert_eq!(ctx.node_of(3, 9), 1);
        assert_eq!(ctx.node_of(8, 9), 2);
        // Out-of-range partition ids clamp to the last node.
        assert_eq!(ctx.node_of(100, 9), 2);
        assert_eq!(ctx.nodes(), 3);
    }

    #[test]
    fn context_handle_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SparkContext>();
    }

    #[test]
    fn parallelism_resolution() {
        let one = SparkContext::new(ClusterConfig::local());
        assert_eq!(one.parallelism(), 1);
        let auto = SparkContext::new(ClusterConfig { parallelism: 0, ..ClusterConfig::local() });
        assert!(auto.parallelism() >= 1);
        let four = SparkContext::new(ClusterConfig { parallelism: 4, ..ClusterConfig::local() });
        assert_eq!(four.parallelism(), 4);
    }

    #[test]
    fn memory_model_rejects_oversize() {
        let mut cfg = ClusterConfig::local();
        cfg.mem_per_node = 1000;
        let ctx = SparkContext::new(cfg);
        assert!(ctx.set_resident("a", vec![500]).is_ok());
        assert!(ctx.set_resident("b", vec![400]).is_ok());
        assert!(ctx.set_resident("c", vec![200]).is_err());
        ctx.clear_resident("b");
        assert!(ctx.set_resident("c", vec![200]).is_ok());
    }

    #[test]
    fn replacing_tag_does_not_accumulate() {
        let mut cfg = ClusterConfig::local();
        cfg.mem_per_node = 1000;
        let ctx = SparkContext::new(cfg);
        for _ in 0..10 {
            ctx.set_resident("g", vec![900]).unwrap();
        }
    }

    #[test]
    fn driver_charge_grows_with_depth() {
        let mut cfg = ClusterConfig::local();
        cfg.sched_overhead = 1.0;
        let ctx = SparkContext::new(cfg);
        let shallow = ctx.charge_driver("s", 10, 0);
        let deep = ctx.charge_driver("d", 10, 20);
        assert!(deep > shallow * 1.5, "deep={deep} shallow={shallow}");
        assert!((ctx.virtual_now() - (shallow + deep)).abs() < 1e-12);
    }

    #[test]
    fn fault_plan_installed_only_when_rate_positive() {
        let off = SparkContext::new(ClusterConfig::local());
        assert!(off.task_policy().is_none());
        assert!(off.checkpoint_store().is_none());
        let on = SparkContext::new(ClusterConfig {
            fault_rate: 0.3,
            fault_seed: 9,
            ..ClusterConfig::local()
        });
        let policy = on.task_policy().expect("rate > 0 installs a plan");
        assert_eq!(policy.plan.rate(), 0.3);
    }

    #[test]
    fn metrics_report_appends_resilience_block_only_on_events() {
        let ctx = SparkContext::new(ClusterConfig::local());
        assert!(!ctx.metrics_report(&[]).contains("resilience"));
        ctx.resilience().record_restore();
        let report = ctx.metrics_report(&[]);
        assert!(report.contains("resilience"), "{report}");
        assert_eq!(ctx.resilience_snapshot().checkpoint_restores, 1);
    }

    #[test]
    fn peak_resident_tracks_high_water_mark_across_tags() {
        let ctx = SparkContext::new(ClusterConfig { nodes: 2, ..ClusterConfig::local() });
        assert_eq!(ctx.peak_resident_bytes(), 0);
        assert!(!ctx.metrics_report(&[]).contains("peak resident"));
        ctx.set_resident("G", vec![600, 400]).unwrap();
        ctx.set_resident("panel", vec![0, 200]).unwrap();
        assert_eq!(ctx.peak_resident_bytes(), 1200);
        // Unpersisting never lowers the recorded peak.
        ctx.clear_resident("G");
        ctx.set_resident("panel", vec![100, 0]).unwrap();
        assert_eq!(ctx.peak_resident_bytes(), 1200);
        assert!(ctx.metrics_report(&[]).contains("peak resident"));
    }

    #[test]
    fn checkpoint_prunes_and_charges() {
        let mut cfg = ClusterConfig::local();
        cfg.disk_bandwidth = 100.0;
        let ctx = SparkContext::new(cfg);
        let mut id = ctx.lineage_add("root", &[]);
        for _ in 0..5 {
            id = ctx.lineage_add("it", &[id]);
        }
        assert_eq!(ctx.lineage_depth(id), 5);
        ctx.charge_checkpoint(id, &[1000]);
        assert_eq!(ctx.lineage_depth(id), 0);
        assert!((ctx.virtual_now() - 10.0).abs() < 1e-9);
    }
}
