//! Partitioners: map a [`BlockId`] to an RDD partition.
//!
//! The paper's key locality optimization (§III-A, Fig. 2) is a custom
//! partitioner for upper-triangular block matrices: blocks are numbered in
//! row-major upper-triangular order and `B = ⌈Q/p'⌉` *consecutive* blocks
//! are packed per partition, so the row/column neighborhoods touched
//! together by the APSP phases land in few partitions. We also implement
//! the two alternatives the paper compares against — MLlib-style
//! `GridPartitioner` and Spark's default hash partitioner — for the
//! ablation benchmark.

use super::block::BlockId;

/// Maps block keys to partitions `0..num_partitions`.
///
/// `Send + Sync` because partitioners are shared (`Arc<dyn Partitioner>`)
/// between the driver and the stage worker threads; implementations are
/// immutable routing tables, so this costs nothing.
pub trait Partitioner: Send + Sync {
    /// Partition index for a key.
    fn partition(&self, id: BlockId) -> usize;
    /// Total number of partitions.
    fn num_partitions(&self) -> usize;
    /// Short name for reports.
    fn name(&self) -> &'static str;
}

/// Row-major index of `(i, j)` within the `q×q` upper triangle.
/// `idx(i,j) = i·q − i(i−1)/2 + (j − i)` for `i ≤ j < q`.
pub fn ut_index(i: usize, j: usize, q: usize) -> usize {
    debug_assert!(i <= j && j < q, "({i},{j}) not upper-triangular for q={q}");
    // Row i starts after q + (q-1) + … + (q-i+1) = i(2q - i - 1)/2 + i
    // entries; equivalently idx = i(2q - i - 1)/2 + j.
    i * (2 * q - i - 1) / 2 + j
}

/// Number of blocks in the upper triangle: `Q = q(q+1)/2`.
pub fn ut_count(q: usize) -> usize {
    q * (q + 1) / 2
}

/// The paper's custom upper-triangular partitioner.
#[derive(Clone, Debug)]
pub struct UpperTriangularPartitioner {
    q: usize,
    parts: usize,
    blocks_per_part: usize,
}

impl UpperTriangularPartitioner {
    /// `q` logical block rows, `parts` RDD partitions.
    pub fn new(q: usize, parts: usize) -> Self {
        assert!(q > 0 && parts > 0);
        let total = ut_count(q);
        let blocks_per_part = total.div_ceil(parts);
        Self { q, parts, blocks_per_part }
    }

    pub fn q(&self) -> usize {
        self.q
    }
}

impl Partitioner for UpperTriangularPartitioner {
    fn partition(&self, id: BlockId) -> usize {
        // Keys outside the strict upper triangle (e.g. kNN lists keyed
        // (I, i_loc), power-iteration keys (I, 0)) fall back to hashing the
        // row index, keeping all keys of one block row co-located.
        if id.j >= id.i && id.j < self.q && id.i < self.q {
            (ut_index(id.i, id.j, self.q) / self.blocks_per_part).min(self.parts - 1)
        } else {
            mix(id.i as u64) as usize % self.parts
        }
    }

    fn num_partitions(&self) -> usize {
        self.parts
    }

    fn name(&self) -> &'static str {
        "upper-triangular"
    }
}

/// MLlib-style grid partitioner: the `q×q` grid of blocks is cut into a
/// `pr × pc` grid of partition rectangles.
#[derive(Clone, Debug)]
pub struct GridPartitioner {
    q: usize,
    pr: usize,
    pc: usize,
}

impl GridPartitioner {
    pub fn new(q: usize, parts: usize) -> Self {
        // Choose the most-square factorization pr*pc >= parts.
        let pr = (parts as f64).sqrt().floor().max(1.0) as usize;
        let pc = parts.div_ceil(pr);
        Self { q, pr, pc }
    }
}

impl Partitioner for GridPartitioner {
    fn partition(&self, id: BlockId) -> usize {
        let rows_per = self.q.div_ceil(self.pr).max(1);
        let cols_per = self.q.div_ceil(self.pc).max(1);
        let r = (id.i / rows_per).min(self.pr - 1);
        let c = (id.j / cols_per).min(self.pc - 1);
        r * self.pc + c
    }

    fn num_partitions(&self) -> usize {
        self.pr * self.pc
    }

    fn name(&self) -> &'static str {
        "grid"
    }
}

/// Spark's default: hash of the key modulo partition count.
#[derive(Clone, Debug)]
pub struct HashPartitioner {
    parts: usize,
}

impl HashPartitioner {
    pub fn new(parts: usize) -> Self {
        assert!(parts > 0);
        Self { parts }
    }
}

fn mix(x: u64) -> u64 {
    // SplitMix64 finalizer.
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Partitioner for HashPartitioner {
    fn partition(&self, id: BlockId) -> usize {
        (mix((id.i as u64) << 32 | id.j as u64) % self.parts as u64) as usize
    }

    fn num_partitions(&self) -> usize {
        self.parts
    }

    fn name(&self) -> &'static str {
        "hash"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ut_index_row_major() {
        // q = 4: row 0 -> 0..3, row 1 -> 4..6, row 2 -> 7..8, row 3 -> 9.
        assert_eq!(ut_index(0, 0, 4), 0);
        assert_eq!(ut_index(0, 3, 4), 3);
        assert_eq!(ut_index(1, 1, 4), 4);
        assert_eq!(ut_index(1, 3, 4), 6);
        assert_eq!(ut_index(2, 2, 4), 7);
        assert_eq!(ut_index(3, 3, 4), 9);
        assert_eq!(ut_count(4), 10);
    }

    #[test]
    fn ut_index_bijective() {
        let q = 9;
        let mut seen = vec![false; ut_count(q)];
        for i in 0..q {
            for j in i..q {
                let idx = ut_index(i, j, q);
                assert!(!seen[idx], "collision at ({i},{j})");
                seen[idx] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn ut_partitioner_balanced_and_contiguous() {
        // Fig. 2 of the paper: q=4, 10 blocks, 5 partitions of 2.
        let p = UpperTriangularPartitioner::new(4, 5);
        assert_eq!(p.partition(BlockId::new(0, 0)), 0);
        assert_eq!(p.partition(BlockId::new(0, 1)), 0);
        assert_eq!(p.partition(BlockId::new(0, 2)), 1);
        assert_eq!(p.partition(BlockId::new(0, 3)), 1);
        assert_eq!(p.partition(BlockId::new(1, 1)), 2);
        assert_eq!(p.partition(BlockId::new(3, 3)), 4);
        // All partitions in range and every partition used.
        let mut used = vec![0usize; 5];
        for i in 0..4 {
            for j in i..4 {
                used[p.partition(BlockId::new(i, j))] += 1;
            }
        }
        assert_eq!(used, vec![2, 2, 2, 2, 2]);
    }

    #[test]
    fn ut_fallback_for_non_ut_keys() {
        let p = UpperTriangularPartitioner::new(4, 3);
        // Lower-triangular and out-of-range keys must still map in range.
        for id in [BlockId::new(3, 1), BlockId::new(0, 100), BlockId::new(50, 2)] {
            assert!(p.partition(id) < 3);
        }
        // Row-hash fallback keeps a block row together.
        assert_eq!(p.partition(BlockId::new(2, 100)), p.partition(BlockId::new(2, 200)));
    }

    #[test]
    fn grid_in_range_and_deterministic() {
        let p = GridPartitioner::new(10, 6);
        for i in 0..10 {
            for j in 0..10 {
                let a = p.partition(BlockId::new(i, j));
                assert!(a < p.num_partitions());
                assert_eq!(a, p.partition(BlockId::new(i, j)));
            }
        }
    }

    #[test]
    fn hash_spreads() {
        let p = HashPartitioner::new(7);
        let mut used = vec![0usize; 7];
        for i in 0..20 {
            for j in i..20 {
                used[p.partition(BlockId::new(i, j))] += 1;
            }
        }
        // All partitions should receive something.
        assert!(used.iter().all(|&c| c > 0), "{used:?}");
    }

    #[test]
    fn names() {
        assert_eq!(UpperTriangularPartitioner::new(2, 1).name(), "upper-triangular");
        assert_eq!(GridPartitioner::new(2, 1).name(), "grid");
        assert_eq!(HashPartitioner::new(1).name(), "hash");
    }
}
