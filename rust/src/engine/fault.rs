//! Executor-failure simulation.
//!
//! Spark's fault-tolerance story *is* lineage: when an executor dies, the
//! driver recomputes the lost partitions from their lineage — which is
//! exactly why the paper must checkpoint the APSP loop (unbounded lineage
//! makes recovery, and scheduling, arbitrarily expensive). This module
//! charges a simulated executor loss against an RDD: the lost partitions'
//! recompute cost scales with the RDD's *ancestry size* (number of
//! transformations that must be replayed), so a freshly-checkpointed RDD
//! recovers almost for free while a deep one replays its whole history.

use super::block::HasBytes;
use super::metrics::StageMetrics;
use super::rdd::BlockRdd;

/// Outcome of a simulated executor failure.
#[derive(Clone, Debug)]
pub struct FailureReport {
    /// Node that failed.
    pub node: usize,
    /// Blocks that were resident on it.
    pub lost_blocks: usize,
    /// Bytes that had to be re-shuffled to rebuild them.
    pub reshuffled_bytes: u64,
    /// Lineage ancestry replayed (transformations).
    pub replayed_ops: usize,
    /// Virtual seconds charged to the recovery.
    pub recovery_secs: f64,
}

/// Simulate losing executor `node` while `rdd` is the live dataset.
///
/// Cost model: each lost block is recomputed by replaying the RDD's
/// ancestry (`ancestry_size + 1` stages at the average measured per-block
/// compute of the run so far), and its input data is re-shuffled once
/// across the network. The virtual clock advances; metrics record a
/// `recovery` stage. Returns what happened.
pub fn simulate_executor_loss<T: HasBytes + Send + Sync>(
    rdd: &BlockRdd<T>,
    node: usize,
) -> FailureReport {
    let ctx = rdd.context();
    let per_node = rdd.per_node_bytes();
    let lost_bytes = per_node.get(node).copied().unwrap_or(0);
    let nodes = ctx.nodes();

    // Blocks resident on the failed node.
    let part = rdd.partitioner();
    let lost_blocks = rdd
        .iter()
        .filter(|(id, _)| ctx.node_of(part.partition(**id), part.num_partitions()) == node)
        .count();

    let replayed_ops = ctx.lineage_ancestry(rdd.lineage_id) + 1;

    // Average measured per-block compute over the run so far; fall back to
    // a nominal 1 ms when nothing has been measured yet.
    let total_tasks = ctx.total_tasks().max(1);
    let avg_task = ctx.total_compute_real() / total_tasks as f64;
    let avg_task = if avg_task > 0.0 { avg_task } else { 1e-3 };

    // Recompute: lost blocks × replayed stages, executed on the surviving
    // nodes' cores in parallel.
    let surviving_cores = ((nodes.saturating_sub(1)).max(1)) * ctx.cluster().cores_per_node;
    let recompute = (lost_blocks * replayed_ops) as f64 * avg_task / surviving_cores as f64;
    // Re-shuffle the lost bytes once across the network.
    let reshuffle = lost_bytes as f64 / ctx.cluster().net_bandwidth.max(1.0);
    let recovery_secs = recompute + reshuffle;

    ctx.advance_clock(recovery_secs);
    ctx.push_metrics(StageMetrics {
        name: "recovery".to_string(),
        tasks: lost_blocks,
        compute_real: 0.0,
        virtual_span: recovery_secs,
        shuffle_bytes: lost_bytes,
        network_time: reshuffle,
        driver_time: recompute,
    });

    FailureReport {
        node,
        lost_blocks,
        reshuffled_bytes: lost_bytes,
        replayed_ops,
        recovery_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::engine::{BlockId, HashPartitioner, SparkContext};
    use crate::linalg::Matrix;
    use std::sync::Arc;

    fn deep_rdd(ctx: &SparkContext, depth: usize, checkpoint: bool) -> BlockRdd<Matrix> {
        let items: Vec<(BlockId, Matrix)> =
            (0..8).map(|i| (BlockId::new(i, i), Matrix::full(16, 16, 1.0))).collect();
        let part: Arc<dyn crate::engine::Partitioner> = Arc::new(HashPartitioner::new(8));
        let mut rdd = ctx.parallelize("x", items, part);
        for i in 0..depth {
            rdd = rdd.map_values("step", |_, m| {
                let mut m = m.clone();
                m.scale(1.0000001);
                m
            });
            if checkpoint && i % 5 == 4 {
                rdd.checkpoint();
            }
        }
        rdd
    }

    #[test]
    fn recovery_reports_losses() {
        let ctx = SparkContext::new(ClusterConfig::paper_testbed(4));
        let rdd = deep_rdd(&ctx, 10, false);
        let before = ctx.virtual_now();
        let report = simulate_executor_loss(&rdd, 0);
        assert!(report.lost_blocks > 0);
        assert!(report.replayed_ops >= 10);
        assert!(report.recovery_secs > 0.0);
        assert!(ctx.virtual_now() > before);
    }

    #[test]
    fn checkpointing_makes_recovery_cheaper() {
        let cost = |checkpoint: bool| -> f64 {
            let ctx = SparkContext::new(ClusterConfig::paper_testbed(4));
            let rdd = deep_rdd(&ctx, 30, checkpoint);
            simulate_executor_loss(&rdd, 1).recovery_secs
        };
        let with = cost(true);
        let without = cost(false);
        assert!(
            with < without,
            "checkpointed recovery {with} must beat unrestrained lineage {without}"
        );
    }

    #[test]
    fn losing_empty_node_is_cheap() {
        let ctx = SparkContext::new(ClusterConfig::paper_testbed(8));
        let items = vec![(BlockId::new(0, 0), Matrix::zeros(4, 4))];
        let part: Arc<dyn crate::engine::Partitioner> = Arc::new(HashPartitioner::new(1));
        let rdd = ctx.parallelize("tiny", items, part);
        // Node 7 hosts nothing (single partition on node 0).
        let report = simulate_executor_loss(&rdd, 7);
        assert_eq!(report.lost_blocks, 0);
        assert_eq!(report.reshuffled_bytes, 0);
    }
}
