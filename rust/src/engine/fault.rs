//! Fault tolerance: the *cost model* and the *live injector*.
//!
//! This module holds two distinct things that must not be conflated:
//!
//! 1. **The recovery cost model** ([`simulate_executor_loss`]): a purely
//!    virtual-time charge for losing an executor, priced by lineage
//!    ancestry. Nothing fails; the clock advances. This regenerates the
//!    paper's argument that unbounded lineage makes recovery (and
//!    scheduling) arbitrarily expensive — the reason the APSP loop is
//!    checkpointed at all.
//! 2. **The live fault injector** ([`FaultPlan`] + [`TaskPolicy`]): a
//!    seeded, deterministic source of *real* task failures served to
//!    `executor::run_tasks_with_policy`. Injected panics and
//!    transient errors actually abort the attempt and are retried with
//!    capped exponential backoff; stragglers charge virtual delay. The
//!    plan is a pure hash of `(fault_seed, stage, task index, attempt)`,
//!    so which attempts fail is completely independent of worker count
//!    and scheduling order — the precondition for the chaos suite's
//!    contract that any fault rate leaves the output bit-identical.
//!
//! A fault decision is drawn per *attempt* with a geometrically decaying
//! threshold `rate^(attempt+1)`: at `rate = 1.0` every attempt fails
//! (deterministic exhaustion, used by the tests), while at realistic
//! rates the probability that a task exhausts all `max_attempts` is
//! `rate^(A(A+1)/2)` — about 1.4e-8 per task at `rate = 0.3, A = 5` —
//! so chaos runs recover transparently instead of flaking.
//!
//! [`ResilienceStats`] aggregates what the injector and the durable
//! checkpoint store did (injections, retries, recoveries, straggler and
//! backoff virtual time, spills/restores); `metrics_report` appends its
//! table whenever any counter is nonzero.

use super::block::HasBytes;
use super::context::SparkContext;
use super::metrics::StageMetrics;
use super::rdd::BlockRdd;
use crate::util::fmt::{human_bytes, render_table};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Default cap on attempts per task under fault injection.
pub const DEFAULT_MAX_ATTEMPTS: usize = 5;

/// First retry's backoff charge, milliseconds (virtual time only).
const BACKOFF_BASE_MS: u64 = 10;
/// Backoff ceiling, milliseconds — the "capped" in capped exponential.
const BACKOFF_CAP_MS: u64 = 1_000;
/// Largest injected straggler delay, milliseconds.
const STRAGGLER_MAX_MS: u64 = 250;

/// One injected fault, decided by a [`FaultPlan`] for a specific
/// `(stage, task, attempt)` coordinate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Inject {
    /// The attempt panics before the task body runs.
    Panic,
    /// The attempt fails with a transient (retryable) error before the
    /// task body runs.
    TransientErr,
    /// The attempt runs to completion but is delayed by this many
    /// virtual milliseconds first (a slow executor, not a failure).
    StragglerDelay(u64),
}

/// Capped exponential backoff charged (in virtual time) before retry
/// `attempt + 1`.
pub(crate) fn backoff_ms(attempt: usize) -> u64 {
    BACKOFF_BASE_MS
        .saturating_mul(1u64 << attempt.min(16) as u32)
        .min(BACKOFF_CAP_MS)
}

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Uniform draw in `[0, 1)` from the top 53 bits of a hash.
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// A deterministic fault schedule: a pure function from
/// `(stage name, task index, attempt)` to "what, if anything, goes wrong".
///
/// Because the decision depends only on those coordinates (plus the seed),
/// two runs with the same plan inject the *same* faults into the *same*
/// tasks regardless of `--threads`, scheduling order, or wall-clock — so
/// the chaos suite can compare outputs bitwise across worker counts.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    rate: f64,
    seed: u64,
    max_attempts: usize,
}

impl FaultPlan {
    /// Build a plan. `rate` is clamped to `[0, 1]`; `max_attempts` to ≥ 1.
    pub fn new(rate: f64, seed: u64, max_attempts: usize) -> Self {
        Self {
            rate: rate.clamp(0.0, 1.0),
            seed,
            max_attempts: max_attempts.max(1),
        }
    }

    /// Injection probability per first attempt.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Attempt ceiling per task (≥ 1).
    pub fn max_attempts(&self) -> usize {
        self.max_attempts
    }

    /// Decide what happens to attempt `attempt` of task `task` in `stage`.
    ///
    /// Failures (panic / transient error) are drawn with threshold
    /// `rate^(attempt+1)` — retries are exponentially less likely to be
    /// re-hit, so realistic rates recover while `rate = 1.0` exhausts
    /// deterministically. Stragglers are drawn independently (an attempt
    /// that fails never also straggles), so they add virtual delay without
    /// ever changing which attempts fail.
    pub fn decide(&self, stage: &str, task: usize, attempt: usize) -> Option<Inject> {
        if self.rate <= 0.0 {
            return None;
        }
        let mut h = crate::data::io::fnv1a64(stage.as_bytes());
        h = splitmix64(h ^ self.seed.rotate_left(17));
        h = splitmix64(h ^ (task as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        h = splitmix64(h ^ attempt as u64);
        let threshold = self.rate.powi(attempt as i32 + 1);
        if unit(h) < threshold {
            return Some(if splitmix64(h ^ 0xd6e8_feb8_6659_fd93) & 1 == 0 {
                Inject::Panic
            } else {
                Inject::TransientErr
            });
        }
        let s = splitmix64(h ^ 0xa076_1d64_78bd_642f);
        if unit(s) < self.rate * 0.5 {
            return Some(Inject::StragglerDelay(1 + splitmix64(s) % STRAGGLER_MAX_MS));
        }
        None
    }
}

/// Monotonic resilience counters, shared by the executor's retry loop and
/// the durable checkpoint store (same relaxed-atomics pattern as
/// [`super::metrics::OffloadStats`] — monitoring data, not control flow).
#[derive(Default)]
pub struct ResilienceStats {
    injected_panics: AtomicU64,
    injected_errors: AtomicU64,
    stragglers: AtomicU64,
    retries: AtomicU64,
    recovered_tasks: AtomicU64,
    exhausted_tasks: AtomicU64,
    worker_losses: AtomicU64,
    straggler_virtual_ms: AtomicU64,
    backoff_virtual_ms: AtomicU64,
    checkpoint_spills: AtomicU64,
    checkpoint_spill_bytes: AtomicU64,
    checkpoint_restores: AtomicU64,
}

/// Point-in-time copy of [`ResilienceStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResilienceSnapshot {
    pub injected_panics: u64,
    pub injected_errors: u64,
    pub stragglers: u64,
    pub retries: u64,
    pub recovered_tasks: u64,
    pub exhausted_tasks: u64,
    pub worker_losses: u64,
    pub straggler_virtual_ms: u64,
    pub backoff_virtual_ms: u64,
    pub checkpoint_spills: u64,
    pub checkpoint_spill_bytes: u64,
    pub checkpoint_restores: u64,
}

impl ResilienceSnapshot {
    /// True when anything at all was recorded.
    pub fn any(&self) -> bool {
        *self != ResilienceSnapshot::default()
    }
}

impl ResilienceStats {
    pub(crate) fn record_injected_panic(&self) {
        self.injected_panics.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_injected_error(&self) {
        self.injected_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_straggler(&self, ms: u64) {
        self.stragglers.fetch_add(1, Ordering::Relaxed);
        self.straggler_virtual_ms.fetch_add(ms, Ordering::Relaxed);
    }

    pub(crate) fn record_retry(&self, backoff: u64) {
        self.retries.fetch_add(1, Ordering::Relaxed);
        self.backoff_virtual_ms.fetch_add(backoff, Ordering::Relaxed);
    }

    pub(crate) fn record_recovered(&self) {
        self.recovered_tasks.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_exhausted(&self) {
        self.exhausted_tasks.fetch_add(1, Ordering::Relaxed);
    }

    /// A remote worker process died or timed out mid-stage; its tasks
    /// were requeued. Recorded by the dist layer's retry loop — transport
    /// failures are typed errors there, never panics, so they can never
    /// poison this shared state.
    pub(crate) fn record_worker_loss(&self) {
        self.worker_losses.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_spill(&self, bytes: u64) {
        self.checkpoint_spills.fetch_add(1, Ordering::Relaxed);
        self.checkpoint_spill_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub(crate) fn record_restore(&self) {
        self.checkpoint_restores.fetch_add(1, Ordering::Relaxed);
    }

    /// Total virtual delay (stragglers + backoff) recorded so far, ms.
    /// Integer accumulation keeps the total independent of the order in
    /// which worker threads recorded their contributions.
    pub(crate) fn virtual_delay_ms(&self) -> u64 {
        self.straggler_virtual_ms.load(Ordering::Relaxed)
            + self.backoff_virtual_ms.load(Ordering::Relaxed)
    }

    /// Consistent-enough copy of all counters.
    pub fn snapshot(&self) -> ResilienceSnapshot {
        ResilienceSnapshot {
            injected_panics: self.injected_panics.load(Ordering::Relaxed),
            injected_errors: self.injected_errors.load(Ordering::Relaxed),
            stragglers: self.stragglers.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            recovered_tasks: self.recovered_tasks.load(Ordering::Relaxed),
            exhausted_tasks: self.exhausted_tasks.load(Ordering::Relaxed),
            worker_losses: self.worker_losses.load(Ordering::Relaxed),
            straggler_virtual_ms: self.straggler_virtual_ms.load(Ordering::Relaxed),
            backoff_virtual_ms: self.backoff_virtual_ms.load(Ordering::Relaxed),
            checkpoint_spills: self.checkpoint_spills.load(Ordering::Relaxed),
            checkpoint_spill_bytes: self.checkpoint_spill_bytes.load(Ordering::Relaxed),
            checkpoint_restores: self.checkpoint_restores.load(Ordering::Relaxed),
        }
    }

    /// Render the resilience block for run reports, or an empty string
    /// when nothing was recorded (the fault-free fast path stays silent).
    pub fn report(&self) -> String {
        let s = self.snapshot();
        if !s.any() {
            return String::new();
        }
        let rows = vec![
            vec![
                "injected".to_string(),
                "retries".to_string(),
                "recovered".to_string(),
                "exhausted".to_string(),
                "workers lost".to_string(),
                "stragglers".to_string(),
                "virtual delay".to_string(),
                "ckpt spills".to_string(),
                "ckpt restores".to_string(),
            ],
            vec![
                format!("{} panic / {} err", s.injected_panics, s.injected_errors),
                s.retries.to_string(),
                s.recovered_tasks.to_string(),
                s.exhausted_tasks.to_string(),
                s.worker_losses.to_string(),
                s.stragglers.to_string(),
                format!("{} ms", s.straggler_virtual_ms + s.backoff_virtual_ms),
                format!(
                    "{} ({})",
                    s.checkpoint_spills,
                    human_bytes(s.checkpoint_spill_bytes)
                ),
                s.checkpoint_restores.to_string(),
            ],
        ];
        format!("resilience\n{}", render_table(&rows))
    }
}

/// Everything `executor::run_tasks_with_policy` needs to inject, retry,
/// and account: the fault schedule, the shared counters, and a context
/// handle for charging straggler/backoff delay to the virtual clock.
/// Built on demand by `SparkContext::task_policy`; `None` there means the
/// stage runs on the plain fast path.
#[derive(Clone)]
pub struct TaskPolicy {
    pub(crate) plan: FaultPlan,
    pub(crate) stats: Arc<ResilienceStats>,
    pub(crate) ctx: SparkContext,
}

impl TaskPolicy {
    /// Build a policy for contexts that did not come from a
    /// `SparkContext` with an installed plan (tests, standalone drivers).
    pub(crate) fn new(plan: FaultPlan, stats: Arc<ResilienceStats>, ctx: SparkContext) -> Self {
        Self { plan, stats, ctx }
    }

    /// Charge accumulated injected delay to the virtual clock — called
    /// once per stage by the executor, with a deterministic integer total,
    /// never from inside worker threads.
    pub(crate) fn charge_virtual_ms(&self, ms: u64) {
        if ms > 0 {
            self.ctx.advance_clock(ms as f64 / 1000.0);
        }
    }
}

/// Outcome of a simulated executor failure.
#[derive(Clone, Debug)]
pub struct FailureReport {
    /// Node that failed.
    pub node: usize,
    /// Blocks that were resident on it.
    pub lost_blocks: usize,
    /// Bytes that had to be re-shuffled to rebuild them.
    pub reshuffled_bytes: u64,
    /// Lineage ancestry replayed (transformations).
    pub replayed_ops: usize,
    /// Virtual seconds charged to the recovery.
    pub recovery_secs: f64,
}

/// Simulate losing executor `node` while `rdd` is the live dataset.
///
/// Cost model: each lost block is recomputed by replaying the RDD's
/// ancestry (`ancestry_size + 1` stages at the average measured per-block
/// compute of the run so far), and its input data is re-shuffled once
/// across the network. The virtual clock advances; metrics record a
/// `recovery` stage. Returns what happened.
pub fn simulate_executor_loss<T: HasBytes + Send + Sync>(
    rdd: &BlockRdd<T>,
    node: usize,
) -> FailureReport {
    let ctx = rdd.context();
    let per_node = rdd.per_node_bytes();
    let lost_bytes = per_node.get(node).copied().unwrap_or(0);
    let nodes = ctx.nodes();

    // Blocks resident on the failed node.
    let part = rdd.partitioner();
    let lost_blocks = rdd
        .iter()
        .filter(|(id, _)| ctx.node_of(part.partition(**id), part.num_partitions()) == node)
        .count();

    let replayed_ops = ctx.lineage_ancestry(rdd.lineage_id) + 1;

    // Average measured per-block compute over the run so far; fall back to
    // a nominal 1 ms when nothing has been measured yet.
    let total_tasks = ctx.total_tasks().max(1);
    let avg_task = ctx.total_compute_real() / total_tasks as f64;
    let avg_task = if avg_task > 0.0 { avg_task } else { 1e-3 };

    // Recompute: lost blocks × replayed stages, executed on the surviving
    // nodes' cores in parallel.
    let surviving_cores = ((nodes.saturating_sub(1)).max(1)) * ctx.cluster().cores_per_node;
    let recompute = (lost_blocks * replayed_ops) as f64 * avg_task / surviving_cores as f64;
    // Re-shuffle the lost bytes once across the network.
    let reshuffle = lost_bytes as f64 / ctx.cluster().net_bandwidth.max(1.0);
    let recovery_secs = recompute + reshuffle;

    ctx.advance_clock(recovery_secs);
    ctx.push_metrics(StageMetrics {
        name: "recovery".to_string(),
        tasks: lost_blocks,
        compute_real: 0.0,
        virtual_span: recovery_secs,
        shuffle_bytes: lost_bytes,
        network_time: reshuffle,
        driver_time: recompute,
    });

    FailureReport {
        node,
        lost_blocks,
        reshuffled_bytes: lost_bytes,
        replayed_ops,
        recovery_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::engine::{BlockId, HashPartitioner, SparkContext};
    use crate::linalg::Matrix;
    use std::sync::Arc;

    fn deep_rdd(ctx: &SparkContext, depth: usize, checkpoint: bool) -> BlockRdd<Matrix> {
        let items: Vec<(BlockId, Matrix)> =
            (0..8).map(|i| (BlockId::new(i, i), Matrix::full(16, 16, 1.0))).collect();
        let part: Arc<dyn crate::engine::Partitioner> = Arc::new(HashPartitioner::new(8));
        let mut rdd = ctx.parallelize("x", items, part);
        for i in 0..depth {
            rdd = rdd.map_values("step", |_, m| {
                let mut m = m.clone();
                m.scale(1.0000001);
                m
            });
            if checkpoint && i % 5 == 4 {
                rdd.checkpoint();
            }
        }
        rdd
    }

    #[test]
    fn recovery_reports_losses() {
        let ctx = SparkContext::new(ClusterConfig::paper_testbed(4));
        let rdd = deep_rdd(&ctx, 10, false);
        let before = ctx.virtual_now();
        let report = simulate_executor_loss(&rdd, 0);
        assert!(report.lost_blocks > 0);
        assert!(report.replayed_ops >= 10);
        assert!(report.recovery_secs > 0.0);
        assert!(ctx.virtual_now() > before);
    }

    #[test]
    fn checkpointing_makes_recovery_cheaper() {
        let cost = |checkpoint: bool| -> f64 {
            let ctx = SparkContext::new(ClusterConfig::paper_testbed(4));
            let rdd = deep_rdd(&ctx, 30, checkpoint);
            simulate_executor_loss(&rdd, 1).recovery_secs
        };
        let with = cost(true);
        let without = cost(false);
        assert!(
            with < without,
            "checkpointed recovery {with} must beat unrestrained lineage {without}"
        );
    }

    #[test]
    fn losing_empty_node_is_cheap() {
        let ctx = SparkContext::new(ClusterConfig::paper_testbed(8));
        let items = vec![(BlockId::new(0, 0), Matrix::zeros(4, 4))];
        let part: Arc<dyn crate::engine::Partitioner> = Arc::new(HashPartitioner::new(1));
        let rdd = ctx.parallelize("tiny", items, part);
        // Node 7 hosts nothing (single partition on node 0).
        let report = simulate_executor_loss(&rdd, 7);
        assert_eq!(report.lost_blocks, 0);
        assert_eq!(report.reshuffled_bytes, 0);
    }

    #[test]
    fn plan_is_a_pure_function_of_coordinates() {
        let plan = FaultPlan::new(0.3, 42, 5);
        for task in 0..64 {
            for attempt in 0..5 {
                let a = plan.decide("apsp:p3[2]", task, attempt);
                let b = plan.decide("apsp:p3[2]", task, attempt);
                assert_eq!(a, b, "decision must be deterministic");
            }
        }
        // Different seeds / stages / tasks decorrelate the schedule.
        let other = FaultPlan::new(0.3, 43, 5);
        let differs = (0..256).any(|t| plan.decide("s", t, 0) != other.decide("s", t, 0));
        assert!(differs, "two seeds produced an identical 256-task schedule");
    }

    #[test]
    fn rate_zero_never_injects_and_rate_one_always_fails() {
        let quiet = FaultPlan::new(0.0, 7, 5);
        let chaos = FaultPlan::new(1.0, 7, 5);
        for task in 0..128 {
            for attempt in 0..5 {
                assert_eq!(quiet.decide("stage", task, attempt), None);
                match chaos.decide("stage", task, attempt) {
                    Some(Inject::Panic) | Some(Inject::TransientErr) => {}
                    other => panic!("rate 1.0 must fail every attempt, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn injection_rate_is_roughly_honest() {
        let plan = FaultPlan::new(0.2, 1, 5);
        let failures = (0..10_000)
            .filter(|&t| {
                matches!(
                    plan.decide("stage", t, 0),
                    Some(Inject::Panic) | Some(Inject::TransientErr)
                )
            })
            .count();
        // 10k first attempts at rate 0.2: expect ~2000 failures; the hash
        // is fixed, so this is a one-time check, not a flaky statistic.
        assert!(
            (1500..2500).contains(&failures),
            "rate 0.2 injected {failures}/10000 first-attempt failures"
        );
    }

    #[test]
    fn backoff_is_capped_exponential() {
        assert_eq!(backoff_ms(0), 10);
        assert_eq!(backoff_ms(1), 20);
        assert_eq!(backoff_ms(2), 40);
        assert_eq!(backoff_ms(10), 1_000); // capped
        assert_eq!(backoff_ms(60), 1_000); // shift guarded, still capped
    }

    #[test]
    fn stats_report_is_empty_until_something_happens() {
        let stats = ResilienceStats::default();
        assert_eq!(stats.report(), "");
        assert!(!stats.snapshot().any());
        stats.record_injected_panic();
        stats.record_retry(backoff_ms(0));
        stats.record_recovered();
        stats.record_straggler(25);
        stats.record_spill(4096);
        stats.record_restore();
        let s = stats.snapshot();
        assert!(s.any());
        assert_eq!(s.injected_panics, 1);
        assert_eq!(s.retries, 1);
        assert_eq!(s.recovered_tasks, 1);
        assert_eq!(s.stragglers, 1);
        assert_eq!(stats.virtual_delay_ms(), 25 + 10);
        let rendered = stats.report();
        assert!(rendered.contains("resilience"), "{rendered}");
        assert!(rendered.contains("1 panic / 0 err"), "{rendered}");
    }
}
