//! Block identifiers and payload sizing.
//!
//! The engine moves *logical blocks*: the paper's `b × b` NumPy sub-matrices
//! (and smaller keyed payloads like per-row kNN candidate lists). A
//! [`BlockId`] is the 2-D key `(I, J)`; payloads implement [`HasBytes`] so
//! shuffles, collects and broadcasts can be charged to the network model.

use crate::linalg::Matrix;

/// Key of a logical block: `(I, J)` in the paper's 2-D decomposition.
/// For non-matrix keyed data the components are reused (e.g. kNN candidate
/// lists are keyed `(I, i_loc)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BlockId {
    pub i: usize,
    pub j: usize,
}

impl BlockId {
    pub fn new(i: usize, j: usize) -> Self {
        Self { i, j }
    }

    /// True when this key lies in the upper triangle (`i <= j`).
    pub fn upper(&self) -> bool {
        self.i <= self.j
    }

    /// The transposed key.
    pub fn t(&self) -> Self {
        Self { i: self.j, j: self.i }
    }
}

impl std::fmt::Display for BlockId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{})", self.i, self.j)
    }
}

/// Serialized size estimate, used for shuffle/collect/broadcast accounting
/// and the per-node memory model (paper: 56 GB executor heaps; exceeding
/// them makes a run "impossible on given resources", Table I's `-`).
pub trait HasBytes {
    fn nbytes(&self) -> u64;
}

/// Fixed per-object overhead mirroring JVM/pickle headers.
const OBJ_OVERHEAD: u64 = 16;

impl HasBytes for Matrix {
    fn nbytes(&self) -> u64 {
        OBJ_OVERHEAD + 8 * (self.nrows() as u64) * (self.ncols() as u64)
    }
}

impl HasBytes for f64 {
    fn nbytes(&self) -> u64 {
        8
    }
}

impl HasBytes for usize {
    fn nbytes(&self) -> u64 {
        8
    }
}

impl<T: HasBytes> HasBytes for Vec<T> {
    fn nbytes(&self) -> u64 {
        OBJ_OVERHEAD + self.iter().map(HasBytes::nbytes).sum::<u64>()
    }
}

impl<T: HasBytes> HasBytes for Option<T> {
    fn nbytes(&self) -> u64 {
        self.as_ref().map_or(0, HasBytes::nbytes)
    }
}

/// A shared payload still *serializes* at full size: zero-copy is a local
/// execution optimization, so shuffle/collect/broadcast accounting (and
/// therefore every simulated cluster number) is identical whether a block
/// is sent by value or by `Arc`.
impl<T: HasBytes> HasBytes for std::sync::Arc<T> {
    fn nbytes(&self) -> u64 {
        self.as_ref().nbytes()
    }
}

impl<A: HasBytes, B: HasBytes> HasBytes for (A, B) {
    fn nbytes(&self) -> u64 {
        self.0.nbytes() + self.1.nbytes()
    }
}

impl<A: HasBytes, B: HasBytes, C: HasBytes> HasBytes for (A, B, C) {
    fn nbytes(&self) -> u64 {
        self.0.nbytes() + self.1.nbytes() + self.2.nbytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_id_orientation() {
        let b = BlockId::new(1, 3);
        assert!(b.upper());
        assert!(!b.t().upper());
        assert_eq!(b.t(), BlockId::new(3, 1));
        assert_eq!(format!("{b}"), "(1,3)");
    }

    #[test]
    fn sizes() {
        let m = Matrix::zeros(4, 8);
        assert_eq!(m.nbytes(), 16 + 8 * 32);
        assert_eq!((1.0f64, 2usize).nbytes(), 16);
        let v: Vec<f64> = vec![0.0; 10];
        assert_eq!(v.nbytes(), 16 + 80);
        assert_eq!(Some(3.0f64).nbytes(), 8);
        assert_eq!(None::<f64>.nbytes(), 0);
        // Arc looks through to the payload: wire size, not pointer size.
        let m2 = std::sync::Arc::new(Matrix::zeros(4, 8));
        assert_eq!(m2.nbytes(), 16 + 8 * 32);
    }
}
