//! In-block (sequential) Floyd–Warshall — Phase 1 of the blocked APSP.
//!
//! The paper delegates this to SciPy's `floyd_warshall`, operating in place
//! on one `b × b` diagonal block. This is the native twin of
//! `python/compile/kernels/fw.py`.

use crate::linalg::Matrix;

/// In-place Floyd–Warshall on a square block: after the call,
/// `g[i][j]` is the shortest path from `i` to `j` using only intermediate
/// vertices inside the block.
pub fn floyd_warshall_inplace(g: &mut Matrix) {
    let n = g.nrows();
    assert_eq!(n, g.ncols(), "FW requires a square block");
    for k in 0..n {
        // Copy row k once: after the pivot iteration, row k itself is
        // updated via d[i][k] + d[k][j]; for i == k the update is a no-op
        // because d[k][k] == 0 after relaxations (non-negative weights).
        let rowk = g.row(k).to_vec();
        for i in 0..n {
            let dik = g[(i, k)];
            if !dik.is_finite() {
                continue;
            }
            let row = g.row_mut(i);
            // Branch-free min vectorizes the relaxation (same §Perf fix as
            // the min-plus kernel).
            for (r, &rk) in row.iter_mut().zip(&rowk) {
                let cand = dik + rk;
                *r = if cand < *r { cand } else { *r };
            }
        }
    }
}

/// Convenience: FW on a copy.
pub fn floyd_warshall(g: &Matrix) -> Matrix {
    let mut out = g.clone();
    floyd_warshall_inplace(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const INF: f64 = f64::INFINITY;

    fn naive_fw(g: &Matrix) -> Matrix {
        let n = g.nrows();
        let mut d = g.clone();
        for k in 0..n {
            for i in 0..n {
                for j in 0..n {
                    let cand = d[(i, k)] + d[(k, j)];
                    if cand < d[(i, j)] {
                        d[(i, j)] = cand;
                    }
                }
            }
        }
        d
    }

    fn random_graph(n: usize, p_edge: f64, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut g = Matrix::full(n, n, INF);
        for i in 0..n {
            g[(i, i)] = 0.0;
            for j in 0..n {
                if i != j && rng.f64() < p_edge {
                    g[(i, j)] = rng.range(0.1, 5.0);
                }
            }
        }
        g
    }

    #[test]
    fn line_graph() {
        // 0 -1- 1 -1- 2: d(0,2) = 2.
        let mut g = Matrix::full(3, 3, INF);
        for i in 0..3 {
            g[(i, i)] = 0.0;
        }
        g[(0, 1)] = 1.0;
        g[(1, 0)] = 1.0;
        g[(1, 2)] = 1.0;
        g[(2, 1)] = 1.0;
        floyd_warshall_inplace(&mut g);
        assert_eq!(g[(0, 2)], 2.0);
        assert_eq!(g[(2, 0)], 2.0);
    }

    #[test]
    fn matches_naive_random() {
        for seed in 0..6 {
            let g = random_graph(20, 0.25, seed);
            let fast = floyd_warshall(&g);
            let slow = naive_fw(&g);
            assert!(fast.max_abs_diff_finite(&slow) < 1e-12, "seed={seed}");
        }
    }

    #[test]
    fn disconnected_stays_infinite() {
        let mut g = Matrix::full(4, 4, INF);
        for i in 0..4 {
            g[(i, i)] = 0.0;
        }
        g[(0, 1)] = 1.0;
        g[(1, 0)] = 1.0;
        // 2,3 disconnected from 0,1.
        g[(2, 3)] = 1.0;
        g[(3, 2)] = 1.0;
        floyd_warshall_inplace(&mut g);
        assert!(g[(0, 2)].is_infinite());
        assert!(g[(3, 1)].is_infinite());
        assert_eq!(g[(0, 1)], 1.0);
    }

    #[test]
    fn triangle_inequality_holds() {
        let g = floyd_warshall(&random_graph(15, 0.3, 42));
        let n = g.nrows();
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    if g[(i, k)].is_finite() && g[(k, j)].is_finite() {
                        assert!(g[(i, j)] <= g[(i, k)] + g[(k, j)] + 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn idempotent() {
        let g = floyd_warshall(&random_graph(12, 0.3, 7));
        let g2 = floyd_warshall(&g);
        assert!(g.max_abs_diff_finite(&g2) < 1e-12);
    }
}

#[cfg(test)]
impl Matrix {
    /// Max |a-b| treating equal infinities as zero difference (test helper).
    fn max_abs_diff_finite(&self, other: &Matrix) -> f64 {
        self.as_slice()
            .iter()
            .zip(other.as_slice())
            .map(|(a, b)| {
                if a.is_infinite() && b.is_infinite() {
                    0.0
                } else {
                    (a - b).abs()
                }
            })
            .fold(0.0, f64::max)
    }
}
