//! Min-plus (tropical) matrix product — the APSP hot spot.
//!
//! Over the semiring (ℝ₊∪{∞}, min, +): `C[i][j] = min_k A[i][k] + B[k][j]`.
//! The paper implements this in Numba-JIT'd Python; here it is the native
//! twin of the Pallas kernel in `python/compile/kernels/minplus.py`.
//!
//! All three entry points run one register-blocked micro-kernel
//! (`mp_tile`): the destination is processed in [`J_TILE`]-wide column
//! tiles held in a stack array across the whole `k` sweep, and the right
//! operand's column panel is packed k-major into per-thread scratch so the
//! inner loop is unit-stride. Versus the PR-1 loop nest (which re-streamed
//! `dst`'s whole row from L1/L2 for every `k`) the tile is loaded and
//! stored exactly once per `(row, tile)` pair.
//!
//! Bit-exactness: tiling changes only the *order in which output elements
//! are finished*, never the candidate set or the per-candidate arithmetic.
//! Each `dst[i][j]` still takes `min` over `a[i][k] + b[k][j]` for `k`
//! ascending; `+` on two finite f64s is a single correctly-rounded op and
//! `min` is associative/commutative, so the result is identical to the
//! untiled kernel bit for bit (the `kernel_tiling` property tests assert
//! equality, not closeness).
//!
//! `minplus_left_inplace` / `minplus_right_inplace` additionally avoid the
//! per-call clone of the destination's old value that the Phase-2 pivot
//! updates `A ← A ⊕ (D ⊗ A)` / `A ← A ⊕ (A ⊗ D)` would otherwise need:
//! the pre-update values are staged in per-thread scratch that is reused
//! across calls — no allocation on the hot path, and safe under the
//! multi-core stage executor because each worker owns its own scratch.

use super::tiling::{self, J_TILE};
use crate::linalg::Matrix;
use std::cell::RefCell;

thread_local! {
    /// Per-thread staging buffer for the in-place pivot updates
    /// (`minplus_right_inplace` stages the full pre-update block).
    static SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
    /// Per-thread packed k-major column panel of the right operand.
    static PANEL: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// `C = A ⊗ B` (min-plus product).
pub fn minplus(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::full(a.nrows(), b.ncols(), f64::INFINITY);
    minplus_into(a, b, &mut c);
    c
}

/// Register-blocked tile update shared by every min-plus entry point:
/// `dst[i][j0..j0+w] ⊕= min_k a[i][k] + panel[k][·]` for `i in 0..m`,
/// where `a` is a row-major `m×kk` buffer and `panel` a k-major `kk×w`
/// packed panel. The destination tile lives in a `[f64; J_TILE]` stack
/// array across the whole `k` sweep; the branch-free select compiles to
/// `vminpd` and the fixed-width path gives LLVM exact trip counts.
fn mp_tile(a: &[f64], kk: usize, panel: &[f64], dst: &mut Matrix, j0: usize, w: usize, m: usize) {
    if w == J_TILE {
        for i in 0..m {
            let arow = &a[i * kk..(i + 1) * kk];
            let drow = &mut dst.row_mut(i)[j0..j0 + J_TILE];
            let mut regs = [0.0f64; J_TILE];
            regs.copy_from_slice(drow);
            for (k, &aik) in arow.iter().enumerate() {
                if !aik.is_finite() {
                    // ∞ entries contribute nothing; skipping them is also
                    // the sparse fast path for barely-connected graphs.
                    continue;
                }
                let prow: &[f64; J_TILE] =
                    panel[k * J_TILE..(k + 1) * J_TILE].try_into().unwrap();
                for (r, &pv) in regs.iter_mut().zip(prow) {
                    let cand = aik + pv;
                    *r = if cand < *r { cand } else { *r };
                }
            }
            drow.copy_from_slice(&regs);
        }
    } else {
        // Ragged last tile: same candidate order, dynamic width.
        for i in 0..m {
            let arow = &a[i * kk..(i + 1) * kk];
            let drow = &mut dst.row_mut(i)[j0..j0 + w];
            for (k, &aik) in arow.iter().enumerate() {
                if !aik.is_finite() {
                    continue;
                }
                let prow = &panel[k * w..(k + 1) * w];
                for (d, &pv) in drow.iter_mut().zip(prow) {
                    let cand = aik + pv;
                    *d = if cand < *d { cand } else { *d };
                }
            }
        }
    }
}

/// `dst = min(dst, A ⊗ B)` — fused product + update.
pub fn minplus_into(a: &Matrix, b: &Matrix, dst: &mut Matrix) {
    let (m, kk) = (a.nrows(), a.ncols());
    let n = b.ncols();
    assert_eq!(kk, b.nrows(), "minplus shape mismatch");
    assert_eq!((dst.nrows(), dst.ncols()), (m, n), "dst shape mismatch");
    PANEL.with(|cell| {
        let mut panel = cell.borrow_mut();
        for (j0, w) in tiling::tiles(n, J_TILE) {
            tiling::pack_col_panel(b.as_slice(), n, kk, j0, w, &mut panel);
            mp_tile(a.as_slice(), kk, &panel, dst, j0, w, m);
        }
    });
}

/// `dst = dst ⊕ (A ⊗ dst₀)` where `dst₀` is `dst`'s value on entry — the
/// APSP Phase-2 row update with a square pivot `A`. Only the current
/// column panel of the old value needs staging: writes to tile `j` never
/// touch the columns a later tile reads, so the scratch is `b×J_TILE`
/// instead of the full-block copy the pre-tiling kernel kept.
pub fn minplus_left_inplace(a: &Matrix, dst: &mut Matrix) {
    let b = a.nrows();
    assert_eq!(a.ncols(), b, "pivot block must be square");
    assert_eq!(dst.nrows(), b, "minplus_left_inplace shape mismatch");
    let n = dst.ncols();
    PANEL.with(|cell| {
        let mut panel = cell.borrow_mut();
        for (j0, w) in tiling::tiles(n, J_TILE) {
            // Stage dst₀'s column panel *before* updating the tile.
            tiling::pack_col_panel(dst.as_slice(), n, b, j0, w, &mut panel);
            mp_tile(a.as_slice(), b, &panel, dst, j0, w, b);
        }
    });
}

/// `dst = dst ⊕ (dst₀ ⊗ B)` with a square pivot `B` — the APSP Phase-2
/// column update. Here every output column reads *all* of `dst₀`, so the
/// whole pre-update block is staged in per-thread scratch (as before) and
/// the tiled product runs scratch ⊗ packed-B-panel.
pub fn minplus_right_inplace(b: &Matrix, dst: &mut Matrix) {
    let bs = b.nrows();
    assert_eq!(b.ncols(), bs, "pivot block must be square");
    assert_eq!(dst.ncols(), bs, "minplus_right_inplace shape mismatch");
    let m = dst.nrows();
    SCRATCH.with(|s| {
        let mut scratch = s.borrow_mut();
        scratch.clear();
        scratch.extend_from_slice(dst.as_slice());
        PANEL.with(|p| {
            let mut panel = p.borrow_mut();
            for (j0, w) in tiling::tiles(bs, J_TILE) {
                tiling::pack_col_panel(b.as_slice(), bs, bs, j0, w, &mut panel);
                mp_tile(&scratch, bs, &panel, dst, j0, w, m);
            }
        });
    });
}

/// Element-wise `dst = min(dst, src)` (Phase-3 combine when the product is
/// computed separately, and the final symmetrization step). Branch-free
/// select, same as the fused inner loop — the old compare-and-store
/// defeated autovectorization on the PJRT combine path.
pub fn elementwise_min_into(dst: &mut Matrix, src: &Matrix) {
    assert_eq!((dst.nrows(), dst.ncols()), (src.nrows(), src.ncols()));
    for (d, &s) in dst.as_mut_slice().iter_mut().zip(src.as_slice()) {
        *d = if s < *d { s } else { *d };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.nrows(), b.ncols());
        for i in 0..a.nrows() {
            for j in 0..b.ncols() {
                let mut best = f64::INFINITY;
                for k in 0..a.ncols() {
                    best = best.min(a[(i, k)] + b[(k, j)]);
                }
                c[(i, j)] = best;
            }
        }
        c
    }

    fn random(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut a = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                a[(i, j)] = if rng.f64() < 0.2 { f64::INFINITY } else { rng.range(0.0, 10.0) };
            }
        }
        a
    }

    #[test]
    fn matches_naive() {
        for (m, k, n, seed) in [(4, 5, 6, 1), (8, 8, 8, 2), (1, 3, 1, 3), (16, 2, 16, 4)] {
            let a = random(m, k, seed);
            let b = random(k, n, seed + 50);
            let got = minplus(&a, &b);
            let want = naive(&a, &b);
            assert_eq!(got.as_slice(), want.as_slice(), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn matches_naive_across_tile_boundaries() {
        // Widths straddling J_TILE exercise the full and ragged tile paths.
        for n in [J_TILE - 1, J_TILE, J_TILE + 1, 2 * J_TILE + 3] {
            let a = random(5, 7, n as u64);
            let b = random(7, n, n as u64 + 9);
            let got = minplus(&a, &b);
            let want = naive(&a, &b);
            assert_eq!(got.as_slice(), want.as_slice(), "n={n}");
        }
    }

    #[test]
    fn identity_semiring() {
        // Min-plus identity: 0 on diagonal, ∞ elsewhere.
        let mut id = Matrix::full(5, 5, f64::INFINITY);
        for i in 0..5 {
            id[(i, i)] = 0.0;
        }
        let a = random(5, 5, 7);
        assert_eq!(minplus(&a, &id).as_slice(), a.as_slice());
        assert_eq!(minplus(&id, &a).as_slice(), a.as_slice());
    }

    #[test]
    fn fused_equals_separate() {
        let a = random(6, 7, 8);
        let b = random(7, 5, 9);
        let mut dst = random(6, 5, 10);
        let mut expect = dst.clone();
        let c = minplus(&a, &b);
        elementwise_min_into(&mut expect, &c);
        minplus_into(&a, &b, &mut dst);
        assert_eq!(dst.as_slice(), expect.as_slice());
    }

    #[test]
    fn left_inplace_matches_cloned_form() {
        for (b, n, seed) in [(5usize, 5usize, 1u64), (8, 3, 2), (7, 12, 3), (1, 4, 4), (17, 33, 5)]
        {
            let d = random(b, b, seed);
            let a0 = random(b, n, seed + 30);
            let mut got = a0.clone();
            minplus_left_inplace(&d, &mut got);
            let mut want = a0.clone();
            minplus_into(&d, &a0, &mut want);
            assert_eq!(got.as_slice(), want.as_slice(), "b={b} n={n}");
        }
    }

    #[test]
    fn right_inplace_matches_cloned_form() {
        for (m, b, seed) in [(5usize, 5usize, 5u64), (3, 8, 6), (12, 7, 7), (4, 1, 8), (33, 17, 9)]
        {
            let d = random(b, b, seed);
            let a0 = random(m, b, seed + 60);
            let mut got = a0.clone();
            minplus_right_inplace(&d, &mut got);
            let mut want = a0.clone();
            minplus_into(&a0, &d, &mut want);
            assert_eq!(got.as_slice(), want.as_slice(), "m={m} b={b}");
        }
    }

    #[test]
    fn inplace_kernels_reuse_scratch_across_sizes() {
        // Consecutive calls with different shapes must not bleed state.
        let d1 = random(6, 6, 20);
        let mut a1 = random(6, 9, 21);
        let r1 = {
            let mut w = a1.clone();
            minplus_into(&d1, &a1.clone(), &mut w);
            w
        };
        minplus_left_inplace(&d1, &mut a1);
        assert_eq!(a1.as_slice(), r1.as_slice());

        let d2 = random(3, 3, 22);
        let mut a2 = random(3, 4, 23);
        let r2 = {
            let mut w = a2.clone();
            minplus_into(&d2, &a2.clone(), &mut w);
            w
        };
        minplus_left_inplace(&d2, &mut a2);
        assert_eq!(a2.as_slice(), r2.as_slice());
    }

    #[test]
    fn associativity_property() {
        // (A⊗B)⊗C == A⊗(B⊗C) — semiring associativity on random inputs.
        for seed in 0..5 {
            let a = random(4, 4, seed);
            let b = random(4, 4, seed + 20);
            let c = random(4, 4, seed + 40);
            let l = minplus(&minplus(&a, &b), &c);
            let r = minplus(&a, &minplus(&b, &c));
            assert!(l.max_abs_diff(&r) < 1e-12);
        }
    }

    #[test]
    fn all_infinite_rows_stay_infinite() {
        let mut a = Matrix::full(3, 3, f64::INFINITY);
        a[(0, 0)] = 0.0;
        let b = Matrix::full(3, 3, f64::INFINITY);
        let c = minplus(&a, &b);
        assert!(c.as_slice().iter().all(|v| v.is_infinite()));
    }
}
