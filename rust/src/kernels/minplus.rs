//! Min-plus (tropical) matrix product — the APSP hot spot.
//!
//! Over the semiring (ℝ₊∪{∞}, min, +): `C[i][j] = min_k A[i][k] + B[k][j]`.
//! The paper implements this in Numba-JIT'd Python; here it is the native
//! twin of the Pallas kernel in `python/compile/kernels/minplus.py`.
//!
//! `minplus_into` also fuses the element-wise `min` with the destination
//! (the Phase-2/3 in-place update of the blocked Floyd–Warshall), which
//! halves memory traffic versus computing `C` then `min`-ing it in.

use crate::linalg::Matrix;

/// `C = A ⊗ B` (min-plus product).
pub fn minplus(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::full(a.nrows(), b.ncols(), f64::INFINITY);
    minplus_into(a, b, &mut c);
    c
}

/// `dst = min(dst, A ⊗ B)` — fused product + update.
///
/// Loop order is i-k-j so the inner loop walks `B`'s row `k` and `dst`'s
/// row `i` contiguously (the cache layout the paper enforces by choosing C
/// vs Fortran order before calling Numba).
pub fn minplus_into(a: &Matrix, b: &Matrix, dst: &mut Matrix) {
    let (m, kk) = (a.nrows(), a.ncols());
    let n = b.ncols();
    assert_eq!(kk, b.nrows(), "minplus shape mismatch");
    assert_eq!((dst.nrows(), dst.ncols()), (m, n), "dst shape mismatch");
    for i in 0..m {
        let arow = a.row(i);
        for k in 0..kk {
            let aik = arow[k];
            if !aik.is_finite() {
                // ∞ row entries contribute nothing; skipping them is also
                // the sparse fast path for barely-connected graphs.
                continue;
            }
            let brow = b.row(k);
            let drow = dst.row_mut(i);
            // Branch-free min lets LLVM vectorize this inner loop
            // (vminpd); the old `if cand < drow[j]` compare-and-store was
            // the APSP hot spot (§Perf: 4.0 -> ~8 Gop/s at b=256).
            for (d, &bv) in drow.iter_mut().zip(brow) {
                let cand = aik + bv;
                *d = if cand < *d { cand } else { *d };
            }
        }
    }
}

/// Element-wise `dst = min(dst, src)` (Phase-3 combine when the product is
/// computed separately, and the final symmetrization step).
pub fn elementwise_min_into(dst: &mut Matrix, src: &Matrix) {
    assert_eq!((dst.nrows(), dst.ncols()), (src.nrows(), src.ncols()));
    for (d, &s) in dst.as_mut_slice().iter_mut().zip(src.as_slice()) {
        if s < *d {
            *d = s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.nrows(), b.ncols());
        for i in 0..a.nrows() {
            for j in 0..b.ncols() {
                let mut best = f64::INFINITY;
                for k in 0..a.ncols() {
                    best = best.min(a[(i, k)] + b[(k, j)]);
                }
                c[(i, j)] = best;
            }
        }
        c
    }

    fn random(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut a = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                a[(i, j)] = if rng.f64() < 0.2 { f64::INFINITY } else { rng.range(0.0, 10.0) };
            }
        }
        a
    }

    #[test]
    fn matches_naive() {
        for (m, k, n, seed) in [(4, 5, 6, 1), (8, 8, 8, 2), (1, 3, 1, 3), (16, 2, 16, 4)] {
            let a = random(m, k, seed);
            let b = random(k, n, seed + 50);
            let got = minplus(&a, &b);
            let want = naive(&a, &b);
            assert_eq!(got.as_slice(), want.as_slice(), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn identity_semiring() {
        // Min-plus identity: 0 on diagonal, ∞ elsewhere.
        let mut id = Matrix::full(5, 5, f64::INFINITY);
        for i in 0..5 {
            id[(i, i)] = 0.0;
        }
        let a = random(5, 5, 7);
        assert_eq!(minplus(&a, &id).as_slice(), a.as_slice());
        assert_eq!(minplus(&id, &a).as_slice(), a.as_slice());
    }

    #[test]
    fn fused_equals_separate() {
        let a = random(6, 7, 8);
        let b = random(7, 5, 9);
        let mut dst = random(6, 5, 10);
        let mut expect = dst.clone();
        let c = minplus(&a, &b);
        elementwise_min_into(&mut expect, &c);
        minplus_into(&a, &b, &mut dst);
        assert_eq!(dst.as_slice(), expect.as_slice());
    }

    #[test]
    fn associativity_property() {
        // (A⊗B)⊗C == A⊗(B⊗C) — semiring associativity on random inputs.
        for seed in 0..5 {
            let a = random(4, 4, seed);
            let b = random(4, 4, seed + 20);
            let c = random(4, 4, seed + 40);
            let l = minplus(&minplus(&a, &b), &c);
            let r = minplus(&a, &minplus(&b, &c));
            assert!(l.max_abs_diff(&r) < 1e-12);
        }
    }

    #[test]
    fn all_infinite_rows_stay_infinite() {
        let mut a = Matrix::full(3, 3, f64::INFINITY);
        a[(0, 0)] = 0.0;
        let b = Matrix::full(3, 3, f64::INFINITY);
        let c = minplus(&a, &b);
        assert!(c.as_slice().iter().all(|v| v.is_infinite()));
    }
}
