//! Min-plus (tropical) matrix product — the APSP hot spot.
//!
//! Over the semiring (ℝ₊∪{∞}, min, +): `C[i][j] = min_k A[i][k] + B[k][j]`.
//! The paper implements this in Numba-JIT'd Python; here it is the native
//! twin of the Pallas kernel in `python/compile/kernels/minplus.py`.
//!
//! `minplus_into` fuses the element-wise `min` with the destination
//! (the Phase-2/3 in-place update of the blocked Floyd–Warshall), which
//! halves memory traffic versus computing `C` then `min`-ing it in.
//! `minplus_left_inplace` / `minplus_right_inplace` additionally remove
//! the per-call clone of the destination's old value that the Phase-2
//! pivot updates `A ← A ⊕ (D ⊗ A)` / `A ← A ⊕ (A ⊗ D)` would otherwise
//! need: the pre-update copy is staged in a per-thread scratch buffer that
//! is reused across calls — no allocation on the hot path, and safe under
//! the multi-core stage executor because each worker owns its own scratch.

use crate::linalg::Matrix;
use std::cell::RefCell;

thread_local! {
    /// Per-thread staging buffer for the in-place pivot updates.
    static SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// `C = A ⊗ B` (min-plus product).
pub fn minplus(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::full(a.nrows(), b.ncols(), f64::INFINITY);
    minplus_into(a, b, &mut c);
    c
}

/// `dst = min(dst, A ⊗ B)` — fused product + update.
///
/// Loop order is i-k-j so the inner loop walks `B`'s row `k` and `dst`'s
/// row `i` contiguously (the cache layout the paper enforces by choosing C
/// vs Fortran order before calling Numba).
pub fn minplus_into(a: &Matrix, b: &Matrix, dst: &mut Matrix) {
    let (m, kk) = (a.nrows(), a.ncols());
    let n = b.ncols();
    assert_eq!(kk, b.nrows(), "minplus shape mismatch");
    assert_eq!((dst.nrows(), dst.ncols()), (m, n), "dst shape mismatch");
    for i in 0..m {
        let arow = a.row(i);
        for k in 0..kk {
            let aik = arow[k];
            if !aik.is_finite() {
                // ∞ row entries contribute nothing; skipping them is also
                // the sparse fast path for barely-connected graphs.
                continue;
            }
            let brow = b.row(k);
            let drow = dst.row_mut(i);
            // Branch-free min lets LLVM vectorize this inner loop
            // (vminpd); the old `if cand < drow[j]` compare-and-store was
            // the APSP hot spot (§Perf: 4.0 -> ~8 Gop/s at b=256).
            for (d, &bv) in drow.iter_mut().zip(brow) {
                let cand = aik + bv;
                *d = if cand < *d { cand } else { *d };
            }
        }
    }
}

/// `dst = dst ⊕ (A ⊗ dst₀)` where `dst₀` is `dst`'s value on entry — the
/// APSP Phase-2 row update with a square pivot `A`. The old value is
/// staged in per-thread scratch, so the caller needs no clone.
pub fn minplus_left_inplace(a: &Matrix, dst: &mut Matrix) {
    let b = a.nrows();
    assert_eq!(a.ncols(), b, "pivot block must be square");
    assert_eq!(dst.nrows(), b, "minplus_left_inplace shape mismatch");
    let n = dst.ncols();
    SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        scratch.clear();
        scratch.extend_from_slice(dst.as_slice());
        for i in 0..b {
            let arow = a.row(i);
            for k in 0..b {
                let aik = arow[k];
                if !aik.is_finite() {
                    continue;
                }
                let srow = &scratch[k * n..(k + 1) * n];
                let drow = dst.row_mut(i);
                for (d, &sv) in drow.iter_mut().zip(srow) {
                    let cand = aik + sv;
                    *d = if cand < *d { cand } else { *d };
                }
            }
        }
    });
}

/// `dst = dst ⊕ (dst₀ ⊗ B)` with a square pivot `B` — the APSP Phase-2
/// column update, same scratch-staging strategy.
pub fn minplus_right_inplace(b: &Matrix, dst: &mut Matrix) {
    let bs = b.nrows();
    assert_eq!(b.ncols(), bs, "pivot block must be square");
    assert_eq!(dst.ncols(), bs, "minplus_right_inplace shape mismatch");
    let m = dst.nrows();
    SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        scratch.clear();
        scratch.extend_from_slice(dst.as_slice());
        for i in 0..m {
            let srow = &scratch[i * bs..(i + 1) * bs];
            for k in 0..bs {
                let sik = srow[k];
                if !sik.is_finite() {
                    continue;
                }
                let brow = b.row(k);
                let drow = dst.row_mut(i);
                for (d, &bv) in drow.iter_mut().zip(brow) {
                    let cand = sik + bv;
                    *d = if cand < *d { cand } else { *d };
                }
            }
        }
    });
}

/// Element-wise `dst = min(dst, src)` (Phase-3 combine when the product is
/// computed separately, and the final symmetrization step). Branch-free
/// select, same as the fused inner loop — the old compare-and-store
/// defeated autovectorization on the PJRT combine path.
pub fn elementwise_min_into(dst: &mut Matrix, src: &Matrix) {
    assert_eq!((dst.nrows(), dst.ncols()), (src.nrows(), src.ncols()));
    for (d, &s) in dst.as_mut_slice().iter_mut().zip(src.as_slice()) {
        *d = if s < *d { s } else { *d };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.nrows(), b.ncols());
        for i in 0..a.nrows() {
            for j in 0..b.ncols() {
                let mut best = f64::INFINITY;
                for k in 0..a.ncols() {
                    best = best.min(a[(i, k)] + b[(k, j)]);
                }
                c[(i, j)] = best;
            }
        }
        c
    }

    fn random(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut a = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                a[(i, j)] = if rng.f64() < 0.2 { f64::INFINITY } else { rng.range(0.0, 10.0) };
            }
        }
        a
    }

    #[test]
    fn matches_naive() {
        for (m, k, n, seed) in [(4, 5, 6, 1), (8, 8, 8, 2), (1, 3, 1, 3), (16, 2, 16, 4)] {
            let a = random(m, k, seed);
            let b = random(k, n, seed + 50);
            let got = minplus(&a, &b);
            let want = naive(&a, &b);
            assert_eq!(got.as_slice(), want.as_slice(), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn identity_semiring() {
        // Min-plus identity: 0 on diagonal, ∞ elsewhere.
        let mut id = Matrix::full(5, 5, f64::INFINITY);
        for i in 0..5 {
            id[(i, i)] = 0.0;
        }
        let a = random(5, 5, 7);
        assert_eq!(minplus(&a, &id).as_slice(), a.as_slice());
        assert_eq!(minplus(&id, &a).as_slice(), a.as_slice());
    }

    #[test]
    fn fused_equals_separate() {
        let a = random(6, 7, 8);
        let b = random(7, 5, 9);
        let mut dst = random(6, 5, 10);
        let mut expect = dst.clone();
        let c = minplus(&a, &b);
        elementwise_min_into(&mut expect, &c);
        minplus_into(&a, &b, &mut dst);
        assert_eq!(dst.as_slice(), expect.as_slice());
    }

    #[test]
    fn left_inplace_matches_cloned_form() {
        for (b, n, seed) in [(5usize, 5usize, 1u64), (8, 3, 2), (7, 12, 3), (1, 4, 4)] {
            let d = random(b, b, seed);
            let a0 = random(b, n, seed + 30);
            let mut got = a0.clone();
            minplus_left_inplace(&d, &mut got);
            let mut want = a0.clone();
            minplus_into(&d, &a0, &mut want);
            assert_eq!(got.as_slice(), want.as_slice(), "b={b} n={n}");
        }
    }

    #[test]
    fn right_inplace_matches_cloned_form() {
        for (m, b, seed) in [(5usize, 5usize, 5u64), (3, 8, 6), (12, 7, 7), (4, 1, 8)] {
            let d = random(b, b, seed);
            let a0 = random(m, b, seed + 60);
            let mut got = a0.clone();
            minplus_right_inplace(&d, &mut got);
            let mut want = a0.clone();
            minplus_into(&a0, &d, &mut want);
            assert_eq!(got.as_slice(), want.as_slice(), "m={m} b={b}");
        }
    }

    #[test]
    fn inplace_kernels_reuse_scratch_across_sizes() {
        // Consecutive calls with different shapes must not bleed state.
        let d1 = random(6, 6, 20);
        let mut a1 = random(6, 9, 21);
        let r1 = {
            let mut w = a1.clone();
            minplus_into(&d1, &a1.clone(), &mut w);
            w
        };
        minplus_left_inplace(&d1, &mut a1);
        assert_eq!(a1.as_slice(), r1.as_slice());

        let d2 = random(3, 3, 22);
        let mut a2 = random(3, 4, 23);
        let r2 = {
            let mut w = a2.clone();
            minplus_into(&d2, &a2.clone(), &mut w);
            w
        };
        minplus_left_inplace(&d2, &mut a2);
        assert_eq!(a2.as_slice(), r2.as_slice());
    }

    #[test]
    fn associativity_property() {
        // (A⊗B)⊗C == A⊗(B⊗C) — semiring associativity on random inputs.
        for seed in 0..5 {
            let a = random(4, 4, seed);
            let b = random(4, 4, seed + 20);
            let c = random(4, 4, seed + 40);
            let l = minplus(&minplus(&a, &b), &c);
            let r = minplus(&a, &minplus(&b, &c));
            assert!(l.max_abs_diff(&r) < 1e-12);
        }
    }

    #[test]
    fn all_infinite_rows_stay_infinite() {
        let mut a = Matrix::full(3, 3, f64::INFINITY);
        a[(0, 0)] = 0.0;
        let b = Matrix::full(3, 3, f64::INFINITY);
        let c = minplus(&a, &b);
        assert!(c.as_slice().iter().all(|v| v.is_infinite()));
    }
}
