//! Native Rust block kernels.
//!
//! Each kernel here is the CPU-native twin of a Pallas kernel in
//! `python/compile/kernels/`: the engine can run either through the
//! [`crate::backend::Backend`] abstraction, and the `runtime_equivalence`
//! integration tests assert both produce identical numerics. In the paper
//! these are the NumPy/SciPy/Numba routines offloaded to MKL.
//!
//! The compute-intensive kernels (min-plus, distance blocks, gemm, the
//! kNN column selection) are cache- and register-blocked through the
//! shared [`tiling`] module — see its docs for the tile geometry and the
//! determinism contract.

pub mod centering;
pub mod floyd_warshall;
pub mod kselect;
pub mod matvec;
pub mod minplus;
pub mod sqdist;
pub mod tiling;

/// Value used for "no edge" in the neighborhood graph and APSP blocks. A
/// large finite value rather than `f64::INFINITY` so that AOT-compiled
/// kernels (which may add two "infinities") cannot produce NaNs via
/// `inf - inf`-style corner cases, matching the Python side's `BIG`.
pub const BIG: f64 = 1.0e30;
