//! Pairwise Euclidean distance block kernel.
//!
//! Computes the `bi × bj` block `M[i][j] = ‖x_i − y_j‖₂` for a pair of point
//! blocks, using the Gram-matrix expansion `‖x‖² + ‖y‖² − 2·X·Yᵀ` — the same
//! formulation the Pallas kernel uses so that on a real TPU the inner
//! product maps onto the MXU (see DESIGN.md §9).
//!
//! The Gram product is a packed, register-blocked BLAS-3 tile product
//! rather than the per-`(i,j)` scalar dot of PR 1: the `Y` block is packed
//! transposed into a k-major [`NR`]-wide per-thread panel, and an
//! [`MR`]`×`[`NR`] accumulator tile is computed per `k` sweep, so each
//! inner iteration does `MR·NR` FMAs on unit-stride operands instead of
//! finishing one dot at a time. Each output's dot is still a single
//! accumulator chain over `k` ascending, so a pair's distance is a pure
//! function of the two rows — independent of block decomposition and tile
//! position, which is what keeps the engine's cross-block distances
//! bit-identical to the dense references.

use super::tiling::{self, MR, NR};
use crate::linalg::Matrix;
use std::cell::RefCell;

thread_local! {
    /// Per-thread packed B-panel (the `Y` tile, transposed k-major).
    static PACK: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// Squared norms of each row.
pub fn row_sqnorms(x: &Matrix) -> Vec<f64> {
    (0..x.nrows())
        .map(|i| x.row(i).iter().map(|v| v * v).sum())
        .collect()
}

/// Packed Gram micro-kernel: `acc[im][jn] += Σ_k xi[i0+im][k] · panel[k][jn]`
/// over the full `k = 0..d` sweep. The `MR×NR` accumulator tile stays in
/// registers; the full-tile path has compile-time trip counts.
#[inline]
fn gram_micro(
    xi: &Matrix,
    i0: usize,
    iw: usize,
    panel: &[f64],
    jw: usize,
    d: usize,
    acc: &mut [[f64; NR]; MR],
) {
    if iw == MR && jw == NR {
        let rows: [&[f64]; MR] = core::array::from_fn(|im| xi.row(i0 + im));
        for k in 0..d {
            let p: &[f64; NR] = panel[k * NR..(k + 1) * NR].try_into().unwrap();
            for (im, row) in rows.iter().enumerate() {
                let a = row[k];
                for (ac, &pv) in acc[im].iter_mut().zip(p) {
                    *ac += a * pv;
                }
            }
        }
    } else {
        for k in 0..d {
            let p = &panel[k * jw..(k + 1) * jw];
            for im in 0..iw {
                let a = xi.row(i0 + im)[k];
                for (ac, &pv) in acc[im][..jw].iter_mut().zip(p) {
                    *ac += a * pv;
                }
            }
        }
    }
}

#[inline]
fn finish_dist(ni: f64, nj: f64, dot: f64) -> f64 {
    let d2 = ni + nj - 2.0 * dot;
    // Guard tiny negatives from cancellation.
    if d2 > 0.0 {
        d2.sqrt()
    } else {
        0.0
    }
}

/// Euclidean distance block between row-blocks `xi` (bi×D) and `xj` (bj×D).
pub fn dist_block(xi: &Matrix, xj: &Matrix) -> Matrix {
    assert_eq!(xi.ncols(), xj.ncols(), "dimension mismatch");
    let bi = xi.nrows();
    let bj = xj.nrows();
    let d = xi.ncols();
    let ni = row_sqnorms(xi);
    let nj = row_sqnorms(xj);
    let mut out = Matrix::zeros(bi, bj);
    PACK.with(|cell| {
        let mut packed = cell.borrow_mut();
        for (j0, jw) in tiling::tiles(bj, NR) {
            tiling::pack_rows_transposed(xj.as_slice(), d, j0, jw, &mut packed);
            for (i0, iw) in tiling::tiles(bi, MR) {
                let mut acc = [[0.0f64; NR]; MR];
                gram_micro(xi, i0, iw, &packed, jw, d, &mut acc);
                for (im, arow) in acc.iter().enumerate().take(iw) {
                    let orow = &mut out.row_mut(i0 + im)[j0..j0 + jw];
                    for (jn, o) in orow.iter_mut().enumerate() {
                        *o = finish_dist(ni[i0 + im], nj[j0 + jn], arow[jn]);
                    }
                }
            }
        }
    });
    out
}

/// Diagonal-block variant: `dist_block(x, x)` exploiting symmetry — only
/// micro-tiles intersecting the strict upper triangle are computed, the
/// diagonal is exactly zero, and the lower triangle is mirrored from the
/// upper, so the result is bit-symmetric at roughly half the FLOPs.
pub fn dist_block_sym(x: &Matrix) -> Matrix {
    let n = x.nrows();
    let d = x.ncols();
    let nrm = row_sqnorms(x);
    let mut out = Matrix::zeros(n, n);
    PACK.with(|cell| {
        let mut packed = cell.borrow_mut();
        for (j0, jw) in tiling::tiles(n, NR) {
            tiling::pack_rows_transposed(x.as_slice(), d, j0, jw, &mut packed);
            for (i0, iw) in tiling::tiles(n, MR) {
                if i0 + 1 >= j0 + jw {
                    continue; // tile entirely on/below the diagonal
                }
                let mut acc = [[0.0f64; NR]; MR];
                gram_micro(x, i0, iw, &packed, jw, d, &mut acc);
                for (im, arow) in acc.iter().enumerate().take(iw) {
                    let gi = i0 + im;
                    for (jn, &dot) in arow.iter().enumerate().take(jw) {
                        let gj = j0 + jn;
                        if gj > gi {
                            out[(gi, gj)] = finish_dist(nrm[gi], nrm[gj], dot);
                        }
                    }
                }
            }
        }
    });
    for i in 1..n {
        for j in 0..i {
            out[(i, j)] = out[(j, i)];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(xi: &Matrix, xj: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(xi.nrows(), xj.nrows());
        for i in 0..xi.nrows() {
            for j in 0..xj.nrows() {
                let d: f64 = xi
                    .row(i)
                    .iter()
                    .zip(xj.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                out[(i, j)] = d.sqrt();
            }
        }
        out
    }

    fn random(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x[(i, j)] = rng.gaussian();
            }
        }
        x
    }

    #[test]
    fn matches_naive() {
        for (n, m, d, seed) in [(5, 7, 3, 1), (16, 16, 784, 2), (1, 9, 2, 3)] {
            let xi = random(n, d, seed);
            let xj = random(m, d, seed + 100);
            let got = dist_block(&xi, &xj);
            let want = naive(&xi, &xj);
            assert!(got.max_abs_diff(&want) < 1e-9, "n={n} m={m} d={d}");
        }
    }

    #[test]
    fn matches_naive_on_tile_boundaries() {
        for (n, m) in [(MR - 1, NR - 1), (MR, NR), (MR + 1, NR + 1), (2 * MR + 1, 2 * NR + 3)] {
            for d in [1usize, 7, 8, 9] {
                let xi = random(n, d, (n * m + d) as u64);
                let xj = random(m, d, (n * m + d) as u64 + 100);
                let got = dist_block(&xi, &xj);
                let want = naive(&xi, &xj);
                assert!(got.max_abs_diff(&want) < 1e-9, "n={n} m={m} d={d}");
            }
        }
    }

    #[test]
    fn symmetric_diag_zero() {
        let x = random(12, 4, 5);
        let m = dist_block_sym(&x);
        for i in 0..12 {
            assert_eq!(m[(i, i)], 0.0);
            for j in 0..12 {
                // Mirrored construction: bit-symmetric, not just close.
                assert_eq!(m[(i, j)].to_bits(), m[(j, i)].to_bits());
            }
        }
    }

    #[test]
    fn sym_matches_general_kernel() {
        for n in [1usize, 7, 8, 9, 21] {
            let x = random(n, 6, n as u64 + 40);
            let full = dist_block(&x, &x);
            let sym = dist_block_sym(&x);
            for i in 0..n {
                for j in 0..n {
                    if i == j {
                        assert_eq!(sym[(i, j)], 0.0);
                    } else {
                        assert_eq!(sym[(i, j)].to_bits(), full[(i, j)].to_bits(), "n={n} ({i},{j})");
                    }
                }
            }
        }
    }

    #[test]
    fn no_negative_under_cancellation() {
        // Two nearly identical far-from-origin points stress the Gram form.
        let mut xi = Matrix::full(2, 3, 1e8);
        xi[(1, 0)] += 1e-4;
        let m = dist_block(&xi, &xi);
        assert!(m.as_slice().iter().all(|&v| v >= 0.0));
        let s = dist_block_sym(&xi);
        assert!(s.as_slice().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn known_values() {
        let a = Matrix::from_rows(&[vec![0.0, 0.0], vec![3.0, 4.0]]);
        let m = dist_block_sym(&a);
        assert!((m[(0, 1)] - 5.0).abs() < 1e-12);
    }
}
