//! Pairwise Euclidean distance block kernel.
//!
//! Computes the `bi × bj` block `M[i][j] = ‖x_i − y_j‖₂` for a pair of point
//! blocks, using the Gram-matrix expansion `‖x‖² + ‖y‖² − 2·x·y` — the same
//! formulation the Pallas kernel uses so that on a real TPU the inner
//! product maps onto the MXU (see DESIGN.md §9).

use crate::linalg::Matrix;

/// Squared norms of each row.
pub fn row_sqnorms(x: &Matrix) -> Vec<f64> {
    (0..x.nrows())
        .map(|i| x.row(i).iter().map(|v| v * v).sum())
        .collect()
}

/// Euclidean distance block between row-blocks `xi` (bi×D) and `xj` (bj×D).
pub fn dist_block(xi: &Matrix, xj: &Matrix) -> Matrix {
    assert_eq!(xi.ncols(), xj.ncols(), "dimension mismatch");
    let bi = xi.nrows();
    let bj = xj.nrows();
    let ni = row_sqnorms(xi);
    let nj = row_sqnorms(xj);
    // G[i][j] = Σ_k xi[i][k]·xj[j][k]: both operands are walked row-wise,
    // so the inner dot is over two contiguous slices.
    let mut out = Matrix::zeros(bi, bj);
    for i in 0..bi {
        let xr = xi.row(i);
        let orow = out.row_mut(i);
        for j in 0..bj {
            let yr = xj.row(j);
            // Four independent accumulators break the serial FP-add
            // dependency so LLVM can vectorize the dot (§Perf: ~1.9× on
            // D=784 blocks).
            let mut acc = [0.0f64; 4];
            let chunks = xr.len() / 4;
            for c in 0..chunks {
                let base = 4 * c;
                acc[0] += xr[base] * yr[base];
                acc[1] += xr[base + 1] * yr[base + 1];
                acc[2] += xr[base + 2] * yr[base + 2];
                acc[3] += xr[base + 3] * yr[base + 3];
            }
            let mut dot = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            for t in 4 * chunks..xr.len() {
                dot += xr[t] * yr[t];
            }
            let d2 = ni[i] + nj[j] - 2.0 * dot;
            // Guard tiny negatives from cancellation.
            orow[j] = if d2 > 0.0 { d2.sqrt() } else { 0.0 };
        }
    }
    out
}

/// Diagonal-block variant: `dist_block(x, x)` with an exactly-zero diagonal.
pub fn dist_block_sym(x: &Matrix) -> Matrix {
    let mut m = dist_block(x, x);
    for i in 0..x.nrows() {
        m[(i, i)] = 0.0;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(xi: &Matrix, xj: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(xi.nrows(), xj.nrows());
        for i in 0..xi.nrows() {
            for j in 0..xj.nrows() {
                let d: f64 = xi
                    .row(i)
                    .iter()
                    .zip(xj.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                out[(i, j)] = d.sqrt();
            }
        }
        out
    }

    fn random(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x[(i, j)] = rng.gaussian();
            }
        }
        x
    }

    #[test]
    fn matches_naive() {
        for (n, m, d, seed) in [(5, 7, 3, 1), (16, 16, 784, 2), (1, 9, 2, 3)] {
            let xi = random(n, d, seed);
            let xj = random(m, d, seed + 100);
            let got = dist_block(&xi, &xj);
            let want = naive(&xi, &xj);
            assert!(got.max_abs_diff(&want) < 1e-9, "n={n} m={m} d={d}");
        }
    }

    #[test]
    fn symmetric_diag_zero() {
        let x = random(12, 4, 5);
        let m = dist_block_sym(&x);
        for i in 0..12 {
            assert_eq!(m[(i, i)], 0.0);
            for j in 0..12 {
                assert!((m[(i, j)] - m[(j, i)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn no_negative_under_cancellation() {
        // Two nearly identical far-from-origin points stress the Gram form.
        let mut xi = Matrix::full(2, 3, 1e8);
        xi[(1, 0)] += 1e-4;
        let m = dist_block(&xi, &xi);
        assert!(m.as_slice().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn known_values() {
        let a = Matrix::from_rows(&[vec![0.0, 0.0], vec![3.0, 4.0]]);
        let m = dist_block_sym(&a);
        assert!((m[(0, 1)] - 5.0).abs() < 1e-12);
    }
}
