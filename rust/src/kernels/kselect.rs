//! Heap-based k-smallest selection — the paper's per-block `L_k` lists.
//!
//! For each local row of a distance block, keep the `k` smallest entries
//! (value + global column coordinate) with a bounded max-heap, then merge
//! per-block lists into the global kNN list per point.

use super::tiling;
use crate::linalg::Matrix;
use std::cell::RefCell;

thread_local! {
    /// Per-thread scratch for the blocked transpose behind [`cols_topk`].
    static TSCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// One nearest-neighbor candidate: (distance, global column index).
pub type Neighbor = (f64, usize);

/// Bounded max-heap over `Neighbor`s keeping the k smallest.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    // Max-heap by distance (largest at root, evicted first).
    heap: Vec<Neighbor>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        Self { k, heap: Vec::with_capacity(k + 1) }
    }

    /// Offer a candidate; keeps at most k smallest.
    #[inline]
    pub fn push(&mut self, d: f64, idx: usize) {
        if self.heap.len() < self.k {
            self.heap.push((d, idx));
            self.sift_up(self.heap.len() - 1);
        } else if d < self.heap[0].0 {
            self.heap[0] = (d, idx);
            self.sift_down(0);
        }
    }

    /// Current worst (largest) kept distance, if full.
    pub fn threshold(&self) -> Option<f64> {
        if self.heap.len() == self.k {
            Some(self.heap[0].0)
        } else {
            None
        }
    }

    /// Extract the kept neighbors sorted ascending by (distance, index).
    pub fn into_sorted(self) -> Vec<Neighbor> {
        let mut v = self.heap;
        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        v
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].0 > self.heap[parent].0 {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < self.heap.len() && self.heap[l].0 > self.heap[largest].0 {
                largest = l;
            }
            if r < self.heap.len() && self.heap[r].0 > self.heap[largest].0 {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }
}

/// Merge several per-block candidate lists into one global top-k
/// (the paper's `combineByKey` reduction of the `L_k` lists).
pub fn merge_topk(k: usize, lists: &[Vec<Neighbor>]) -> Vec<Neighbor> {
    let mut top = TopK::new(k);
    for list in lists {
        for &(d, i) in list {
            top.push(d, i);
        }
    }
    top.into_sorted()
}

/// Top-k smallest entries of a slice, excluding index `exclude`
/// (a point is not its own neighbor). Returns (value, index) ascending.
pub fn row_topk(row: &[f64], k: usize, offset: usize, exclude: Option<usize>) -> Vec<Neighbor> {
    let mut top = TopK::new(k);
    for (j, &d) in row.iter().enumerate() {
        let gj = offset + j;
        if Some(gj) == exclude {
            continue;
        }
        top.push(d, gj);
    }
    top.into_sorted()
}

/// Top-k smallest entries of every *column* of `blk`: entry `j` of the
/// result is `row_topk` over column `j` with row indices offset by
/// `offset`. Instead of gathering each column with a strided scalar loop
/// (one cache miss per element once the block exceeds L1, and a `Vec`
/// allocation per column — the kNN under-diagonal hot spot), the block is
/// transposed once through the cache-blocked [`tiling::transpose_into`]
/// into per-thread scratch and the selection runs over contiguous rows.
/// Candidate order per column is rows-ascending, identical to the scalar
/// gather, so the returned lists are bit-identical to the old path.
pub fn cols_topk(blk: &Matrix, k: usize, offset: usize) -> Vec<Vec<Neighbor>> {
    let (r, c) = (blk.nrows(), blk.ncols());
    TSCRATCH.with(|cell| {
        let mut t = cell.borrow_mut();
        t.resize(r * c, 0.0);
        tiling::transpose_into(blk.as_slice(), r, c, t.as_mut_slice());
        (0..c).map(|j| row_topk(&t[j * r..(j + 1) * r], k, offset, None)).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn keeps_k_smallest() {
        let mut t = TopK::new(3);
        for (i, d) in [5.0, 1.0, 4.0, 2.0, 3.0, 0.5].iter().enumerate() {
            t.push(*d, i);
        }
        let got = t.into_sorted();
        assert_eq!(got, vec![(0.5, 5), (1.0, 1), (2.0, 3)]);
    }

    #[test]
    fn fewer_than_k() {
        let mut t = TopK::new(10);
        t.push(2.0, 0);
        t.push(1.0, 1);
        assert_eq!(t.len(), 2);
        assert_eq!(t.threshold(), None);
        assert_eq!(t.into_sorted(), vec![(1.0, 1), (2.0, 0)]);
    }

    #[test]
    fn matches_full_sort_random() {
        let mut rng = Rng::seed(1);
        for k in [1, 3, 10, 50] {
            let xs: Vec<f64> = (0..200).map(|_| rng.f64()).collect();
            let got = row_topk(&xs, k, 0, None);
            let mut all: Vec<Neighbor> = xs.iter().cloned().zip(0..).map(|(d, i)| (d, i)).collect();
            all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            all.truncate(k);
            assert_eq!(got, all, "k={k}");
        }
    }

    #[test]
    fn exclusion_works() {
        let row = [0.0, 5.0, 1.0];
        let got = row_topk(&row, 2, 100, Some(100));
        assert_eq!(got, vec![(1.0, 102), (5.0, 101)]);
    }

    #[test]
    fn merge_equals_global() {
        let mut rng = Rng::seed(2);
        let xs: Vec<f64> = (0..300).map(|_| rng.f64()).collect();
        // Split into 3 chunks, top-k each, merge.
        let k = 7;
        let lists: Vec<Vec<Neighbor>> = xs
            .chunks(100)
            .enumerate()
            .map(|(c, chunk)| row_topk(chunk, k, c * 100, None))
            .collect();
        let merged = merge_topk(k, &lists);
        let global = row_topk(&xs, k, 0, None);
        assert_eq!(merged, global);
    }

    #[test]
    fn ties_break_by_index() {
        let row = [1.0, 1.0, 1.0, 1.0];
        let got = row_topk(&row, 2, 0, None);
        assert_eq!(got, vec![(1.0, 0), (1.0, 1)]);
    }

    #[test]
    fn cols_topk_matches_scalar_gather() {
        let mut rng = Rng::seed(3);
        for (r, c) in [(1usize, 1usize), (7, 5), (33, 31), (40, 64), (64, 40)] {
            let mut m = Matrix::zeros(r, c);
            for i in 0..r {
                for j in 0..c {
                    m[(i, j)] = rng.f64();
                }
            }
            let got = cols_topk(&m, 4, 17);
            assert_eq!(got.len(), c);
            for (j, list) in got.iter().enumerate() {
                let col: Vec<f64> = (0..r).map(|i| m[(i, j)]).collect();
                assert_eq!(list, &row_topk(&col, 4, 17, None), "r={r} c={c} col {j}");
            }
        }
    }
}
