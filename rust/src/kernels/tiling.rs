//! Shared tile geometry for the register-blocked kernel suite.
//!
//! Every numeric hot path (min-plus APSP updates, the Gram-product
//! distance blocks, the power-iteration `A·Q` products, the kNN
//! column-side selection) blocks its loops with the constants defined
//! here, so the cache/register story is tuned in exactly one place:
//!
//! * [`J_TILE`] destination columns are held in a stack array across the
//!   whole `k` sweep — the micro-kernels read/write `dst` once per tile
//!   instead of re-streaming the row from L2 for every `k` (the BLAS-2 →
//!   BLAS-3 step the paper gets for free from MKL).
//! * Operand panels are *packed* into small contiguous per-thread scratch
//!   buffers (k-major, tile-width rows) so the inner loop walks memory
//!   unit-stride regardless of the source matrix's leading dimension.
//! * The packed Gram micro-kernel computes an [`MR`]`×`[`NR`] accumulator
//!   tile per `k` sweep (MR·NR = 32 f64 = 8 AVX2 vectors, leaving
//!   registers for the broadcast operand and panel loads).
//!
//! Determinism contract: tiling only changes *which* output elements are
//! produced together, never the reduction order *within* an element.
//! Every kernel built on this module accumulates each output element over
//! `k` ascending with a single chain, so results are a pure function of
//! the input — independent of tile sizes, block decomposition and worker
//! count (see `tests/determinism_parallel.rs` and `tests/kernel_tiling.rs`).

/// f64 lanes in one vector register on the widest ISA we tune for
/// (AVX2 `ymm`; on NEON/SSE2 the compiler simply uses two 2-lane ops).
pub const SIMD_WIDTH: usize = 4;

/// Unroll factor of the j-register tile: enough independent accumulator
/// vectors to hide FP latency without spilling.
pub const J_UNROLL: usize = 4;

/// Destination columns held in registers by the min-plus / gemm
/// micro-kernels (`SIMD_WIDTH × J_UNROLL` = 16 f64 = 4 `ymm`).
pub const J_TILE: usize = SIMD_WIDTH * J_UNROLL;

/// Rows per micro-tile of the packed Gram product.
pub const MR: usize = 4;

/// Columns per micro-tile of the packed Gram product (2 `ymm` per row;
/// `MR×NR` accumulators = 8 `ymm`).
pub const NR: usize = 8;

/// Edge of the square tiles used by the blocked transpose (32×32 f64 =
/// 8 KiB: two tiles — read side + write side — fit in L1 together).
pub const TRANSPOSE_TILE: usize = 32;

/// Iterate `(start, width)` tiles covering `0..n` in `tile`-wide steps;
/// the last tile is ragged when `tile ∤ n`.
pub fn tiles(n: usize, tile: usize) -> impl Iterator<Item = (usize, usize)> {
    let tile = tile.max(1);
    (0..n).step_by(tile).map(move |s| (s, tile.min(n - s)))
}

/// Cache-blocked transpose of a row-major `r×c` buffer into a row-major
/// `c×r` buffer. Walking both sides in [`TRANSPOSE_TILE`]-square tiles
/// keeps the strided side's working set inside L1 instead of taking a
/// cache miss per element (the failure mode of the naive loop once
/// `r·8 B` exceeds a page).
pub fn transpose_into(src: &[f64], r: usize, c: usize, dst: &mut [f64]) {
    assert_eq!(src.len(), r * c, "transpose: src shape mismatch");
    assert_eq!(dst.len(), r * c, "transpose: dst shape mismatch");
    for (i0, ih) in tiles(r, TRANSPOSE_TILE) {
        for (j0, jw) in tiles(c, TRANSPOSE_TILE) {
            for i in i0..i0 + ih {
                let row = &src[i * c + j0..i * c + j0 + jw];
                for (jj, &v) in row.iter().enumerate() {
                    dst[(j0 + jj) * r + i] = v;
                }
            }
        }
    }
}

/// Pack the `w`-wide column panel `[j0, j0+w)` of a row-major `rows×c`
/// buffer into `dst` as a k-major `rows×w` panel:
/// `dst[k·w + jj] = src[k][j0+jj]`. Row fragments are contiguous, so this
/// is one `memcpy` per source row.
pub fn pack_col_panel(src: &[f64], c: usize, rows: usize, j0: usize, w: usize, dst: &mut Vec<f64>) {
    assert!(j0 + w <= c, "pack_col_panel: panel out of range");
    dst.clear();
    dst.reserve(rows * w);
    for k in 0..rows {
        dst.extend_from_slice(&src[k * c + j0..k * c + j0 + w]);
    }
}

/// Pack rows `[r0, r0+w)` of a row-major `·×c` buffer *transposed* into
/// `dst` as a k-major `c×w` panel: `dst[k·w + jj] = src[r0+jj][k]`. This
/// is the B-panel layout of the packed Gram product: the micro-kernel
/// reads one contiguous `w`-wide row per `k`.
pub fn pack_rows_transposed(src: &[f64], c: usize, r0: usize, w: usize, dst: &mut Vec<f64>) {
    assert!((r0 + w) * c <= src.len(), "pack_rows_transposed: rows out of range");
    dst.clear();
    dst.resize(c * w, 0.0);
    for jj in 0..w {
        let row = &src[(r0 + jj) * c..(r0 + jj + 1) * c];
        for (k, &v) in row.iter().enumerate() {
            dst[k * w + jj] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiles_cover_exactly() {
        for (n, t) in [(0usize, 16usize), (1, 16), (15, 16), (16, 16), (17, 16), (45, 16)] {
            let spans: Vec<(usize, usize)> = tiles(n, t).collect();
            let total: usize = spans.iter().map(|&(_, w)| w).sum();
            assert_eq!(total, n, "n={n} t={t}");
            let mut next = 0;
            for (s, w) in spans {
                assert_eq!(s, next);
                assert!(w >= 1 && w <= t);
                next = s + w;
            }
        }
    }

    #[test]
    fn transpose_matches_naive() {
        for (r, c) in [(1usize, 1usize), (3, 5), (31, 33), (32, 32), (40, 7), (65, 64)] {
            let src: Vec<f64> = (0..r * c).map(|x| x as f64).collect();
            let mut dst = vec![0.0; r * c];
            transpose_into(&src, r, c, &mut dst);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(dst[j * r + i], src[i * c + j], "r={r} c={c} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn col_panel_packs_kmajor() {
        // 3×4 source, panel cols [1,3).
        let src: Vec<f64> = (0..12).map(|x| x as f64).collect();
        let mut p = Vec::new();
        pack_col_panel(&src, 4, 3, 1, 2, &mut p);
        assert_eq!(p, vec![1.0, 2.0, 5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn rows_transposed_packs_kmajor() {
        // 4×3 source, rows [1,3) transposed: panel[k][jj] = src[1+jj][k].
        let src: Vec<f64> = (0..12).map(|x| x as f64).collect();
        let mut p = Vec::new();
        pack_rows_transposed(&src, 3, 1, 2, &mut p);
        assert_eq!(p, vec![3.0, 6.0, 4.0, 7.0, 5.0, 8.0]);
    }

    #[test]
    fn geometry_is_simd_multiple() {
        assert_eq!(J_TILE % SIMD_WIDTH, 0);
        assert_eq!(NR % SIMD_WIDTH, 0);
        assert!(MR * NR <= 4 * J_TILE, "accumulator tile must fit registers");
    }
}
