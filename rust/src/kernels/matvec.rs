//! Block mat-vec kernels for the power-iteration stage:
//! `V_I += A^{(I,J)} · Q_J` and the transposed contribution
//! `V_J += (A^{(I,J)})ᵀ · Q_I` for upper-triangular block storage.
//!
//! For the practical visualization widths (d ≤ 4) a specialized path keeps
//! the accumulators in registers across the whole `k` sweep (§Perf: ~3× on
//! the power-iteration stage at d = 2). Wider `d` (ablations, spectral
//! baselines) runs the shared register tiling from [`super::tiling`]: the
//! output row tile lives in a `[f64; J_TILE]` stack array across the whole
//! `k` sweep, so `out` is read and written once per tile instead of once
//! per `k`. Each output element is still one accumulator chain over `k`
//! (respectively `i`) ascending — deterministic per input.

use super::tiling::{self, J_TILE, MR};
use crate::linalg::Matrix;

/// `out += a · q` where `a` is `bi×bj` and `q` is `bj×d`.
pub fn gemm_acc(a: &Matrix, q: &Matrix, out: &mut Matrix) {
    assert_eq!(a.ncols(), q.nrows());
    assert_eq!(out.nrows(), a.nrows());
    assert_eq!(out.ncols(), q.ncols());
    let d = q.ncols();
    let qs = q.as_slice();
    if d <= 4 {
        for i in 0..a.nrows() {
            let arow = a.row(i);
            let mut acc = [0.0f64; 4];
            for (k, &aik) in arow.iter().enumerate() {
                let qrow = &qs[k * d..k * d + d];
                for (t, &x) in qrow.iter().enumerate() {
                    acc[t] += aik * x;
                }
            }
            for (o, &v) in out.row_mut(i).iter_mut().zip(&acc[..d]) {
                *o += v;
            }
        }
        return;
    }
    for (j0, w) in tiling::tiles(d, J_TILE) {
        if w == J_TILE {
            for i in 0..a.nrows() {
                let arow = a.row(i);
                let mut regs = [0.0f64; J_TILE];
                for (k, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let qrow: &[f64; J_TILE] =
                        qs[k * d + j0..k * d + j0 + J_TILE].try_into().unwrap();
                    for (r, &x) in regs.iter_mut().zip(qrow) {
                        *r += aik * x;
                    }
                }
                for (o, &v) in out.row_mut(i)[j0..j0 + J_TILE].iter_mut().zip(&regs) {
                    *o += v;
                }
            }
        } else {
            for i in 0..a.nrows() {
                let arow = a.row(i);
                let mut regs = [0.0f64; J_TILE];
                for (k, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let qrow = &qs[k * d + j0..k * d + j0 + w];
                    for (r, &x) in regs[..w].iter_mut().zip(qrow) {
                        *r += aik * x;
                    }
                }
                for (o, &v) in out.row_mut(i)[j0..j0 + w].iter_mut().zip(&regs[..w]) {
                    *o += v;
                }
            }
        }
    }
}

/// `out += aᵀ · q` where `a` is `bi×bj`, `q` is `bi×d`, `out` is `bj×d` —
/// walks `a` row-wise so no explicit transpose is materialized. Small-d
/// path caches `q`'s row in registers per `i` sweep (§Perf, as
/// [`gemm_acc`]). The wide-d path register-blocks [`MR`] output rows ×
/// [`J_TILE`] columns and accumulates over the `i` sweep, reading `a`'s
/// row fragments contiguously.
pub fn gemm_t_acc(a: &Matrix, q: &Matrix, out: &mut Matrix) {
    assert_eq!(a.nrows(), q.nrows());
    assert_eq!(out.nrows(), a.ncols());
    assert_eq!(out.ncols(), q.ncols());
    let d = q.ncols();
    if d <= 4 {
        let os = out.as_mut_slice();
        for i in 0..a.nrows() {
            let arow = a.row(i);
            let mut qr = [0.0f64; 4];
            qr[..d].copy_from_slice(q.row(i));
            for (k, &aik) in arow.iter().enumerate() {
                let orow = &mut os[k * d..k * d + d];
                for (t, o) in orow.iter_mut().enumerate() {
                    *o += aik * qr[t];
                }
            }
        }
        return;
    }
    let (bi, bj) = (a.nrows(), a.ncols());
    for (j0, w) in tiling::tiles(d, J_TILE) {
        for (k0, kh) in tiling::tiles(bj, MR) {
            // MR output rows × one column tile accumulated over the whole
            // `i` sweep; `a`'s per-row fragment a[i][k0..k0+kh] is
            // contiguous, so no strided gathers despite the transpose.
            let mut regs = [[0.0f64; J_TILE]; MR];
            for i in 0..bi {
                let afrag = &a.row(i)[k0..k0 + kh];
                let qrow = &q.row(i)[j0..j0 + w];
                for (km, &aik) in afrag.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    for (r, &x) in regs[km][..w].iter_mut().zip(qrow) {
                        *r += aik * x;
                    }
                }
            }
            for (km, reg) in regs.iter().enumerate().take(kh) {
                let orow = &mut out.row_mut(k0 + km)[j0..j0 + w];
                for (o, &v) in orow.iter_mut().zip(&reg[..w]) {
                    *o += v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut a = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                a[(i, j)] = rng.gaussian();
            }
        }
        a
    }

    #[test]
    fn acc_matches_matmul() {
        let a = random(7, 5, 1);
        let q = random(5, 3, 2);
        let mut out = Matrix::zeros(7, 3);
        gemm_acc(&a, &q, &mut out);
        assert!(out.max_abs_diff(&a.matmul(&q)) < 1e-12);
    }

    #[test]
    fn acc_matches_matmul_wide() {
        // Exercises the tiled d > 4 path across tile boundaries.
        for d in [5usize, J_TILE - 1, J_TILE, J_TILE + 1, 2 * J_TILE + 3] {
            let a = random(9, 11, d as u64);
            let q = random(11, d, d as u64 + 7);
            let mut out = Matrix::zeros(9, d);
            gemm_acc(&a, &q, &mut out);
            assert!(out.max_abs_diff(&a.matmul(&q)) < 1e-10, "d={d}");
        }
    }

    #[test]
    fn accumulates() {
        let a = random(4, 4, 3);
        let q = random(4, 2, 4);
        let mut out = Matrix::full(4, 2, 1.0);
        gemm_acc(&a, &q, &mut out);
        let mut want = a.matmul(&q);
        for x in want.as_mut_slice() {
            *x += 1.0;
        }
        assert!(out.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn transposed_matches_explicit() {
        let a = random(6, 4, 5);
        let q = random(6, 3, 6);
        let mut out = Matrix::zeros(4, 3);
        gemm_t_acc(&a, &q, &mut out);
        assert!(out.max_abs_diff(&a.transpose().matmul(&q)) < 1e-12);
    }

    #[test]
    fn transposed_matches_explicit_wide() {
        for d in [5usize, J_TILE, J_TILE + 1] {
            for bj in [MR - 1, MR, MR + 1, 2 * MR + 1] {
                let a = random(7, bj, (d + bj) as u64);
                let q = random(7, d, (d + bj) as u64 + 9);
                let mut out = Matrix::zeros(bj, d);
                gemm_t_acc(&a, &q, &mut out);
                assert!(
                    out.max_abs_diff(&a.transpose().matmul(&q)) < 1e-10,
                    "d={d} bj={bj}"
                );
            }
        }
    }
}
