//! Block mat-vec kernels for the power-iteration stage:
//! `V_I += A^{(I,J)} · Q_J` and the transposed contribution
//! `V_J += (A^{(I,J)})ᵀ · Q_I` for upper-triangular block storage.

use crate::linalg::Matrix;

/// `out += a · q` where `a` is `bi×bj` and `q` is `bj×d`.
///
/// For the practical visualization widths (d ≤ 4) a specialized path keeps
/// the accumulators in registers across the whole `k` sweep instead of
/// re-walking `out`'s row per `k` (§Perf: ~3× on the power-iteration
/// stage at d = 2).
pub fn gemm_acc(a: &Matrix, q: &Matrix, out: &mut Matrix) {
    assert_eq!(a.ncols(), q.nrows());
    assert_eq!(out.nrows(), a.nrows());
    assert_eq!(out.ncols(), q.ncols());
    let d = q.ncols();
    if d <= 4 {
        let qs = q.as_slice();
        for i in 0..a.nrows() {
            let arow = a.row(i);
            let mut acc = [0.0f64; 4];
            for (k, &aik) in arow.iter().enumerate() {
                let qrow = &qs[k * d..k * d + d];
                for (t, &x) in qrow.iter().enumerate() {
                    acc[t] += aik * x;
                }
            }
            for (o, &v) in out.row_mut(i).iter_mut().zip(&acc[..d]) {
                *o += v;
            }
        }
        return;
    }
    for i in 0..a.nrows() {
        let arow = a.row(i);
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let qrow = q.row(k);
            let orow = out.row_mut(i);
            for (o, &x) in orow.iter_mut().zip(qrow) {
                *o += aik * x;
            }
        }
    }
}

/// `out += aᵀ · q` where `a` is `bi×bj`, `q` is `bi×d`, `out` is `bj×d` —
/// walks `a` row-wise so no explicit transpose is materialized. Small-d
/// path caches `q`'s row in registers per `i` sweep (§Perf, as
/// [`gemm_acc`]).
pub fn gemm_t_acc(a: &Matrix, q: &Matrix, out: &mut Matrix) {
    assert_eq!(a.nrows(), q.nrows());
    assert_eq!(out.nrows(), a.ncols());
    assert_eq!(out.ncols(), q.ncols());
    let d = q.ncols();
    if d <= 4 {
        let os = out.as_mut_slice();
        for i in 0..a.nrows() {
            let arow = a.row(i);
            let mut qr = [0.0f64; 4];
            qr[..d].copy_from_slice(q.row(i));
            for (k, &aik) in arow.iter().enumerate() {
                let orow = &mut os[k * d..k * d + d];
                for (t, o) in orow.iter_mut().enumerate() {
                    *o += aik * qr[t];
                }
            }
        }
        return;
    }
    for i in 0..a.nrows() {
        let arow = a.row(i);
        let qrow = q.row(i);
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let orow = out.row_mut(k);
            for (o, &x) in orow.iter_mut().zip(qrow) {
                *o += aik * x;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut a = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                a[(i, j)] = rng.gaussian();
            }
        }
        a
    }

    #[test]
    fn acc_matches_matmul() {
        let a = random(7, 5, 1);
        let q = random(5, 3, 2);
        let mut out = Matrix::zeros(7, 3);
        gemm_acc(&a, &q, &mut out);
        assert!(out.max_abs_diff(&a.matmul(&q)) < 1e-12);
    }

    #[test]
    fn accumulates() {
        let a = random(4, 4, 3);
        let q = random(4, 2, 4);
        let mut out = Matrix::full(4, 2, 1.0);
        gemm_acc(&a, &q, &mut out);
        let mut want = a.matmul(&q);
        for x in want.as_mut_slice() {
            *x += 1.0;
        }
        assert!(out.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn transposed_matches_explicit() {
        let a = random(6, 4, 5);
        let q = random(6, 3, 6);
        let mut out = Matrix::zeros(4, 3);
        gemm_t_acc(&a, &q, &mut out);
        assert!(out.max_abs_diff(&a.transpose().matmul(&q)) < 1e-12);
    }
}
