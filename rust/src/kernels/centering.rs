//! Double-centering block kernels (paper §III-C).
//!
//! The feature matrix is centered directly (not via `H·A·H`): per-block
//! column sums are reduced to global column means `μ` and the grand mean
//! `μ̂`; each block entry is then updated as
//! `a ← −½ (a − μ_col − μ_row + μ̂)` — including the −½ factor from
//! classical MDS so the centered matrix is ready for eigendecomposition.

use crate::linalg::Matrix;

/// Column sums of a block (the paper's per-block `flatMap` step).
pub fn col_sums(block: &Matrix) -> Vec<f64> {
    let mut s = vec![0.0; block.ncols()];
    for i in 0..block.nrows() {
        for (acc, &x) in s.iter_mut().zip(block.row(i)) {
            *acc += x;
        }
    }
    s
}

/// Row sums of a block (needed for the transposed contribution of
/// off-diagonal blocks in the upper-triangular layout).
pub fn row_sums(block: &Matrix) -> Vec<f64> {
    (0..block.nrows()).map(|i| block.row(i).iter().sum()).collect()
}

/// Apply double centering to one block given the broadcast means.
///
/// `mu_rows[i]` is the column-mean vector entry for the block's global row
/// `i`, `mu_cols[j]` likewise for columns, `grand` is μ̂. Applies the MDS
/// `-1/2` scaling.
pub fn center_block(block: &mut Matrix, mu_rows: &[f64], mu_cols: &[f64], grand: f64) {
    assert_eq!(mu_rows.len(), block.nrows());
    assert_eq!(mu_cols.len(), block.ncols());
    for i in 0..block.nrows() {
        let mr = mu_rows[i];
        for (x, &mc) in block.row_mut(i).iter_mut().zip(mu_cols) {
            *x = -0.5 * (*x - mr - mc + grand);
        }
    }
}

/// Reference implementation on a full matrix: `-½ · H A H` with
/// `H = I - (1/n)·11ᵀ`. Used by tests to validate the blocked path.
pub fn center_full_reference(a: &Matrix) -> Matrix {
    let n = a.nrows();
    let mut h = Matrix::full(n, n, -1.0 / n as f64);
    for i in 0..n {
        h[(i, i)] += 1.0;
    }
    let mut c = h.matmul(a).matmul(&h);
    c.scale(-0.5);
    c
}

/// Direct full-matrix double centering (the algorithm the blocks implement),
/// exposed for the single-node baseline.
pub fn center_full_direct(a: &mut Matrix) {
    let n = a.nrows() as f64;
    let mut mu = vec![0.0; a.ncols()];
    for i in 0..a.nrows() {
        for (m, &x) in mu.iter_mut().zip(a.row(i)) {
            *m += x;
        }
    }
    for m in &mut mu {
        *m /= n;
    }
    let grand = mu.iter().sum::<f64>() / mu.len() as f64;
    for i in 0..a.nrows() {
        let mr = mu[i];
        for (x, &mc) in a.row_mut(i).iter_mut().zip(&mu) {
            *x = -0.5 * (*x - mr - mc + grand);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_symmetric(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let x = rng.range(0.0, 10.0);
                a[(i, j)] = x;
                a[(j, i)] = x;
            }
        }
        a
    }

    #[test]
    fn direct_matches_hah() {
        for seed in 0..4 {
            let a = random_symmetric(12, seed);
            let want = center_full_reference(&a);
            let mut got = a.clone();
            center_full_direct(&mut got);
            assert!(got.max_abs_diff(&want) < 1e-10, "seed={seed}");
        }
    }

    #[test]
    fn centered_rows_cols_zero_mean() {
        let a = random_symmetric(10, 5);
        let mut c = a.clone();
        center_full_direct(&mut c);
        for i in 0..10 {
            let rm: f64 = c.row(i).iter().sum::<f64>() / 10.0;
            assert!(rm.abs() < 1e-10, "row {i} mean {rm}");
            let cm: f64 = c.col(i).iter().sum::<f64>() / 10.0;
            assert!(cm.abs() < 1e-10, "col {i} mean {cm}");
        }
    }

    #[test]
    fn block_path_matches_direct() {
        let a = random_symmetric(8, 6);
        // Global means.
        let n = 8.0;
        let mut mu = vec![0.0; 8];
        for j in 0..8 {
            mu[j] = a.col(j).iter().sum::<f64>() / n;
        }
        let grand = a.grand_mean();
        // Blocked apply with b = 4 over all four blocks.
        let mut blocked = a.clone();
        for bi in 0..2 {
            for bj in 0..2 {
                let mut blk = blocked.slice(bi * 4, bi * 4 + 4, bj * 4, bj * 4 + 4);
                center_block(&mut blk, &mu[bi * 4..bi * 4 + 4], &mu[bj * 4..bj * 4 + 4], grand);
                blocked.paste(bi * 4, bj * 4, &blk);
            }
        }
        let mut direct = a.clone();
        center_full_direct(&mut direct);
        assert!(blocked.max_abs_diff(&direct) < 1e-12);
    }

    #[test]
    fn sums_helpers() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(col_sums(&m), vec![4.0, 6.0]);
        assert_eq!(row_sums(&m), vec![3.0, 7.0]);
    }
}
