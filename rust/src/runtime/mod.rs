//! PJRT artifact runtime — the AOT bridge.
//!
//! `make artifacts` runs `python/compile/aot.py`, which lowers each L2 JAX
//! block op (backed by the L1 Pallas kernels) to **HLO text** and writes
//! `artifacts/manifest.json` describing every (op, shape) artifact. The
//! [`pjrt`] implementation loads those artifacts through the `xla` crate's
//! PJRT CPU client: compile once per (op, shape), cache the executable,
//! and execute from the L3 hot path. Python never runs at request time.
//!
//! The bridge is gated behind the **`pjrt` cargo feature** (off by
//! default): the `xla` crate cannot be fetched in the offline build
//! environment, so the default build substitutes [`stub`], whose
//! `PjrtEngine::load` always errors — [`crate::backend::Backend`] then
//! falls back to the native kernels and `cargo build/test` stay green
//! with no network access. Enabling the feature additionally requires
//! adding the `xla` dependency to Cargo.toml:
//!
//! ```toml
//! [dependencies]
//! xla = { version = "0.1", optional = true }
//! ```
//!
//! HLO *text* (not serialized `HloModuleProto`) is the interchange format:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).
//!
//! Shapes are static in HLO, so the artifact set is generated for the
//! block sizes listed in `aot.py`. Calls with other shapes (e.g. the
//! ragged last block when `b ∤ n`) return `Err`, and [`crate::backend`]
//! transparently falls back to the native kernel — the hot path (full
//! blocks) stays on PJRT.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{ArtifactEntry, PjrtEngine};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtEngine;
