//! PJRT artifact runtime — the AOT bridge.
//!
//! `make artifacts` runs `python/compile/aot.py`, which lowers each L2 JAX
//! block op (backed by the L1 Pallas kernels) to **HLO text** and writes
//! `artifacts/manifest.json` describing every (op, shape) artifact. The
//! `pjrt` implementation loads those artifacts through the `xla` crate's
//! PJRT CPU client: compile once per (op, shape), cache the executable,
//! and execute from the L3 hot path. Python never runs at request time.
//!
//! The bridge is gated behind the **`pjrt` cargo feature** (off by
//! default): the `xla` crate cannot be fetched in the offline build
//! environment, so the default build substitutes `stub`, whose
//! `PjrtEngine::load` always errors — [`crate::backend::Backend`] then
//! falls back to the native kernels and `cargo build/test` stay green
//! with no network access. Enabling the feature additionally requires
//! adding the `xla` dependency to Cargo.toml:
//!
//! ```toml
//! [dependencies]
//! xla = { version = "0.1", optional = true }
//! ```
//!
//! HLO *text* (not serialized `HloModuleProto`) is the interchange format:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).
//!
//! # Shape-polymorphic padded execution
//!
//! Shapes are static in HLO, so the artifact set is generated for the
//! block sizes listed in `aot.py`. The runtime is nevertheless
//! **shape-polymorphic**: a ragged `r×c` call (the last row/column of
//! blocks whenever `b ∤ n`) is served by padding the operands up to the
//! nearest manifest artifact with the op's *neutral element*, executing
//! the full-shape executable, and slicing the `r×c` result back out. The
//! artifact choice for a call is a `ShapePlan`, cached by
//! `(op, rows, cols, extra-dim)` so the planning cost is paid once per
//! distinct shape; each op derives its per-operand padding from the
//! chosen artifact.
//!
//! Neutral elements per op (exactness argument in parentheses):
//!
//! | op            | padding                                  | why exact                                  |
//! |---------------|------------------------------------------|--------------------------------------------|
//! | `minplus`     | `+∞` rows/cols on both operands          | `min(x, ∞ + y) = x`; padded k contribute ∞ |
//! | `fw`          | `+∞` rows/cols                           | padded pivots relax nothing (`∞ + w = ∞`)  |
//! | `center`      | zero rows/cols, zero-extended mean vecs  | element-wise op; padded entries sliced off |
//! | `dist`        | zero rows (points) *and* zero dims       | `(0−0)² = 0` adds nothing to any distance  |
//! | `gemm`/`gemmt`| zero rows/cols (as `pad_cols` always did)| `0·x` contributes nothing to any dot       |
//!
//! # Fallback policy: counted miss vs propagated error
//!
//! Runtime entry points return [`RtError`] on failure, and the two
//! variants are handled very differently by [`crate::backend::Backend`]:
//!
//! * [`RtError::ShapeMiss`] — no artifact (even padded) covers the shape,
//!   e.g. a block larger than the largest lowered `b`, or a point
//!   dimensionality above every `dist` artifact. The backend falls back to
//!   the native kernel **and the miss is counted** in the engine's
//!   [`crate::engine::metrics::OffloadStats`], surfaced as offload-coverage
//!   fractions by `isospark info` and after `isospark run`.
//! * [`RtError::Hard`] — a real failure (manifest/HLO parse error, compile
//!   failure, element-count mismatch in a result). These **propagate** (the
//!   backend panics with context, which the stage executor forwards to the
//!   driver with the task index) instead of masquerading as ragged-shape
//!   fallbacks — a corrupted artifact must never silently degrade the run
//!   to the native kernels.
//!
//! The offline `stub` mirrors the same surface: every call records a
//! counted miss, so fallback accounting is testable without the `xla`
//! dependency.

use std::fmt;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{ArtifactEntry, PjrtEngine};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtEngine;

/// Why a runtime call could not be served. See the module docs for the
/// fallback policy attached to each variant.
#[derive(Debug)]
pub enum RtError {
    /// No artifact — not even a larger one reachable by neutral-element
    /// padding — covers the requested shape. Callers fall back to the
    /// native kernel; the engine records the miss in its offload counters.
    ShapeMiss {
        /// Op name (`minplus`, `dist`, …).
        op: &'static str,
        /// Human-readable description of the unserved shape.
        detail: String,
    },
    /// Real failure: I/O, HLO parse, compile, execution, or a result that
    /// does not match the planned shape. Must propagate, never be
    /// swallowed into a native-kernel fallback.
    Hard(anyhow::Error),
}

impl RtError {
    /// Build a shape-miss for `op`.
    pub fn shape_miss(op: &'static str, detail: impl Into<String>) -> Self {
        RtError::ShapeMiss { op, detail: detail.into() }
    }

    /// Wrap a real failure.
    pub fn hard(err: impl Into<anyhow::Error>) -> Self {
        RtError::Hard(err.into())
    }

    /// True when the error is a fallback-eligible shape miss.
    pub fn is_shape_miss(&self) -> bool {
        matches!(self, RtError::ShapeMiss { .. })
    }
}

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RtError::ShapeMiss { op, detail } => {
                write!(f, "no artifact serves {op}: {detail}")
            }
            RtError::Hard(e) => write!(f, "runtime failure: {e:#}"),
        }
    }
}

impl std::error::Error for RtError {}

/// Result alias for runtime entry points.
pub type RtResult<T> = Result<T, RtError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_miss_classified_and_displayed() {
        let e = RtError::shape_miss("minplus", "b=200 exceeds largest artifact b=128");
        assert!(e.is_shape_miss());
        let msg = e.to_string();
        assert!(msg.contains("minplus"), "{msg}");
        assert!(msg.contains("b=200"), "{msg}");
    }

    #[test]
    fn hard_error_not_a_miss() {
        let e = RtError::hard(anyhow::anyhow!("compile exploded"));
        assert!(!e.is_shape_miss());
        assert!(e.to_string().contains("compile exploded"));
        // Converts into anyhow for callers that bubble it further.
        let any: anyhow::Error = e.into();
        assert!(format!("{any:#}").contains("compile exploded"));
    }
}
