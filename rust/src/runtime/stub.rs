//! Stub runtime used when the crate is built without the `pjrt` feature.
//!
//! Mirrors the public surface of the real [`super`] PJRT engine so that
//! callers (the CLI `info` command, benches, the equivalence test suite)
//! compile unchanged; every entry point reports that artifacts are
//! unavailable, and [`crate::backend::Backend`] falls back to the native
//! kernels. This keeps `cargo build && cargo test` fully offline — the
//! `xla` crate is only required when the feature is enabled.

use crate::linalg::Matrix;
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

const DISABLED: &str =
    "isospark was built without the `pjrt` feature — AOT artifacts are unavailable \
     (rebuild with `--features pjrt` after running `make artifacts`)";

/// Placeholder for the PJRT executor; `load` always fails.
#[derive(Debug)]
pub struct PjrtEngine {
    dir: PathBuf,
}

impl PjrtEngine {
    /// Always errors: the PJRT bridge is compiled out.
    pub fn load(dir: &Path) -> Result<Self> {
        let _ = dir;
        bail!(DISABLED)
    }

    /// Artifact directory this engine would serve.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Available artifacts (none).
    pub fn inventory(&self) -> Vec<String> {
        Vec::new()
    }

    /// Pairwise-distance block — unavailable.
    pub fn dist_block(&self, _xi: &Matrix, _xj: &Matrix) -> Result<Matrix> {
        bail!(DISABLED)
    }

    /// Min-plus product — unavailable.
    pub fn minplus(&self, _a: &Matrix, _b: &Matrix) -> Result<Matrix> {
        bail!(DISABLED)
    }

    /// In-block Floyd–Warshall — unavailable.
    pub fn floyd_warshall(&self, _g: &Matrix) -> Result<Matrix> {
        bail!(DISABLED)
    }

    /// Double-centering application — unavailable.
    pub fn center_block(
        &self,
        _block: &Matrix,
        _mu_r: &[f64],
        _mu_c: &[f64],
        _grand: f64,
    ) -> Result<Matrix> {
        bail!(DISABLED)
    }

    /// Power-iteration block product — unavailable.
    pub fn gemm(&self, _a: &Matrix, _q: &Matrix) -> Result<Matrix> {
        bail!(DISABLED)
    }

    /// Transposed block product — unavailable.
    pub fn gemm_t(&self, _a: &Matrix, _q: &Matrix) -> Result<Matrix> {
        bail!(DISABLED)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_always_errors_with_feature_hint() {
        let err = PjrtEngine::load(Path::new("artifacts")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
