//! Stub runtime used when the crate is built without the `pjrt` feature.
//!
//! Mirrors the public surface of the real [`super`] PJRT engine — including
//! the shape-plan/fallback accounting contract — so that callers (the CLI
//! `info` command, benches, the equivalence test suite) compile unchanged.
//! `load` always errors (there are no executables to run); the
//! [`PjrtEngine::disconnected`] constructor builds an artifact-less engine
//! whose every entry point records a counted **shape miss** in its
//! [`OffloadStats`] and returns [`RtError::ShapeMiss`], so
//! [`crate::backend::Backend`] falls back to the native kernels exactly as
//! it would for an unserved shape — and the fallback counters are testable
//! fully offline. This keeps `cargo build && cargo test` free of the `xla`
//! dependency.

use super::{RtError, RtResult};
use crate::engine::metrics::{OffloadOp, OffloadStats};
use crate::linalg::Matrix;
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

const DISABLED: &str =
    "isospark was built without the `pjrt` feature — AOT artifacts are unavailable \
     (rebuild with `--features pjrt` after running `make artifacts`)";

/// Placeholder for the PJRT executor; `load` always fails.
#[derive(Debug)]
pub struct PjrtEngine {
    dir: PathBuf,
    stats: OffloadStats,
}

impl PjrtEngine {
    /// Always errors: the PJRT bridge is compiled out.
    pub fn load(dir: &Path) -> Result<Self> {
        let _ = dir;
        bail!(DISABLED)
    }

    /// An engine with no artifacts at all: every call is a counted shape
    /// miss. Lets the fallback-accounting path be exercised (and tested)
    /// without the `xla` dependency.
    pub fn disconnected(dir: &Path) -> Self {
        Self { dir: dir.to_path_buf(), stats: OffloadStats::new() }
    }

    /// Artifact directory this engine would serve.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Offload counters (all recorded calls are misses here).
    pub fn stats(&self) -> &OffloadStats {
        &self.stats
    }

    /// Available artifacts (none).
    pub fn inventory(&self) -> Vec<String> {
        Vec::new()
    }

    /// Every stub shape plan resolves to a counted miss.
    fn miss(&self, op: OffloadOp) -> RtError {
        self.stats.record_miss(op);
        RtError::shape_miss(op.name(), DISABLED)
    }

    /// Pairwise-distance block — unavailable (counted miss).
    pub fn dist_block(&self, _xi: &Matrix, _xj: &Matrix) -> RtResult<Matrix> {
        Err(self.miss(OffloadOp::Dist))
    }

    /// Min-plus product — unavailable (counted miss).
    pub fn minplus(&self, _a: &Matrix, _b: &Matrix) -> RtResult<Matrix> {
        Err(self.miss(OffloadOp::Minplus))
    }

    /// In-block Floyd–Warshall — unavailable (counted miss).
    pub fn floyd_warshall(&self, _g: &Matrix) -> RtResult<Matrix> {
        Err(self.miss(OffloadOp::Fw))
    }

    /// Double-centering application — unavailable (counted miss).
    pub fn center_block(
        &self,
        _block: &Matrix,
        _mu_r: &[f64],
        _mu_c: &[f64],
        _grand: f64,
    ) -> RtResult<Matrix> {
        Err(self.miss(OffloadOp::Center))
    }

    /// Power-iteration block product — unavailable (counted miss).
    pub fn gemm(&self, _a: &Matrix, _q: &Matrix) -> RtResult<Matrix> {
        Err(self.miss(OffloadOp::Gemm))
    }

    /// Transposed block product — unavailable (counted miss).
    pub fn gemm_t(&self, _a: &Matrix, _q: &Matrix) -> RtResult<Matrix> {
        Err(self.miss(OffloadOp::Gemmt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_always_errors_with_feature_hint() {
        let err = PjrtEngine::load(Path::new("artifacts")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn disconnected_records_a_miss_per_call() {
        let rt = PjrtEngine::disconnected(Path::new("artifacts"));
        let m = Matrix::zeros(3, 3);
        assert!(rt.minplus(&m, &m).unwrap_err().is_shape_miss());
        assert!(rt.minplus(&m, &m).unwrap_err().is_shape_miss());
        assert!(rt.floyd_warshall(&m).unwrap_err().is_shape_miss());
        let snap = rt.stats().op_snapshot(OffloadOp::Minplus);
        assert_eq!((snap.exact, snap.padded, snap.missed), (0, 0, 2));
        assert_eq!(rt.stats().op_snapshot(OffloadOp::Fw).missed, 1);
        assert_eq!(rt.stats().total_missed(), 3);
        assert!(rt.inventory().is_empty());
        assert_eq!(rt.dir(), Path::new("artifacts"));
    }
}
