//! The real PJRT executor (enabled by the `pjrt` cargo feature): loads
//! `manifest.json`, compiles HLO-text artifacts once per (op, shape), and
//! executes them through the `xla` crate's PJRT CPU client. See the parent
//! module docs for the artifact pipeline, the padded-execution scheme and
//! the offline stub.

use super::{RtError, RtResult};
use crate::engine::metrics::{OffloadOp, OffloadStats};
use crate::linalg::Matrix;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// One artifact from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub op: String,
    /// Shape parameters, op-specific: `minplus`/`fw`/`center` use `b`;
    /// `dist` uses `b` and `dim`; `gemm`/`gemmt` use `b` and `d`.
    pub b: usize,
    pub dim: usize,
    pub d: usize,
    pub file: PathBuf,
}

/// Padding fill each op's artifacts tolerate — the neutral element of the
/// op (see the parent module's table). The manifest may carry the same
/// policy (`pad` field, manifest version ≥ 2); when it does, the two must
/// agree or [`PjrtEngine::load`] refuses the artifact set.
fn pad_fill(op: OffloadOp) -> f64 {
    match op {
        OffloadOp::Minplus | OffloadOp::Fw => f64::INFINITY,
        OffloadOp::Dist | OffloadOp::Center | OffloadOp::Gemm | OffloadOp::Gemmt => 0.0,
    }
}

/// Manifest spelling of a fill value.
fn fill_name(fill: f64) -> &'static str {
    if fill.is_infinite() {
        "+inf"
    } else {
        "zero"
    }
}

fn op_by_name(name: &str) -> Option<OffloadOp> {
    OffloadOp::ALL.iter().copied().find(|op| op.name() == name)
}

/// Resolved execution plan for one `(op, shape)` call: the index of the
/// artifact that serves it (operands pad up to that artifact's shape and
/// the result slices back — each op computes its own per-operand padding
/// from the entry, since e.g. a `5×7` dist call needs row padding even
/// when an exact `b = 7` artifact exists). Cached by the requested shape
/// so the manifest scan happens once per distinct shape.
#[derive(Clone, Copy, Debug)]
struct ShapePlan {
    /// Index into [`PjrtEngine::entries`].
    entry: usize,
}

/// Executable slot for one artifact: per-key locking so two workers
/// first-touching the *same* artifact compile it exactly once, while
/// different artifacts (and executions of already-compiled ones) proceed
/// without queueing behind the compile.
type ExeCell = Arc<Mutex<Option<Arc<xla::PjRtLoadedExecutable>>>>;

/// Lazily-compiling PJRT executor over an artifact directory.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    entries: Vec<ArtifactEntry>,
    cache: Mutex<HashMap<String, ExeCell>>,
    plans: Mutex<HashMap<(&'static str, usize, usize, usize), Option<ShapePlan>>>,
    /// Serializes every `xla_extension` FFI call (HLO parse, computation
    /// construction, compile, execute): the multi-core stage executor
    /// calls the backend from many worker threads, and the bindings make
    /// no documented thread-safety promise, so we take the conservative
    /// route — one in-flight xla call at a time. Held only around the FFI
    /// calls themselves, never across cache/plan bookkeeping or operand
    /// padding, so block ops still overlap with the native-kernel work of
    /// other workers; the per-artifact cell in `cache` additionally makes
    /// racing first touches of one artifact compile it exactly once.
    exec: Mutex<()>,
    stats: OffloadStats,
    dir: PathBuf,
}

// SAFETY: every use of shared xla state after construction — the client
// and the loaded executables (HLO parse, computation construction,
// compile, execute, result fetch) — happens with `exec` held, so at most
// one thread touches them at any moment. `Literal` values are standalone
// host buffers built per call (as before this module was made
// shape-polymorphic); the remaining fields are plain data behind their
// own locks or atomics.
unsafe impl Send for PjrtEngine {}
unsafe impl Sync for PjrtEngine {}

impl PjrtEngine {
    /// Load `dir/manifest.json` and create the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?} — run `make artifacts` first"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let ops = json
            .get("ops")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing `ops` array"))?;
        let mut entries = Vec::new();
        for o in ops {
            let get = |k: &str| o.get(k).and_then(Json::as_usize).unwrap_or(0);
            let op = o
                .get("op")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("op entry missing name"))?
                .to_string();
            // Manifest pad metadata (version ≥ 2): the AOT side declares
            // which fill each artifact tolerates; a disagreement with the
            // runtime's neutral-element table is a hard config error, not
            // something to paper over with native fallbacks.
            if let (Some(declared), Some(known)) =
                (o.get("pad").and_then(Json::as_str), op_by_name(&op))
            {
                let expected = fill_name(pad_fill(known));
                if declared != expected {
                    bail!(
                        "manifest pad policy mismatch for {op}: artifact declares \
                         {declared:?}, runtime pads with {expected:?}"
                    );
                }
            }
            entries.push(ArtifactEntry {
                op,
                b: get("b"),
                dim: get("dim"),
                d: get("d"),
                file: dir.join(
                    o.get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("op entry missing file"))?,
                ),
            });
        }
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self {
            client,
            entries,
            cache: Mutex::new(HashMap::new()),
            plans: Mutex::new(HashMap::new()),
            exec: Mutex::new(()),
            stats: OffloadStats::new(),
            dir: dir.to_path_buf(),
        })
    }

    /// Artifact directory this engine serves.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Offload counters accumulated over this engine's lifetime.
    pub fn stats(&self) -> &OffloadStats {
        &self.stats
    }

    /// Available (op, b, dim, d) tuples — for `isospark info`.
    pub fn inventory(&self) -> Vec<String> {
        self.entries
            .iter()
            .map(|e| format!("{} b={} dim={} d={} ({})", e.op, e.b, e.dim, e.d, e.file.display()))
            .collect()
    }

    /// Pick the smallest artifact of `op` whose every static dimension
    /// covers the requested one, caching the decision per requested shape.
    /// A `None` in the cache is a remembered miss: re-planning the same
    /// unserved shape still records one fallback per call, but never
    /// re-scans the manifest.
    fn plan(
        &self,
        op: OffloadOp,
        need_b: usize,
        need_dim: usize,
        need_d: usize,
    ) -> RtResult<&ArtifactEntry> {
        let key = (op.name(), need_b, need_dim, need_d);
        let cached = self.plans.lock().unwrap().get(&key).copied();
        let plan = match cached {
            Some(p) => p,
            None => {
                let found = self
                    .entries
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| {
                        e.op == op.name() && e.b >= need_b && e.dim >= need_dim && e.d >= need_d
                    })
                    .min_by_key(|(_, e)| (e.b, e.dim, e.d))
                    .map(|(i, _)| ShapePlan { entry: i });
                self.plans.lock().unwrap().insert(key, found);
                found
            }
        };
        match plan {
            Some(p) => Ok(&self.entries[p.entry]),
            None => {
                self.stats.record_miss(op);
                Err(RtError::shape_miss(
                    op.name(),
                    format!("no artifact covers b>={need_b} dim>={need_dim} d>={need_d}"),
                ))
            }
        }
    }

    /// Compile-once executable lookup. The per-artifact cell lock makes
    /// concurrent first touches of one artifact compile it exactly once
    /// (the old check-drop-insert pattern compiled per racing worker);
    /// every xla FFI call (parse + compile) runs under `exec`, and a
    /// cache hit touches no xla state at all.
    fn executable(&self, e: &ArtifactEntry) -> RtResult<Arc<xla::PjRtLoadedExecutable>> {
        let key = format!("{}:{}:{}:{}", e.op, e.b, e.dim, e.d);
        let cell = Arc::clone(self.cache.lock().unwrap().entry(key).or_default());
        let mut slot = cell.lock().unwrap();
        if let Some(exe) = slot.as_ref() {
            return Ok(Arc::clone(exe));
        }
        let exe = {
            let _xla = self.exec.lock().unwrap();
            let proto = xla::HloModuleProto::from_text_file(&e.file)
                .map_err(|err| RtError::hard(anyhow!("parse HLO text {:?}: {err}", e.file)))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Arc::new(
                self.client
                    .compile(&comp)
                    .map_err(|err| RtError::hard(anyhow!("compile {}: {err}", e.op)))?,
            )
        };
        *slot = Some(Arc::clone(&exe));
        Ok(exe)
    }

    fn lit(m: &Matrix) -> RtResult<xla::Literal> {
        xla::Literal::vec1(m.as_slice())
            .reshape(&[m.nrows() as i64, m.ncols() as i64])
            .map_err(|err| RtError::hard(anyhow!("literal reshape: {err}")))
    }

    fn lit_vec(v: &[f64]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    /// Pad `m` to `rows × cols` with `fill` (no-op copy at exact shape).
    fn pad_matrix(m: &Matrix, rows: usize, cols: usize, fill: f64) -> Matrix {
        if m.nrows() == rows && m.ncols() == cols {
            return m.clone();
        }
        let mut p = Matrix::full(rows, cols, fill);
        p.paste(0, 0, m);
        p
    }

    /// Zero-extend a mean vector to the artifact length.
    fn pad_vec(v: &[f64], len: usize) -> Vec<f64> {
        let mut out = vec![0.0; len];
        out[..v.len()].copy_from_slice(v);
        out
    }

    /// Execute one artifact; `rows × cols` is the artifact's full output
    /// shape. Execution errors and result-shape mismatches are hard.
    fn run1(
        &self,
        e: &ArtifactEntry,
        args: &[xla::Literal],
        rows: usize,
        cols: usize,
    ) -> RtResult<Matrix> {
        let exe = self.executable(e)?;
        let result = {
            let _serialized = self.exec.lock().unwrap();
            exe.execute::<xla::Literal>(args)
                .map_err(|err| RtError::hard(anyhow!("execute {}: {err}", e.op)))?[0][0]
                .to_literal_sync()
                .map_err(|err| RtError::hard(anyhow!("fetch {} result: {err}", e.op)))?
        };
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|err| RtError::hard(anyhow!("untuple {} result: {err}", e.op)))?;
        let data = out
            .to_vec::<f64>()
            .map_err(|err| RtError::hard(anyhow!("read {} result: {err}", e.op)))?;
        if data.len() != rows * cols {
            return Err(RtError::hard(anyhow!(
                "artifact {} returned {} elements, expected {}",
                e.op,
                data.len(),
                rows * cols
            )));
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }

    fn record(&self, op: OffloadOp, padded: bool) {
        if padded {
            self.stats.record_padded(op);
        } else {
            self.stats.record_exact(op);
        }
    }

    /// Pairwise-distance block via the Pallas sqdist kernel. Ragged point
    /// blocks are padded with zero points, and a dimensionality below the
    /// artifact's is zero-extended — both exact for Euclidean distance —
    /// then the `r×c` corner is sliced out.
    pub fn dist_block(&self, xi: &Matrix, xj: &Matrix) -> RtResult<Matrix> {
        let (r, c, dim) = (xi.nrows(), xj.nrows(), xi.ncols());
        if xj.ncols() != dim {
            return Err(RtError::hard(anyhow!(
                "dist operands disagree on dimensionality: {dim} vs {}",
                xj.ncols()
            )));
        }
        let e = self.plan(OffloadOp::Dist, r.max(c), dim, 0)?;
        let (eb, edim) = (e.b, e.dim);
        let padded = r != eb || c != eb || dim != edim;
        let out = if padded {
            let xip = Self::pad_matrix(xi, eb, edim, 0.0);
            let xjp = Self::pad_matrix(xj, eb, edim, 0.0);
            self.run1(e, &[Self::lit(&xip)?, Self::lit(&xjp)?], eb, eb)?.slice(0, r, 0, c)
        } else {
            self.run1(e, &[Self::lit(xi)?, Self::lit(xj)?], r, c)?
        };
        self.record(OffloadOp::Dist, padded);
        Ok(out)
    }

    /// Min-plus product `a ⊗ b` via the Pallas kernel. Ragged operands are
    /// padded with `+∞` (the semiring's annihilator: padded terms never win
    /// the min) up to the artifact block size.
    pub fn minplus(&self, a: &Matrix, b: &Matrix) -> RtResult<Matrix> {
        let (m, k, n) = (a.nrows(), a.ncols(), b.ncols());
        if b.nrows() != k {
            return Err(RtError::hard(anyhow!(
                "minplus inner dimensions disagree: {k} vs {}",
                b.nrows()
            )));
        }
        let need = m.max(k).max(n);
        let e = self.plan(OffloadOp::Minplus, need, 0, 0)?;
        let eb = e.b;
        let padded = m != eb || k != eb || n != eb;
        let out = if padded {
            let ap = Self::pad_matrix(a, eb, eb, f64::INFINITY);
            let bp = Self::pad_matrix(b, eb, eb, f64::INFINITY);
            self.run1(e, &[Self::lit(&ap)?, Self::lit(&bp)?], eb, eb)?.slice(0, m, 0, n)
        } else {
            self.run1(e, &[Self::lit(a)?, Self::lit(b)?], eb, eb)?
        };
        self.record(OffloadOp::Minplus, padded);
        Ok(out)
    }

    /// In-block Floyd–Warshall via the Pallas kernel. A ragged diagonal
    /// block is padded with `+∞` rows/cols: padded pivots relax nothing
    /// (`∞ + w = ∞`), so the real corner is untouched.
    pub fn floyd_warshall(&self, g: &Matrix) -> RtResult<Matrix> {
        let r = g.nrows();
        if g.ncols() != r {
            return Err(RtError::hard(anyhow!(
                "fw requires a square block, got {r}×{}",
                g.ncols()
            )));
        }
        let e = self.plan(OffloadOp::Fw, r, 0, 0)?;
        let eb = e.b;
        let padded = r != eb;
        let out = if padded {
            let gp = Self::pad_matrix(g, eb, eb, f64::INFINITY);
            self.run1(e, &[Self::lit(&gp)?], eb, eb)?.slice(0, r, 0, r)
        } else {
            self.run1(e, &[Self::lit(g)?], r, r)?
        };
        self.record(OffloadOp::Fw, padded);
        Ok(out)
    }

    /// Double-centering application on one block. The op is element-wise,
    /// so ragged blocks pad with zeros and the mean vectors zero-extend
    /// (masked means: padded entries never reach the sliced result).
    pub fn center_block(
        &self,
        block: &Matrix,
        mu_r: &[f64],
        mu_c: &[f64],
        grand: f64,
    ) -> RtResult<Matrix> {
        let (r, c) = (block.nrows(), block.ncols());
        if mu_r.len() != r || mu_c.len() != c {
            return Err(RtError::hard(anyhow!(
                "center mean vectors ({}, {}) do not match block {r}×{c}",
                mu_r.len(),
                mu_c.len()
            )));
        }
        let e = self.plan(OffloadOp::Center, r.max(c), 0, 0)?;
        let eb = e.b;
        let padded = r != eb || c != eb;
        let out = if padded {
            let bp = Self::pad_matrix(block, eb, eb, 0.0);
            let args = vec![
                Self::lit(&bp)?,
                Self::lit_vec(&Self::pad_vec(mu_r, eb)),
                Self::lit_vec(&Self::pad_vec(mu_c, eb)),
                xla::Literal::scalar(grand),
            ];
            self.run1(e, &args, eb, eb)?.slice(0, r, 0, c)
        } else {
            let args = vec![
                Self::lit(block)?,
                Self::lit_vec(mu_r),
                Self::lit_vec(mu_c),
                xla::Literal::scalar(grand),
            ];
            self.run1(e, &args, r, c)?
        };
        self.record(OffloadOp::Center, padded);
        Ok(out)
    }

    /// `a · q` (power-iteration block product). Ragged blocks zero-pad to
    /// the artifact's `b`, and `q`'s column count zero-pads to the
    /// artifact width — both exact for matmul — then the `r×d` corner is
    /// sliced out.
    pub fn gemm(&self, a: &Matrix, q: &Matrix) -> RtResult<Matrix> {
        let (r, k, d) = (a.nrows(), a.ncols(), q.ncols());
        if q.nrows() != k {
            return Err(RtError::hard(anyhow!(
                "gemm inner dimensions disagree: {k} vs {}",
                q.nrows()
            )));
        }
        let e = self.plan(OffloadOp::Gemm, r.max(k), 0, d)?;
        let (eb, ed) = (e.b, e.d);
        let padded = r != eb || k != eb || d != ed;
        let out = if padded {
            let ap = Self::pad_matrix(a, eb, eb, 0.0);
            let qp = Self::pad_matrix(q, eb, ed, 0.0);
            self.run1(e, &[Self::lit(&ap)?, Self::lit(&qp)?], eb, ed)?.slice(0, r, 0, d)
        } else {
            self.run1(e, &[Self::lit(a)?, Self::lit(q)?], eb, ed)?
        };
        self.record(OffloadOp::Gemm, padded);
        Ok(out)
    }

    /// `aᵀ · q` — same padding scheme as [`Self::gemm`]; the result is the
    /// `c×d` corner (`c` = `a`'s column count).
    pub fn gemm_t(&self, a: &Matrix, q: &Matrix) -> RtResult<Matrix> {
        let (r, c, d) = (a.nrows(), a.ncols(), q.ncols());
        if q.nrows() != r {
            return Err(RtError::hard(anyhow!(
                "gemmt row counts disagree: {r} vs {}",
                q.nrows()
            )));
        }
        let e = self.plan(OffloadOp::Gemmt, r.max(c), 0, d)?;
        let (eb, ed) = (e.b, e.d);
        let padded = r != eb || c != eb || d != ed;
        let out = if padded {
            let ap = Self::pad_matrix(a, eb, eb, 0.0);
            let qp = Self::pad_matrix(q, eb, ed, 0.0);
            self.run1(e, &[Self::lit(&ap)?, Self::lit(&qp)?], eb, ed)?.slice(0, c, 0, d)
        } else {
            self.run1(e, &[Self::lit(a)?, Self::lit(q)?], eb, ed)?
        };
        self.record(OffloadOp::Gemmt, padded);
        Ok(out)
    }
}

impl std::fmt::Debug for PjrtEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PjrtEngine({} artifacts from {:?})", self.entries.len(), self.dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_missing_dir_errors() {
        let err = PjrtEngine::load(Path::new("/nonexistent/artifacts")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    /// Per-process-unique scratch dir so concurrent test runs sharing the
    /// system temp dir cannot race on manifest.json.
    fn scratch_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("isospark_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn manifest_parse_rejects_bad_json() {
        let dir = scratch_dir("rt_bad");
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(PjrtEngine::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_pad_policy_mismatch_is_a_hard_load_error() {
        let dir = scratch_dir("rt_badpad");
        // minplus pads with +inf; a manifest claiming "zero" must refuse
        // to load rather than silently produce wrong padded results.
        let manifest = r#"{"version": 2, "ops":
            [{"op": "minplus", "b": 32, "pad": "zero", "file": "x.hlo.txt"}]}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let err = PjrtEngine::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("pad policy mismatch"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pad_matrix_fills_and_preserves_corner() {
        let q = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let p = PjrtEngine::pad_matrix(&q, 4, 3, f64::INFINITY);
        assert_eq!((p.nrows(), p.ncols()), (4, 3));
        assert_eq!(p[(0, 0)], 1.0);
        assert_eq!(p[(1, 1)], 4.0);
        assert!(p[(0, 2)].is_infinite());
        assert!(p[(3, 0)].is_infinite());
        // Exact shape: untouched copy.
        let same = PjrtEngine::pad_matrix(&q, 2, 2, 0.0);
        assert_eq!(same.as_slice(), q.as_slice());
    }

    #[test]
    fn pad_vec_zero_extends() {
        assert_eq!(PjrtEngine::pad_vec(&[1.0, 2.0], 4), vec![1.0, 2.0, 0.0, 0.0]);
    }
}
