//! The real PJRT executor (enabled by the `pjrt` cargo feature): loads
//! `manifest.json`, compiles HLO-text artifacts once per (op, shape), and
//! executes them through the `xla` crate's PJRT CPU client. See the parent
//! module docs for the artifact pipeline and the offline stub.

use crate::linalg::Matrix;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// One artifact from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub op: String,
    /// Shape parameters, op-specific: `minplus`/`fw`/`center` use `b`;
    /// `dist` uses `b` and `dim`; `gemm`/`gemmt` use `b` and `d`.
    pub b: usize,
    pub dim: usize,
    pub d: usize,
    pub file: PathBuf,
}

/// Lazily-compiling PJRT executor over an artifact directory.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    entries: Vec<ArtifactEntry>,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// Serializes every compile/execute against the PJRT client: the
    /// multi-core stage executor calls the backend from many worker
    /// threads, and the `xla_extension` bindings make no documented
    /// thread-safety promise, so we take the conservative route — one
    /// in-flight PJRT call at a time. Block ops still overlap with the
    /// native-kernel work of other workers.
    exec: Mutex<()>,
    dir: PathBuf,
}

// SAFETY: all uses of the non-Sync xla handles after construction happen
// with `exec` (or `cache`) held, so at most one thread touches the PJRT
// client / executables at any moment; the remaining fields are plain data.
unsafe impl Send for PjrtEngine {}
unsafe impl Sync for PjrtEngine {}

impl PjrtEngine {
    /// Load `dir/manifest.json` and create the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?} — run `make artifacts` first"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let ops = json
            .get("ops")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing `ops` array"))?;
        let mut entries = Vec::new();
        for o in ops {
            let get = |k: &str| o.get(k).and_then(Json::as_usize).unwrap_or(0);
            entries.push(ArtifactEntry {
                op: o
                    .get("op")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("op entry missing name"))?
                    .to_string(),
                b: get("b"),
                dim: get("dim"),
                d: get("d"),
                file: dir.join(
                    o.get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("op entry missing file"))?,
                ),
            });
        }
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self {
            client,
            entries,
            cache: Mutex::new(HashMap::new()),
            exec: Mutex::new(()),
            dir: dir.to_path_buf(),
        })
    }

    /// Artifact directory this engine serves.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Available (op, b, dim, d) tuples — for `isospark info`.
    pub fn inventory(&self) -> Vec<String> {
        self.entries
            .iter()
            .map(|e| format!("{} b={} dim={} d={} ({})", e.op, e.b, e.dim, e.d, e.file.display()))
            .collect()
    }

    fn find(&self, op: &str, b: usize, dim: usize, d: usize) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.op == op && e.b == b && e.dim == dim && e.d == d)
            .ok_or_else(|| anyhow!("no artifact for {op} b={b} dim={dim} d={d}"))
    }

    fn executable(&self, e: &ArtifactEntry) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let key = format!("{}:{}:{}:{}", e.op, e.b, e.dim, e.d);
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(Arc::clone(exe));
        }
        let proto = xla::HloModuleProto::from_text_file(&e.file)
            .with_context(|| format!("parse HLO text {:?}", e.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp).with_context(|| format!("compile {key}"))?);
        self.cache.lock().unwrap().insert(key, Arc::clone(&exe));
        Ok(exe)
    }

    fn lit(m: &Matrix) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(m.as_slice()).reshape(&[m.nrows() as i64, m.ncols() as i64])?)
    }

    fn lit_vec(v: &[f64]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    fn run1(&self, e: &ArtifactEntry, args: &[xla::Literal], rows: usize, cols: usize) -> Result<Matrix> {
        let _serialized = self.exec.lock().unwrap();
        let exe = self.executable(e)?;
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f64>()?;
        if data.len() != rows * cols {
            bail!("artifact {} returned {} elements, expected {}", e.op, data.len(), rows * cols);
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }

    /// Pairwise-distance block via the Pallas sqdist kernel.
    pub fn dist_block(&self, xi: &Matrix, xj: &Matrix) -> Result<Matrix> {
        if xi.nrows() != xj.nrows() || xi.ncols() != xj.ncols() {
            bail!("dist artifacts require equal square point blocks");
        }
        let e = self.find("dist", xi.nrows(), xi.ncols(), 0)?;
        self.run1(e, &[Self::lit(xi)?, Self::lit(xj)?], xi.nrows(), xj.nrows())
    }

    /// Min-plus product `a ⊗ b` via the Pallas kernel.
    pub fn minplus(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let bsz = a.nrows();
        if a.ncols() != bsz || b.nrows() != bsz || b.ncols() != bsz {
            bail!("minplus artifacts are square-only");
        }
        let e = self.find("minplus", bsz, 0, 0)?;
        self.run1(e, &[Self::lit(a)?, Self::lit(b)?], bsz, bsz)
    }

    /// In-block Floyd–Warshall via the Pallas kernel.
    pub fn floyd_warshall(&self, g: &Matrix) -> Result<Matrix> {
        let bsz = g.nrows();
        if g.ncols() != bsz {
            bail!("fw requires square block");
        }
        let e = self.find("fw", bsz, 0, 0)?;
        self.run1(e, &[Self::lit(g)?], bsz, bsz)
    }

    /// Double-centering application on one block.
    pub fn center_block(&self, block: &Matrix, mu_r: &[f64], mu_c: &[f64], grand: f64) -> Result<Matrix> {
        let bsz = block.nrows();
        if block.ncols() != bsz || mu_r.len() != bsz || mu_c.len() != bsz {
            bail!("center requires square block with matching mean vectors");
        }
        let e = self.find("center", bsz, 0, 0)?;
        let args = vec![
            Self::lit(block)?,
            Self::lit_vec(mu_r),
            Self::lit_vec(mu_c),
            xla::Literal::scalar(grand),
        ];
        self.run1(e, &args, bsz, bsz)
    }

    /// Find the gemm artifact column width for block size `b` (smallest
    /// `d_pad >= d`).
    fn gemm_entry(&self, op: &str, b: usize, d: usize) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.op == op && e.b == b && e.d >= d)
            .min_by_key(|e| e.d)
            .ok_or_else(|| anyhow!("no {op} artifact for b={b} d>={d}"))
    }

    fn pad_cols(q: &Matrix, d_pad: usize) -> Matrix {
        if q.ncols() == d_pad {
            return q.clone();
        }
        let mut p = Matrix::zeros(q.nrows(), d_pad);
        for i in 0..q.nrows() {
            p.row_mut(i)[..q.ncols()].copy_from_slice(q.row(i));
        }
        p
    }

    /// `a · q` (power-iteration block product). `q`'s column count may be
    /// smaller than the artifact width; zero-padding is exact.
    pub fn gemm(&self, a: &Matrix, q: &Matrix) -> Result<Matrix> {
        let bsz = a.nrows();
        if a.ncols() != bsz || q.nrows() != bsz {
            bail!("gemm artifacts are (b,b)x(b,d)");
        }
        let e = self.gemm_entry("gemm", bsz, q.ncols())?;
        let qp = Self::pad_cols(q, e.d);
        let full = self.run1(e, &[Self::lit(a)?, Self::lit(&qp)?], bsz, e.d)?;
        Ok(full.slice(0, bsz, 0, q.ncols()))
    }

    /// `aᵀ · q`.
    pub fn gemm_t(&self, a: &Matrix, q: &Matrix) -> Result<Matrix> {
        let bsz = a.nrows();
        if a.ncols() != bsz || q.nrows() != bsz {
            bail!("gemmt artifacts are (b,b)x(b,d)");
        }
        let e = self.gemm_entry("gemmt", bsz, q.ncols())?;
        let qp = Self::pad_cols(q, e.d);
        let full = self.run1(e, &[Self::lit(a)?, Self::lit(&qp)?], bsz, e.d)?;
        Ok(full.slice(0, bsz, 0, q.ncols()))
    }
}

impl std::fmt::Debug for PjrtEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PjrtEngine({} artifacts from {:?})", self.entries.len(), self.dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_missing_dir_errors() {
        let err = PjrtEngine::load(Path::new("/nonexistent/artifacts")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn manifest_parse_rejects_bad_json() {
        let dir = std::env::temp_dir().join("isospark_rt_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(PjrtEngine::load(&dir).is_err());
    }

    #[test]
    fn pad_cols_zero_extends() {
        let q = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let p = PjrtEngine::pad_cols(&q, 4);
        assert_eq!(p.ncols(), 4);
        assert_eq!(p[(0, 0)], 1.0);
        assert_eq!(p[(1, 3)], 0.0);
    }
}
