//! Compute-backend abstraction.
//!
//! Every numerically heavy block operation the coordinator issues goes
//! through a [`Backend`], which either runs the native Rust kernels
//! ([`crate::kernels`]) or executes the AOT-compiled Pallas/JAX artifacts
//! through PJRT ([`crate::runtime`]). The `runtime_equivalence` test suite
//! asserts the two agree to tight tolerances; benches compare their
//! throughput (ablation d: BLAS-offload vs interpreter, mirroring the
//! paper's NumPy→MKL offload argument).
//!
//! **Fallback policy** (see `runtime` module docs): a PJRT call that fails
//! with [`crate::runtime::RtError::ShapeMiss`] — the runtime has no
//! artifact, even via padding, for the shape — falls back to the native
//! kernel; the runtime has already counted the miss in its
//! [`OffloadStats`], so the coverage
//! report stays honest. Any other runtime error is a *hard* failure
//! (corrupt artifact, compile error, wrong element count) and panics with
//! context instead of silently degrading to native execution; the stage
//! executor forwards the panic to the driver with the task index.
//!
//! Backends are `Send + Sync`: the multi-core stage executor invokes the
//! same backend concurrently from every worker thread.

use crate::engine::metrics::{OffloadOpSnapshot, OffloadStats};
use crate::kernels;
use crate::linalg::Matrix;
use crate::runtime::{PjrtEngine, RtResult};
use anyhow::Result;
use std::sync::Arc;

/// Unwrap a PJRT result under the fallback policy: `Ok` passes through,
/// a shape miss (already counted by the runtime) yields `None` so the
/// caller runs the native kernel, and a hard error panics with context.
fn pjrt_or_native<T>(what: &str, res: RtResult<T>) -> Option<T> {
    match res {
        Ok(v) => Some(v),
        Err(e) if e.is_shape_miss() => None,
        Err(e) => panic!("PJRT backend hard failure in {what} (not a shape miss): {e}"),
    }
}

/// Which engine executes block math.
#[derive(Clone)]
pub enum Backend {
    /// Pure-Rust kernels (always available; also the perf baseline).
    Native,
    /// AOT Pallas/JAX artifacts via the PJRT CPU client.
    Pjrt(Arc<PjrtEngine>),
}

impl Backend {
    /// Load the PJRT backend from an artifact directory (`make artifacts`).
    pub fn pjrt_from_dir(dir: &std::path::Path) -> Result<Backend> {
        Ok(Backend::Pjrt(Arc::new(PjrtEngine::load(dir)?)))
    }

    /// Human-readable name.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Offload counters of the PJRT runtime (`None` for the native
    /// backend, which has nothing to offload).
    pub fn offload_stats(&self) -> Option<&OffloadStats> {
        match self {
            Backend::Native => None,
            Backend::Pjrt(rt) => Some(rt.stats()),
        }
    }

    /// Snapshot of the per-op offload counters, when PJRT is in use.
    pub fn offload_snapshot(&self) -> Option<Vec<OffloadOpSnapshot>> {
        self.offload_stats().map(OffloadStats::snapshot)
    }

    /// Rendered offload-coverage table, when PJRT is in use.
    pub fn offload_report(&self) -> Option<String> {
        self.offload_stats().map(OffloadStats::report)
    }

    /// Pairwise-distance block `‖x_i − y_j‖₂`.
    pub fn dist_block(&self, xi: &Matrix, xj: &Matrix) -> Matrix {
        match self {
            Backend::Native => kernels::sqdist::dist_block(xi, xj),
            Backend::Pjrt(rt) => pjrt_or_native("dist_block", rt.dist_block(xi, xj))
                .unwrap_or_else(|| kernels::sqdist::dist_block(xi, xj)),
        }
    }

    /// Diagonal pairwise-distance block `‖x_i − x_j‖₂` with an exactly-zero
    /// diagonal. The native kernel computes only the upper triangle and
    /// mirrors (bit-symmetric at ~half the FLOPs); the PJRT path reuses the
    /// general distance artifact and fixes the diagonal, matching the old
    /// hand-rolled zeroing the kNN coordinator carried.
    pub fn dist_block_sym(&self, x: &Matrix) -> Matrix {
        match self {
            Backend::Native => kernels::sqdist::dist_block_sym(x),
            Backend::Pjrt(rt) => match pjrt_or_native("dist_block_sym", rt.dist_block(x, x)) {
                Some(mut d) => {
                    for r in 0..d.nrows() {
                        d[(r, r)] = 0.0;
                    }
                    d
                }
                None => kernels::sqdist::dist_block_sym(x),
            },
        }
    }

    /// `dst = min(dst, a ⊗ b)` over the min-plus semiring.
    pub fn minplus_into(&self, a: &Matrix, b: &Matrix, dst: &mut Matrix) {
        match self {
            Backend::Native => kernels::minplus::minplus_into(a, b, dst),
            Backend::Pjrt(rt) => match pjrt_or_native("minplus_into", rt.minplus(a, b)) {
                Some(c) => kernels::minplus::elementwise_min_into(dst, &c),
                None => kernels::minplus::minplus_into(a, b, dst),
            },
        }
    }

    /// `dst = dst ⊕ (a ⊗ dst)` — the APSP Phase-2 *row* update
    /// `A_{IJ} ← A_{IJ} ⊕ (D ⊗ A_{IJ})` without allocating a copy of the
    /// old block (the native kernel stages it in per-thread scratch).
    pub fn minplus_left_inplace(&self, a: &Matrix, dst: &mut Matrix) {
        match self {
            Backend::Native => kernels::minplus::minplus_left_inplace(a, dst),
            Backend::Pjrt(rt) => match pjrt_or_native("minplus_left_inplace", rt.minplus(a, dst)) {
                Some(c) => kernels::minplus::elementwise_min_into(dst, &c),
                None => kernels::minplus::minplus_left_inplace(a, dst),
            },
        }
    }

    /// `dst = dst ⊕ (dst ⊗ b)` — the APSP Phase-2 *column* update
    /// `A_{ÎI} ← A_{ÎI} ⊕ (A_{ÎI} ⊗ D)`, same scratch-reuse strategy.
    pub fn minplus_right_inplace(&self, b: &Matrix, dst: &mut Matrix) {
        match self {
            Backend::Native => kernels::minplus::minplus_right_inplace(b, dst),
            Backend::Pjrt(rt) => match pjrt_or_native("minplus_right_inplace", rt.minplus(dst, b))
            {
                Some(c) => kernels::minplus::elementwise_min_into(dst, &c),
                None => kernels::minplus::minplus_right_inplace(b, dst),
            },
        }
    }

    /// In-place Floyd–Warshall on a square block.
    pub fn fw_inplace(&self, g: &mut Matrix) {
        match self {
            Backend::Native => kernels::floyd_warshall::floyd_warshall_inplace(g),
            Backend::Pjrt(rt) => match pjrt_or_native("fw_inplace", rt.floyd_warshall(g)) {
                Some(out) => *g = out,
                None => kernels::floyd_warshall::floyd_warshall_inplace(g),
            },
        }
    }

    /// Double-centering application on one block.
    pub fn center_block(&self, block: &mut Matrix, mu_r: &[f64], mu_c: &[f64], grand: f64) {
        match self {
            Backend::Native => kernels::centering::center_block(block, mu_r, mu_c, grand),
            Backend::Pjrt(rt) => {
                match pjrt_or_native("center_block", rt.center_block(block, mu_r, mu_c, grand)) {
                    Some(out) => *block = out,
                    None => kernels::centering::center_block(block, mu_r, mu_c, grand),
                }
            }
        }
    }

    /// `out += a · q` (power-iteration block product).
    pub fn gemm_acc(&self, a: &Matrix, q: &Matrix, out: &mut Matrix) {
        match self {
            Backend::Native => kernels::matvec::gemm_acc(a, q, out),
            Backend::Pjrt(rt) => match pjrt_or_native("gemm_acc", rt.gemm(a, q)) {
                Some(c) => {
                    for (o, &x) in out.as_mut_slice().iter_mut().zip(c.as_slice()) {
                        *o += x;
                    }
                }
                None => kernels::matvec::gemm_acc(a, q, out),
            },
        }
    }

    /// `out += aᵀ · q` (transposed contribution of upper-triangular blocks).
    pub fn gemm_t_acc(&self, a: &Matrix, q: &Matrix, out: &mut Matrix) {
        match self {
            Backend::Native => kernels::matvec::gemm_t_acc(a, q, out),
            Backend::Pjrt(rt) => match pjrt_or_native("gemm_t_acc", rt.gemm_t(a, q)) {
                Some(c) => {
                    for (o, &x) in out.as_mut_slice().iter_mut().zip(c.as_slice()) {
                        *o += x;
                    }
                }
                None => kernels::matvec::gemm_t_acc(a, q, out),
            },
        }
    }
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Backend::{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut a = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                a[(i, j)] = rng.range(0.0, 5.0);
            }
        }
        a
    }

    #[test]
    fn backend_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Backend>();
    }

    #[test]
    fn native_backend_has_no_offload_stats() {
        assert!(Backend::Native.offload_stats().is_none());
        assert!(Backend::Native.offload_snapshot().is_none());
        assert!(Backend::Native.offload_report().is_none());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_pjrt_backend_falls_back_and_counts_misses() {
        use crate::engine::metrics::OffloadOp;
        // A disconnected stub engine serves nothing: every call must fall
        // back to the native kernel (identical results) and record exactly
        // one miss — the honest-accounting half of the fallback policy.
        let be = Backend::Pjrt(Arc::new(PjrtEngine::disconnected(std::path::Path::new(
            "artifacts",
        ))));
        let x = random(5, 3, 1);
        assert_eq!(
            be.dist_block(&x, &x).as_slice(),
            Backend::Native.dist_block(&x, &x).as_slice()
        );
        let a = random(4, 4, 2);
        let b = random(4, 4, 3);
        let mut dst = Matrix::full(4, 4, f64::INFINITY);
        let mut dst_native = dst.clone();
        be.minplus_into(&a, &b, &mut dst);
        Backend::Native.minplus_into(&a, &b, &mut dst_native);
        assert_eq!(dst.as_slice(), dst_native.as_slice());
        let snap = be.offload_stats().unwrap();
        assert_eq!(snap.op_snapshot(OffloadOp::Dist).missed, 1);
        assert_eq!(snap.op_snapshot(OffloadOp::Minplus).missed, 1);
        assert_eq!(snap.op_snapshot(OffloadOp::Dist).offloaded(), 0);
        let report = be.offload_report().unwrap();
        assert!(report.contains("dist"), "{report}");
        assert!(report.contains("0.0%"), "{report}");
    }

    #[test]
    fn native_backend_smoke() {
        let be = Backend::Native;
        assert_eq!(be.name(), "native");
        let x = random(4, 3, 1);
        let d = be.dist_block(&x, &x);
        assert_eq!(d.nrows(), 4);
        let a = random(4, 4, 2);
        let b = random(4, 4, 3);
        let mut dst = Matrix::full(4, 4, f64::INFINITY);
        be.minplus_into(&a, &b, &mut dst);
        assert!(dst.as_slice().iter().all(|v| v.is_finite()));
        let mut out = Matrix::zeros(4, 2);
        be.gemm_acc(&a, &random(4, 2, 4), &mut out);
        assert!(out.fro_norm() > 0.0);
    }

    #[test]
    fn dist_block_sym_matches_general() {
        let be = Backend::Native;
        let x = random(9, 4, 5);
        let sym = be.dist_block_sym(&x);
        let full = be.dist_block(&x, &x);
        for i in 0..9 {
            assert_eq!(sym[(i, i)], 0.0);
            for j in 0..9 {
                if i != j {
                    assert_eq!(sym[(i, j)].to_bits(), full[(i, j)].to_bits(), "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn inplace_updates_match_two_step_form() {
        let be = Backend::Native;
        let d = random(6, 6, 10);
        let a0 = random(6, 6, 11);

        // Left: A ← A ⊕ (D ⊗ A) vs explicit old-copy formulation.
        let mut left = a0.clone();
        be.minplus_left_inplace(&d, &mut left);
        let mut want = a0.clone();
        let old = a0.clone();
        be.minplus_into(&d, &old, &mut want);
        assert_eq!(left.as_slice(), want.as_slice());

        // Right: A ← A ⊕ (A ⊗ D).
        let mut right = a0.clone();
        be.minplus_right_inplace(&d, &mut right);
        let mut want = a0.clone();
        be.minplus_into(&old, &d, &mut want);
        assert_eq!(right.as_slice(), want.as_slice());
    }
}
